"""Roofline summary: aggregates results/dryrun/*.json (produced by
``python -m repro.launch.sweep``) into per-cell rows. Requires the sweep to
have run; cells not yet swept are reported as missing."""
from __future__ import annotations

import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def load_cells():
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run():
    rows = []
    cells = load_cells()
    if not cells:
        # non-zero value: a zero here reads as "roofline measured 0" in
        # the CSV; 1.0 marks an intentional not-yet-swept sentinel row
        return [("roofline.note", 1.0,
                 "no cells swept; run `python -m repro.launch.sweep` first")]
    n_ok = n_skip = n_err = 0
    worst = None
    for c in cells:
        key = f"{c.get('arch')}__{c.get('shape')}__{c.get('mesh', '?')}"
        if "skipped" in c:
            n_skip += 1
            continue
        if "error" in c:
            n_err += 1
            rows.append((f"roofline.ERROR.{key}", 0.0, c["error"][:60]))
            continue
        n_ok += 1
        rl = c["roofline"]
        rows.append((f"roofline.{key}.bound_s",
                     rl["step_time_bound_s"] * 1e6,
                     f"dom={rl['dominant']} frac={rl['roofline_fraction']:.3f}"
                     f" useful={rl['useful_flops_ratio']:.3f}"
                     f" fits={c['memory']['fits_16GB']}"))
        if worst is None or rl["roofline_fraction"] < worst[1]:
            worst = (key, rl["roofline_fraction"])
    rows.append(("roofline.cells_ok", float(n_ok), ""))
    rows.append(("roofline.cells_skipped_documented", float(n_skip), ""))
    rows.append(("roofline.cells_error", float(n_err), ""))
    if worst:
        rows.append(("roofline.worst_fraction", worst[1], worst[0]))
    return rows
