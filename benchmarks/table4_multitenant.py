"""Multi-tenant serving: cross-tenant continuous batching on one shared
device vs. sequential per-tenant serving (each tenant gets the device in
turn, RSaaS-style time sharing).

The paper's multi-tenancy argument (§V): co-residency maximizes device
utilization. For LM serving the same effect appears as decode-slot
occupancy — each tenant alone leaves slots idle; batching ACROSS tenants
fills them, so aggregate throughput rises with no per-request code change.
Both paths run through the RC3E hypervisor (sessions, vSlices, audit log);
the decode executable is compiled once and PR-swapped from the program
cache for every session.
"""
from __future__ import annotations

import time

import jax
import numpy as np

N_TENANTS = 4
REQS_PER_TENANT = 2          # a trickle per tenant: the realistic case
PROMPT_LEN = 4
MAX_NEW = 16
N_SLOTS = 4


def _setup():
    from repro.configs import get_config, reduced
    from repro.models import get_model
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, seed):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=PROMPT_LEN).tolist()
            for _ in range(REQS_PER_TENANT)]


def _serve(gw, tenant, prompts):
    reqs = [gw.submit(tenant, p, max_new_tokens=MAX_NEW) for p in prompts]
    return reqs


def run():
    from repro.core import ClusterSpec, Hypervisor
    from repro.runtime import ServingGateway

    cfg, model, params = _setup()
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    tenants = [f"t{i}" for i in range(N_TENANTS)]

    # ---- sequential: each tenant served alone, one after another ----
    gw = ServingGateway(hv, model, params, n_slots=N_SLOTS, max_len=64)
    gw.open_session("warmup", slots=1)        # warm the decode executable
    gw.submit("warmup", _prompts(cfg, 99)[0], max_new_tokens=2)
    gw.run_until_idle()
    gw.close_session("warmup")
    gw.engine.steps = 0
    t0 = time.perf_counter()
    seq_tokens = seq_steps = 0
    for i, t in enumerate(tenants):
        gw.open_session(t, slots=1)
        reqs = _serve(gw, t, _prompts(cfg, i))
        gw.run_until_idle()
        gw.close_session(t)
        seq_tokens += sum(len(r.out_tokens) for r in reqs)
    seq_s = time.perf_counter() - t0
    seq_steps = gw.engine.steps

    # ---- cross-tenant: all tenants co-resident, one batched stream ----
    hv2 = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    hv2.reconfig = hv.reconfig                # shared program cache (PR hit)
    gw2 = ServingGateway(hv2, model, params, n_slots=N_SLOTS, max_len=64)
    for t in tenants:
        gw2.open_session(t, slots=1)
    t1 = time.perf_counter()
    reqs = []
    for i, t in enumerate(tenants):
        reqs += _serve(gw2, t, _prompts(cfg, i))
    gw2.run_until_idle()
    bat_s = time.perf_counter() - t1
    bat_tokens = sum(len(r.out_tokens) for r in reqs)
    bat_steps = gw2.engine.steps
    gw2.close()

    assert bat_tokens == seq_tokens, (bat_tokens, seq_tokens)
    seq_tps = seq_tokens / seq_s
    bat_tps = bat_tokens / bat_s
    rows = [
        ("table4.sequential_tok_s", seq_tps,
         f"{N_TENANTS} tenants served one-by-one; {seq_steps} engine steps"),
        ("table4.cross_tenant_tok_s", bat_tps,
         f"co-resident tenants batched per step; {bat_steps} engine steps"),
        ("table4.batched_speedup", bat_tps / seq_tps,
         "paper §V: co-residency maximizes utilization"),
    ]
    assert bat_tps >= seq_tps, \
        f"cross-tenant batching slower than sequential ({bat_tps:.1f} < {seq_tps:.1f} tok/s)"
    return rows
