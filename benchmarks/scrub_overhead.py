"""Zero-on-free cost: paged decode step time with ``scrub_on_free`` on vs
off, under a slot-churn workload where pages actually recycle.

The isolation policy's only dataplane cost is the batched device-side
scrub ``BatchingEngine._flush_scrub`` dispatches before allocations. This
cell measures it where it is hottest: a steady stream of short requests so
slots (and their pages) turn over continuously and nearly every step both
frees and reallocates pages. Acceptance gate for the tenant-isolation PR:
**scrub-on median step time within 5% of scrub-off** (ratio <= 1.05).

Also reported: cumulative scrub dispatch milliseconds (the number the
gateway exports to ``Monitor.status()["scrub"]``) and pages scrubbed, so
the per-page cost is visible, not just the ratio.

Run:  PYTHONPATH=src python benchmarks/scrub_overhead.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

PAGE_SIZE = 16
N_SLOTS = 4
MAX_LEN = 128


def _setup():
    from repro.configs import get_config, reduced
    from repro.models import get_model
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _churn_workload(cfg, n_reqs, prompt_len=12, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=prompt_len).tolist()
            for _ in range(n_reqs)]


def _churn_step_ms(model, params, cfg, scrub: bool, n_reqs: int,
                   max_new: int = 6):
    """Median per-step wall time draining ``n_reqs`` short requests (every
    completion frees pages; every admission re-allocates them — the
    scrub queue is hot the whole run). Returns (median_ms, pages_scrubbed,
    scrub_ms)."""
    from repro.runtime import BatchingEngine
    eng = BatchingEngine(model, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                         paged=True, page_size=PAGE_SIZE,
                         scrub_on_free=scrub)
    for p in _churn_workload(cfg, n_reqs):
        eng.submit(p, max_new_tokens=max_new)
    for _ in range(4):                      # warm the decode executable
        eng.step()
    times = []
    for _ in range(10000):
        t0 = time.perf_counter()
        n = eng.step()
        times.append((time.perf_counter() - t0) * 1e3)
        if n == 0 and eng.idle():
            break
    assert eng.idle(), "churn workload did not drain"
    pool = eng.pool
    assert pool.used_pages == 0
    if scrub:
        assert pool.pages_scrubbed > 0, \
            "no pages recycled — the cell measured nothing"
    return float(np.median(times)), pool.pages_scrubbed, eng.scrub_ms


def measure(model, params, cfg, smoke: bool):
    n_reqs = 16 if smoke else 48
    off_ms, _, _ = _churn_step_ms(model, params, cfg, False, n_reqs)
    on_ms, pages, scrub_ms = _churn_step_ms(model, params, cfg, True, n_reqs)
    ratio = on_ms / off_ms
    per_page_us = 1e3 * scrub_ms / max(1, pages)
    return ratio, on_ms, off_ms, pages, scrub_ms, per_page_us


def run():
    """Harness entry (``benchmarks/run.py``): CSV rows."""
    cfg, model, params = _setup()
    ratio, on_ms, off_ms, pages, scrub_ms, per_page_us = \
        measure(model, params, cfg, smoke=True)
    return [
        ("scrub_overhead.step_ms_scrub_on", on_ms * 1e3,
         f"median us/step; {pages} pages scrubbed"),
        ("scrub_overhead.step_ms_scrub_off", off_ms * 1e3,
         "median us/step baseline arm"),
        ("scrub_overhead.on_off_ratio", ratio,
         f"target<=1.05; scrub dispatch {scrub_ms:.2f}ms total "
         f"({per_page_us:.1f}us/page)"),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload for CI")
    args = ap.parse_args()
    cfg, model, params = _setup()
    ratio, on_ms, off_ms, pages, scrub_ms, per_page_us = \
        measure(model, params, cfg, args.smoke)
    print("== zero-on-free scrub overhead (slot-churn paged decode) ==")
    print(f"  scrub off: {off_ms:.3f} ms/step (median)")
    print(f"  scrub on : {on_ms:.3f} ms/step (median), {pages} pages "
          f"scrubbed, {scrub_ms:.2f} ms total dispatch "
          f"({per_page_us:.1f} us/page)")
    print(f"  => on/off step-time ratio {ratio:.3f} (target <= 1.05)")
    if ratio > 1.05:
        print("WARNING: scrub overhead exceeded the 5% envelope on this "
              "host (batched dispatch amortizes poorly on tiny CPU "
              "models; re-check on an accelerator before gating)")


if __name__ == "__main__":
    main()
