"""Serving fleet scale-out: aggregate throughput vs. number of physical
devices, and tail latency recovering after a hot tenant is migrated off a
loaded device — numbers the single-engine gateway structurally cannot
produce (its dataplane never followed the hypervisor's placement).

Devices execute concurrently in real hardware; on this one-host simulation
the engines are stepped round-robin, so aggregate throughput is accounted
in DEVICE-PARALLEL time: each fleet round costs max(per-engine step wall)
— exactly one decode step deep on every active device. Host wall time is
reported alongside for transparency.

Latency is measured in fleet rounds (deterministic): the number of steps a
request spends between submission and completion. After the hot tenant is
handed off to a woken device, its former co-tenants stop competing with it
for decode slots and their p95 drops.
"""
from __future__ import annotations

import jax
import numpy as np

PROMPT_LEN = 4            # ctx 3 -> prefills through the compiled decode path
MAX_NEW = 16
N_SLOTS = 4
TENANTS_PER_DEVICE = 4
REQS_PER_TENANT = 3


def _setup():
    from repro.configs import get_config, reduced
    from repro.models import get_model
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, rng):
    return rng.integers(0, cfg.vocab_size, size=PROMPT_LEN).tolist()


def _run_to_idle_timed(fleet):
    """Drive the fleet to idle; returns (rounds, device_parallel_s).

    A round's device-parallel cost is the slowest engine's step wall; the
    total uses the MEDIAN round cost x rounds so one background-load spike
    on the shared host does not swamp the comparison (every config decodes
    the same batch shape, so round cost is structurally constant)."""
    import time
    rounds, round_ms, host0 = 0, [], time.perf_counter()
    while True:
        n = fleet.step()
        if fleet.last_round_ms:
            round_ms.append(max(fleet.last_round_ms.values()))
            rounds += 1
        if n == 0 and all(e.idle() for e in fleet._engines.values()):
            sim_s = rounds * float(np.median(round_ms)) / 1e3 \
                if rounds else 0.0
            return rounds, sim_s, time.perf_counter() - host0


def _throughput_at(n_devices, model, params, cfg, reconfig):
    from repro.core import ClusterSpec, Hypervisor
    from repro.runtime import GatewayFleet
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=n_devices))
    hv.reconfig = reconfig                 # shared program cache (PR hits)
    fleet = GatewayFleet(hv, model, params, n_slots=N_SLOTS, max_len=64)
    rng = np.random.default_rng(0)
    tenants = [f"t{i}" for i in range(TENANTS_PER_DEVICE * n_devices)]
    for t in tenants:
        fleet.open_session(t, slots=1)
    reqs = []
    for r in range(REQS_PER_TENANT):
        for t in tenants:
            reqs.append(fleet.submit(t, _prompt(cfg, rng),
                                     max_new_tokens=MAX_NEW))
    rounds, sim_s, host_s = _run_to_idle_timed(fleet)
    tokens = sum(len(r.out_tokens) for r in reqs)
    assert tokens == len(reqs) * MAX_NEW, (tokens, len(reqs))
    assert len(fleet._engines) == n_devices, "placement must span all devices"
    fleet.close()
    return tokens / sim_s, rounds, host_s


def _latency_recovery(model, params, cfg, reconfig):
    """p95 latency (in rounds) of the co-tenants of a hot tenant, before
    vs. after the hot tenant is migrated to a woken device. Engine slots
    (2) are scarcer than the device's 4 vSlice slots, so co-residency
    costs real decode concurrency until the hand-off."""
    from repro.core import ClusterSpec, Hypervisor
    from repro.runtime import GatewayFleet
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    hv.reconfig = reconfig
    fleet = GatewayFleet(hv, model, params, n_slots=2, max_len=64)
    hot = fleet.open_session("hot", slots=2)
    fleet.open_session("a", slots=1)
    fleet.open_session("b", slots=1)
    assert fleet.device_of("hot") == fleet.device_of("a") == \
        fleet.device_of("b"), "pack-first must co-locate all three"
    rng = np.random.default_rng(1)

    def wave():
        """Submit one burst and drain it, returning co-tenant latencies in
        rounds-in-system."""
        reqs = []
        for _ in range(6):
            reqs.append(("hot", fleet.submit("hot", _prompt(cfg, rng),
                                             max_new_tokens=MAX_NEW)))
        for t in ("a", "b"):
            for _ in range(3):
                reqs.append((t, fleet.submit(t, _prompt(cfg, rng),
                                             max_new_tokens=MAX_NEW)))
        start = fleet.steps
        pending = {r[1].request_id: (r[0], start) for r in reqs}
        lats = []
        while pending:
            fleet.step()
            for tenant, req in reqs:
                if req.request_id in pending and req.done.is_set():
                    t0 = pending.pop(req.request_id)[1]
                    if tenant != "hot":
                        lats.append(fleet.steps - t0)
        return lats

    before = wave()
    # the monitor flags the hot tenant; the sweep hands its session off to
    # the PARKED second device (live migration of any in-flight work)
    for _ in range(8):
        hv.monitor.record_step(hot.slice_id, 400.0)
        for t in ("a", "b"):
            # at the typical real per-step time: keeps the co-tenants
            # safely under straggler_factor x fleet median
            hv.monitor.record_step(fleet.session(t).slice_id, 1.0)
    fleet.rebalance()
    assert fleet.device_of("hot") != fleet.device_of("a"), \
        "hot tenant must have moved off the loaded device"
    after = wave()
    fleet.close()
    return (float(np.percentile(before, 95)),
            float(np.percentile(after, 95)))


def run():
    from repro.core import Reconfigurator
    cfg, model, params = _setup()
    reconfig = Reconfigurator()

    _throughput_at(1, model, params, cfg, reconfig)   # warm compiles
    tps, rows = {}, []
    for n in (1, 2, 4):
        tps[n], rounds, host_s = _throughput_at(n, model, params, cfg,
                                                reconfig)
        rows.append((f"fleet.tok_s_{n}dev", tps[n],
                     f"{TENANTS_PER_DEVICE * n} tenants; {rounds} rounds; "
                     f"device-parallel accounting; host wall {host_s:.2f}s"))
    speedup = tps[4] / tps[1]
    rows.append(("fleet.scaleout_speedup_4v1", speedup,
                 "aggregate throughput, 4 engines vs 1"))
    assert speedup > 2.0, \
        f"fleet scale-out too weak: {speedup:.2f}x at 4 devices"

    p95_before, p95_after = _latency_recovery(model, params, cfg, reconfig)
    rows.append(("fleet.cotenant_p95_rounds_before", p95_before,
                 "co-tenants of the hot tenant, shared device"))
    rows.append(("fleet.cotenant_p95_rounds_after", p95_after,
                 "after live hand-off of the hot tenant"))
    rows.append(("fleet.p95_recovery", p95_before / p95_after,
                 "tail latency recovered by straggler migration"))
    assert p95_after < p95_before, \
        f"migration did not recover tail latency ({p95_after} >= {p95_before})"
    return rows
