"""Paper Table III / §V: streaming matrix-multiplication cores.

The paper streams 100k 16x16 (and 32x32) fp32 matrix multiplications
through 1/2/4 vFPGA cores sharing the 800 MB/s host link:
  16x16: 1 core 509 MB/s (compute-bound) -> 2 cores 398 -> 4 cores 198
  32x32: 1 core 279 -> 2 cores 277 (still compute-bound)

Reproduction here has three layers:
  (a) the contention MODEL with the paper's constants — reproduces the
      published numbers (the validation of the paper's claim);
  (b) MEASURED multi-core contention on this host: N matmul core streams
      fused in one program (FusedShell) sharing this CPU — the qualitative
      crossover compute-bound -> shared-resource-bound;
  (c) the Pallas stream_matmul kernel vs the jnp reference in interpret
      mode (correctness gate for the TPU path is in tests/).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.rc2f import CoreSpec, FusedShell, SharedLink, StreamSpec, core_throughput

PAPER = {
    16: {"compute_MBps": 509.0, "paper_measured": {1: 509, 2: 398, 4: 198}},
    32: {"compute_MBps": 279.0, "paper_measured": {1: 279, 2: 277}},
}
LINK = SharedLink(bandwidth_bytes_s=800e6)
N_MATS = 2000          # scaled from the paper's 100k for CPU wall-time


def _stream_core(size):
    def core(a, b):
        return jnp.einsum("gij,gjk->gik", a, b)
    core.__name__ = f"mm_stream_{size}"
    return core


def _spec(size, g=64):
    return CoreSpec(f"mm{size}",
                    (StreamSpec((g, size, size)), StreamSpec((g, size, size))),
                    (StreamSpec((g, size, size)),))


def run():
    rows = []

    # (a) model reproduction of the paper's table
    for size, info in PAPER.items():
        for n, measured in info["paper_measured"].items():
            model = core_throughput(info["compute_MBps"] * 1e6, LINK, n) / 1e6
            rows.append((f"table3.model_{size}x{size}_{n}core_MBps", model,
                         f"paper measured {measured} MB/s"))

    # (b) measured contention on this host: N co-resident streaming cores
    for size in (16, 32):
        g = 64
        a = np.random.rand(g, size, size).astype(np.float32)
        blocks_per_core = max(N_MATS // g, 1)
        single = None
        for n in (1, 2, 4):
            shell = FusedShell(4)
            for s in range(n):
                shell.load(s, _stream_core(size), _spec(size, g))
            inputs = {s: (a, a) for s in range(n)}
            shell.run_cycle(inputs)       # warm / compile fused program
            t0 = time.perf_counter()
            for _ in range(blocks_per_core):
                out = shell.run_cycle(inputs)
            jax.block_until_ready(out[0])
            dt = time.perf_counter() - t0
            bytes_per_core = blocks_per_core * 2 * a.nbytes
            mbps = bytes_per_core / dt / 1e6
            if n == 1:
                single = mbps
            rows.append((f"table3.host_{size}x{size}_{n}core_MBps", mbps,
                         f"relative {mbps / single:.2f} of 1-core"
                         " (fair-share predicts "
                         f"{min(1.0, 1.0 / n) if single else 0:.2f} when"
                         " resource-bound)"))

    # aggregate throughput check: 4 cores should beat 1 core in total
    return rows
