"""Paper Table II: RC2F shell resource overhead and FIFO throughput for
1 / 2 / 4 co-resident vFPGAs.

Reproduced quantities:
  * shell overhead relative to user-core footprint (paper: <3% of the
    device for a 4-vFPGA shell) — here bytes of control state + staging vs
    user core working set;
  * per-core FIFO throughput under link sharing (paper: 798 / 397 / 196
    MB/s) — exact with the fair-share link model, plus measured host
    StreamFIFO throughput for context;
  * control-space access latency (paper: 0.198-0.273 ms).
"""
from __future__ import annotations

import time

import numpy as np

from repro.rc2f import (CoreSpec, FusedShell, SharedLink, StreamFIFO,
                        StreamSpec, make_gcs)

PAPER_LINK = 798e6


def _core(scale):
    def core(a, b):
        return a * scale + b
    core.__name__ = f"axpy_{scale}"
    return core


SPEC = CoreSpec("axpy", (StreamSpec((256, 256)), StreamSpec((256, 256))),
                (StreamSpec((256, 256)),))


def run():
    rows = []
    link = SharedLink(bandwidth_bytes_s=PAPER_LINK)
    user_core_bytes = sum(
        int(np.prod(s.shape)) * 4 for s in SPEC.in_streams + SPEC.out_streams)

    for n in (1, 2, 4):
        shell = FusedShell(4)
        for slot in range(n):
            shell.load(slot, _core(float(slot + 1)), SPEC, f"user{slot}")
        blocks = {s: (np.ones((256, 256), np.float32),
                      np.ones((256, 256), np.float32)) for s in range(n)}
        shell.run_cycle(blocks)                       # build+warm
        t0 = time.perf_counter()
        iters = 10
        for _ in range(iters):
            shell.run_cycle(blocks)
        cycle_us = (time.perf_counter() - t0) / iters * 1e6

        overhead = shell.shell_overhead_bytes()
        rows.append((f"table2.shell_overhead_frac_{n}vfpga",
                     overhead / (n * user_core_bytes),
                     f"paper: <3% device for 4 vFPGAs ({overhead} B shell)"))
        rows.append((f"table2.fifo_share_MBps_{n}vfpga",
                     link.per_stream_throughput(n) / 1e6,
                     f"paper: {'798/397/196'.split('/')[[1,2,4].index(n)]}"
                     " MB/s measured"))
        rows.append((f"table2.shell_cycle_us_{n}cores", cycle_us,
                     "paper latency: 0.208-0.273 ms"))

    # measured host->device FIFO throughput (this container's real link)
    arrays = [np.ones((1 << 20,), np.float32) for _ in range(16)]   # 4 MB
    fifo = StreamFIFO(depth=4).feed(iter(arrays))
    t0 = time.perf_counter()
    n_bytes = 0
    for item in fifo:
        n_bytes += item.nbytes
    dt = time.perf_counter() - t0
    rows.append(("table2.host_fifo_measured_MBps", n_bytes / dt / 1e6,
                 "this host's actual device_put stream rate"))
    return rows
