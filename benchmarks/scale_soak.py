"""Open-loop scale soak: replay synthesized traffic traces against the
serving fleet and persist per-cell records to ``BENCH_scale.json``.

Each cell of the matrix is one ``(trace spec, fleet spec, seed)`` triple
replayed by ``repro.runtime.loadgen.replay_trace``: Poisson-burst arrival
waves with diurnal modulation, lognormal request sizes and Zipf tenant
skew, driven open-loop against a ``GatewayFleet`` on the injected
``FakeClock``. A full (non ``--smoke``) run is the STANDING SOAK MATRIX:
chaos seeds × trace specs × fleet sizes, every cell with a seeded
mixed-fault schedule (device kill + transient partition) and an
invariant check (``verify_invariants`` — quota/journal conservation and
``PagePoolManager.verify``) before its record is accepted.

Records contain no wall-clock values — goodput is tokens per fleet
*round* and latency is measured in rounds — so the file is a pure
function of the matrix and is diffable across hosts. That is what makes
the committed baseline (``benchmarks/BENCH_scale_baseline.json``) a
usable CI regression gate: ``--check`` fails when any cell's goodput
drops more than 10% below the baseline's.

Run:
  PYTHONPATH=src python benchmarks/scale_soak.py --smoke \
      --out BENCH_scale.json --check benchmarks/BENCH_scale_baseline.json
  PYTHONPATH=src python benchmarks/scale_soak.py --seeds 0,1,2   # full soak
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "BENCH_scale_baseline.json")
GOODPUT_DROP_TOLERANCE = 0.10


def _setup():
    import jax
    from repro.configs import get_config, reduced
    from repro.models import get_model
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _cell_key(rec: dict) -> str:
    c = rec["cell"]
    chaos = "+chaos" if c.get("chaos") else ""
    return f"{c['trace']}|{c['fleet']}|{c['seed']}{chaos}"


def run_matrix(smoke: bool, seeds, chaos: bool, progress=None):
    """Replay the matrix; returns its records (no wall-clock inside)."""
    from repro.runtime.loadgen import (SoakMatrix, preset_fleets,
                                       preset_traces, smoke_cell)
    _, model, params = _setup()
    if smoke:
        trace, fleet, seed = smoke_cell()
        matrix = SoakMatrix([trace], [fleet], [seed], chaos=False)
    else:
        matrix = SoakMatrix(preset_traces(), preset_fleets(), list(seeds),
                            chaos=chaos)
    from repro.core.reconfig import ProgramCache, Reconfigurator
    reconfig = Reconfigurator(ProgramCache())   # shared PR cache: cells
    return matrix.run(model, params, reconfig=reconfig,  # after the first
                      progress=progress)                 # hit, not miss


def check_regression(records, baseline_path: str,
                     tolerance: float = GOODPUT_DROP_TOLERANCE):
    """Compare per-cell goodput against a committed baseline. Returns the
    list of failure strings (empty == pass). Cells absent from the
    baseline are skipped — adding matrix cells must not fail CI."""
    with open(baseline_path) as f:
        base = {_cell_key(r): r["metrics"]["goodput_tokens_per_round"]
                for r in json.load(f)["records"]}
    failures = []
    for rec in records:
        key = _cell_key(rec)
        if key not in base:
            continue
        got = rec["metrics"]["goodput_tokens_per_round"]
        floor = (1.0 - tolerance) * base[key]
        if got < floor:
            failures.append(
                f"{key}: goodput {got:.4f} < {floor:.4f} "
                f"(baseline {base[key]:.4f}, tolerance {tolerance:.0%})")
    return failures


def write_records(records, path: str):
    with open(path, "w") as f:
        json.dump({"records": records}, f, indent=1, sort_keys=True)
        f.write("\n")


def run():
    """benchmarks/run.py protocol: replay the pinned smoke cell, emit
    (name, value, derived) rows."""
    records = run_matrix(smoke=True, seeds=[0], chaos=False)
    m = records[0]["metrics"]
    lat = m["latency_rounds"]
    return [
        ("scale_soak.smoke.goodput_tok_per_round",
         m["goodput_tokens_per_round"],
         f"completed={m['completed']}/{m['arrivals']}"),
        ("scale_soak.smoke.p95_latency_rounds", float(lat["p95"]),
         f"p50={lat['p50']};p99={lat['p99']}"),
        ("scale_soak.smoke.energy_device_steps",
         m["energy_device_steps"],
         f"peak_devices={m['peak_active_devices']}"),
    ]


def main() -> int:
    sys.path.insert(0, _ROOT)
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="replay only the pinned smoke cell (CI)")
    ap.add_argument("--seeds", default="0,1,2",
                    help="comma-separated chaos seeds for the full matrix")
    ap.add_argument("--no-chaos", action="store_true",
                    help="disable the per-cell fault schedule")
    ap.add_argument("--out", default="BENCH_scale.json",
                    help="where to write the records")
    ap.add_argument("--check", nargs="?", const=BASELINE, default=None,
                    metavar="BASELINE",
                    help="fail if any cell's goodput drops >10%% below "
                         "this baseline (default: the committed one)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="replay the smoke cell AND the full matrix and "
                         "write both to the committed baseline path")
    args = ap.parse_args()

    seeds = [int(s) for s in args.seeds.split(",") if s.strip() != ""]
    t0 = time.perf_counter()

    def progress(rec):
        m = rec["metrics"]
        print(f"  {_cell_key(rec):32s} goodput="
              f"{m['goodput_tokens_per_round']:.3f} "
              f"p95={m['latency_rounds']['p95']} "
              f"completed={m['completed']}/{m['arrivals']} "
              f"evict={m['evictions']} energy={m['energy_device_steps']}",
              flush=True)

    if args.write_baseline:
        records = (run_matrix(smoke=True, seeds=seeds, chaos=False,
                              progress=progress)
                   + run_matrix(smoke=False, seeds=seeds,
                                chaos=not args.no_chaos,
                                progress=progress))
        write_records(records, BASELINE)
        print(f"baseline ({len(records)} cells) -> {BASELINE}")
        return 0
    records = run_matrix(smoke=args.smoke, seeds=seeds,
                         chaos=not args.no_chaos, progress=progress)
    write_records(records, args.out)
    print(f"{len(records)} cell(s) -> {args.out} "
          f"({time.perf_counter() - t0:.1f}s host wall)")

    if args.check:
        failures = check_regression(records, args.check)
        if failures:
            print("GOODPUT REGRESSION:", file=sys.stderr)
            for line in failures:
                print("  " + line, file=sys.stderr)
            return 1
        print(f"regression check vs {args.check}: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
