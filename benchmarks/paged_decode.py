"""Paged KV-cache pool vs dense per-slot caches: serving density at equal
HBM, decode step latency at equal occupancy, and page occupancy surfaced
through the hypervisor monitor.

Three measurements:

1. **Density at fixed cache memory** — a dense engine pins
   ``n_slots x max_len`` KV positions whether a request needs them or not,
   so its concurrency IS its slot count. A paged engine holding the same
   number of cache positions (same HBM) admits slots against *actual*
   usage: short sessions take 1-2 pages instead of a max_len row, so the
   same memory serves >= 2x the concurrent sessions.
2. **Step latency at equal occupancy** — same model, same number of active
   slots, same context lengths; the paged engine adds block-table
   indirection (gather on CPU / the scalar-prefetch Pallas kernel on TPU).
   Reported as paged/dense mean per-step ratio (target: within 10%).
3. **Occupancy telemetry** — the paged gateway pushes pool occupancy into
   ``Monitor.status()["pages"]`` every step (the RC2F gcs-status analogue
   for the memory fabric).

Run:  PYTHONPATH=src python benchmarks/paged_decode.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

PAGE_SIZE = 16
N_SLOTS_DENSE = 4
MAX_LEN = 128


def _setup():
    from repro.configs import get_config, reduced
    from repro.models import get_model
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, n, length, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=length).tolist()
            for _ in range(n)]


def _drive(engine, reqs):
    """Run to idle; returns (peak concurrent slots, rounds)."""
    peak = rounds = 0
    while True:
        n = engine.step()
        if n == 0 and engine.idle():
            return peak, rounds
        peak = max(peak, n)
        rounds += 1
        assert rounds < 10000, "engine stalled"


def density_at_equal_hbm(model, params, cfg, smoke):
    """Same cache positions in HBM; how many sessions decode at once?"""
    from repro.runtime import BatchingEngine
    max_new = 8 if smoke else 24
    n_sessions = 2 * N_SLOTS_DENSE
    prompt_len = 12
    positions = N_SLOTS_DENSE * MAX_LEN            # dense engine's footprint
    pool_pages = positions // PAGE_SIZE            # same footprint, paged

    dense = BatchingEngine(model, params, n_slots=N_SLOTS_DENSE,
                           max_len=MAX_LEN)
    paged = BatchingEngine(model, params, n_slots=n_sessions,
                           max_len=MAX_LEN, paged=True, page_size=PAGE_SIZE,
                           cache_pages=pool_pages + 1)   # +1: reserved null

    results = {}
    for name, eng in (("dense", dense), ("paged", paged)):
        reqs = [eng.submit(p, max_new_tokens=max_new)
                for p in _prompts(cfg, n_sessions, prompt_len)]
        peak, rounds = _drive(eng, reqs)
        assert all(len(r.out_tokens) == max_new for r in reqs)
        results[name] = (peak, rounds)
        extra = f", {eng.page_stats()}" if eng.paged else ""
        print(f"  {name:5s}: {positions} cache positions, peak "
              f"{peak} concurrent sessions, {rounds} rounds "
              f"for {n_sessions} x {max_new} tokens{extra}")
    ratio = results["paged"][0] / results["dense"][0]
    print(f"  => {ratio:.1f}x concurrent sessions at equal HBM "
          f"({results['dense'][1] / results['paged'][1]:.2f}x fewer rounds)")
    assert results["paged"][0] >= 2 * results["dense"][0], \
        "paged engine must double concurrency at equal cache memory"
    return ratio


def step_latency_at_equal_occupancy(model, params, cfg, smoke):
    """Mean decode-step wall with the SAME active slot count + contexts."""
    from repro.runtime import BatchingEngine
    measure = 12 if smoke else 48
    warmup = 4
    prompt_len = 24
    max_new = warmup + measure + 8

    def mean_step_ms(paged):
        kw = dict(paged=True, page_size=PAGE_SIZE) if paged else {}
        eng = BatchingEngine(model, params, n_slots=N_SLOTS_DENSE,
                             max_len=MAX_LEN, **kw)
        for p in _prompts(cfg, N_SLOTS_DENSE, prompt_len, seed=1):
            eng.submit(p, max_new_tokens=max_new)
        while sum(r is not None for r in eng._slots) < N_SLOTS_DENSE:
            eng.step()
        for _ in range(warmup):
            eng.step()
        times = []
        for _ in range(measure):
            t0 = time.perf_counter()
            n = eng.step()
            times.append((time.perf_counter() - t0) * 1e3)
            assert n == N_SLOTS_DENSE        # equal occupancy throughout
        eng.run_until_idle()
        return float(np.median(times))

    dense_ms = mean_step_ms(False)
    paged_ms = mean_step_ms(True)
    ratio = paged_ms / dense_ms
    print(f"  dense {dense_ms:.2f} ms/step, paged {paged_ms:.2f} ms/step "
          f"at {N_SLOTS_DENSE} active slots -> ratio {ratio:.3f} "
          f"(target <= 1.10)")
    return ratio


def monitor_occupancy(model, params, cfg):
    """Pool occupancy must be visible in Monitor.status()."""
    from repro.core import ClusterSpec, Hypervisor
    from repro.runtime import ServingGateway
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1,
                                cache_pages_per_device=256))
    gw = ServingGateway(hv, model, params, n_slots=4, max_len=MAX_LEN,
                        paged=True, page_size=PAGE_SIZE)
    gw.open_session("tenant-a", slots=2)
    gw.open_session("tenant-b", slots=2)
    for t in ("tenant-a", "tenant-b"):
        for p in _prompts(cfg, 2, 20, seed=hash(t) % 100):
            gw.submit(t, p, max_new_tokens=6)
    for _ in range(3):
        gw.step()
    status = hv.status()
    assert status["pages"], "page occupancy missing from Monitor.status()"
    print(f"  Monitor.status() pages: {status['pages']}")
    print(f"  vSlice page grants:     {status['page_grants']}")
    assert gw.run_until_idle()
    gw.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload for CI")
    args = ap.parse_args()
    cfg, model, params = _setup()

    print("== serving density at equal cache HBM ==")
    density = density_at_equal_hbm(model, params, cfg, args.smoke)

    print("== decode step latency at equal occupancy ==")
    ratio = step_latency_at_equal_occupancy(model, params, cfg, args.smoke)

    print("== page occupancy in Monitor.status() ==")
    monitor_occupancy(model, params, cfg)

    print(f"\nsummary: {density:.1f}x sessions at equal HBM; "
          f"paged/dense step ratio {ratio:.3f}; occupancy exported")
    if not args.smoke and ratio > 1.10:
        print("WARNING: paged step latency exceeded the 10% envelope on "
              "this host (CPU gathers; the TPU kernel path sweeps the "
              "pool in place)")


if __name__ == "__main__":
    main()
