"""Benchmark harness: one module per paper table (+ roofline summary).
Prints ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    sys.path.insert(0, _ROOT)
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    from benchmarks import (async_overlap, fleet_scaleout, kernel_tuner,
                            roofline, scale_soak, scrub_overhead,
                            table1_overhead, table2_shell, table3_matmul,
                            table4_multitenant)

    modules = [
        ("table1", table1_overhead),
        ("table2", table2_shell),
        ("table3", table3_matmul),
        ("table4", table4_multitenant),
        ("fleet", fleet_scaleout),
        ("scale_soak", scale_soak),
        ("async_overlap", async_overlap),
        ("kernel_tuner", kernel_tuner),
        ("scrub_overhead", scrub_overhead),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            for row_name, value, derived in mod.run():
                print(f"{row_name},{value:.4f},{str(derived).replace(',', ';')}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}.FAILED,0,{type(e).__name__}: "
                  f"{str(e)[:120].replace(chr(10), ' ')}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
