"""Benchmark harness: one module per paper table (+ roofline summary).
Prints ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import sys


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks import roofline, table1_overhead, table2_shell, table3_matmul

    modules = [
        ("table1", table1_overhead),
        ("table2", table2_shell),
        ("table3", table3_matmul),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            for row_name, value, derived in mod.run():
                print(f"{row_name},{value:.4f},{str(derived).replace(',', ';')}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}.FAILED,0,{type(e).__name__}: "
                  f"{str(e)[:120].replace(chr(10), ' ')}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
