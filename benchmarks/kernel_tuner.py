"""Design-space tuner benchmark: default vs tuned geometry per device
class, persisted to ``BENCH_tuner.json``.

Each modeled cell is one ``(arch, paged, device class)`` triple swept by
``repro.tuning.tune``: every legal (kernel blocks x page size x slots x
prefill chunk) candidate is scored by the roofline-backed cost model and
the winner is compared against the hand-picked default geometry. The
scores are pure math — no jax, no wall clock — so the file is a function
of the design space and diffs cleanly across hosts; that is what makes
the committed baseline (``benchmarks/BENCH_tuner_baseline.json``) a CI
regression gate: ``--check`` fails when a cell's win ratio drops more
than 10% below the baseline's, when a cell the baseline tuned a win for
stops winning, or when any parity cell's token streams diverge.

Parity cells prove the wins are free: a reduced model is served twice
through a real two-class ``GatewayFleet`` (speeds 1.0 / 0.25) — once on
the default geometry, once with ``autotune=True`` binding each engine
its class's tuned winner — and the per-tenant greedy token logs must
match bit-for-bit (geometry changes WHERE bytes move, never WHAT is
computed).

Run:
  PYTHONPATH=src python benchmarks/kernel_tuner.py --smoke \
      --out BENCH_tuner.json --check benchmarks/BENCH_tuner_baseline.json
  PYTHONPATH=src python benchmarks/kernel_tuner.py   # full matrix
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "BENCH_tuner_baseline.json")
WIN_DROP_TOLERANCE = 0.10

ARCHS = ("smollm-135m", "gemma3-1b")
SPEEDS = (1.0, 0.25)
MAX_LEN = 2048                     # modeled serving length


# ---------------------------------------------------------------------------
# Modeled cells (pure math — every cell, even under --smoke)
# ---------------------------------------------------------------------------

def modeled_cells():
    from repro.configs import get_config
    from repro.tuning import device_class, profile_for_speed, tune
    records = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for paged in (False, True):
            for speed in SPEEDS:
                rep = tune(cfg, profile_for_speed(speed),
                           max_len=MAX_LEN, paged=paged)
                records.append({
                    "kind": "modeled",
                    "cell": {"arch": arch, "paged": paged,
                             "device_class": device_class(speed)},
                    "metrics": {
                        "default_us_per_token":
                            round(rep.default_cost.us_per_token, 4),
                        "tuned_us_per_token":
                            round(rep.best_cost.us_per_token, 4),
                        "win": round(rep.win, 4),
                        "geometry": rep.best.geometry_key(),
                        "n_candidates": rep.n_candidates,
                        "n_pruned": rep.n_pruned,
                    }})
    return records


# ---------------------------------------------------------------------------
# Parity cells (real fleet, reduced model: tuned tokens == default tokens)
# ---------------------------------------------------------------------------

def _serve_tokens(model, params, cfg, paged: bool, autotune: bool):
    import numpy as np
    from repro.core import ClusterSpec, Hypervisor
    from repro.runtime import GatewayFleet
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2,
                                device_speeds=SPEEDS))
    fleet = GatewayFleet(hv, model, params, n_slots=4, max_len=64,
                         paged=paged, page_size=8, autotune=autotune)
    rng = np.random.default_rng(0)
    reqs = {}
    try:
        # three 2-slot sessions overflow the first device: the third
        # lands on the second (slow-class) device, so both classes serve
        for t in ("a", "b", "c"):
            fleet.open_session(t, slots=2)
            prompt = rng.integers(0, cfg.vocab_size, size=6).tolist()
            reqs[t] = fleet.submit(t, prompt, max_new_tokens=8)
        assert fleet.run_until_idle()
        fleet.verify_invariants()
        return {t: list(r.out_tokens) for t, r in reqs.items()}
    finally:
        fleet.close()


def parity_cells(smoke: bool, progress=None):
    import jax
    from repro.configs import get_config, reduced
    from repro.models import get_model
    records = []
    for arch in ARCHS[:1] if smoke else ARCHS:
        cfg = reduced(get_config(arch)).replace(dtype="float32")
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        for paged in (False, True):
            base = _serve_tokens(model, params, cfg, paged, autotune=False)
            tuned = _serve_tokens(model, params, cfg, paged, autotune=True)
            rec = {"kind": "parity",
                   "cell": {"arch": arch, "paged": paged},
                   "metrics": {"tokens_match": base == tuned,
                               "tenants": len(base)}}
            records.append(rec)
            if progress:
                progress(rec)
    return records


# ---------------------------------------------------------------------------
# Regression gate
# ---------------------------------------------------------------------------

def _key(rec: dict) -> str:
    c = rec["cell"]
    paged = "paged" if c["paged"] else "dense"
    return f"{rec['kind']}|{c['arch']}|{paged}|{c.get('device_class', '-')}"


def check_regression(records, baseline_path: str,
                     tolerance: float = WIN_DROP_TOLERANCE):
    """Returns failure strings (empty == pass). Cells absent from the
    baseline are skipped — adding matrix cells must not fail CI."""
    with open(baseline_path) as f:
        base = {_key(r): r for r in json.load(f)["records"]}
    failures = []
    for rec in records:
        b = base.get(_key(rec))
        if b is None:
            continue
        if rec["kind"] == "parity":
            if not rec["metrics"]["tokens_match"]:
                failures.append(f"{_key(rec)}: tuned token stream diverged "
                                "from default (bit-exactness broken)")
            continue
        got, want = rec["metrics"]["win"], b["metrics"]["win"]
        if want > 1.0 and got <= 1.0:
            failures.append(f"{_key(rec)}: tuner no longer beats the "
                            f"default (win {got:.4f}, baseline {want:.4f})")
        elif got < (1.0 - tolerance) * want:
            failures.append(f"{_key(rec)}: win {got:.4f} < "
                            f"{(1.0 - tolerance) * want:.4f} "
                            f"(baseline {want:.4f}, tol {tolerance:.0%})")
    return failures


def write_records(records, path: str):
    with open(path, "w") as f:
        json.dump({"records": records}, f, indent=1, sort_keys=True)
        f.write("\n")


def run():
    """benchmarks/run.py protocol: modeled cells only (fast, pure math);
    emits one (name, win, derived) row per cell."""
    rows = []
    for rec in modeled_cells():
        c, m = rec["cell"], rec["metrics"]
        mode = "paged" if c["paged"] else "dense"
        rows.append((
            f"tuner.{c['arch']}.{mode}.{c['device_class']}.win",
            m["win"],
            f"tuned={m['tuned_us_per_token']}us;"
            f"default={m['default_us_per_token']}us;geom={m['geometry']}"))
    return rows


def main() -> int:
    sys.path.insert(0, _ROOT)
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="parity-serve only the first arch (CI); modeled "
                         "cells always run in full (pure math)")
    ap.add_argument("--out", default="BENCH_tuner.json",
                    help="where to write the records")
    ap.add_argument("--check", nargs="?", const=BASELINE, default=None,
                    metavar="BASELINE",
                    help="fail when a cell's win drops >10%% below this "
                         "baseline or parity breaks (default: committed)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="run the full matrix and write the committed "
                         "baseline path")
    args = ap.parse_args()
    t0 = time.perf_counter()

    def progress(rec):
        c, m = rec["cell"], rec["metrics"]
        if rec["kind"] == "modeled":
            print(f"  {_key(rec):44s} win={m['win']:.4f} "
                  f"geom={m['geometry']}", flush=True)
        else:
            print(f"  {_key(rec):44s} tokens_match={m['tokens_match']}",
                  flush=True)

    records = modeled_cells()
    for rec in records:
        progress(rec)
    records += parity_cells(smoke=args.smoke and not args.write_baseline,
                            progress=progress)
    out = BASELINE if args.write_baseline else args.out
    write_records(records, out)
    print(f"{len(records)} cell(s) -> {out} "
          f"({time.perf_counter() - t0:.1f}s host wall)")

    if args.check and not args.write_baseline:
        failures = check_regression(records, args.check)
        if failures:
            print("TUNER REGRESSION:", file=sys.stderr)
            for line in failures:
                print("  " + line, file=sys.stderr)
            return 1
        print(f"regression check vs {args.check}: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
