"""Paper Table I: latency of status calls, full configuration and partial
reconfiguration, with and without the RC3E middleware.

FPGA -> TPU mapping: full configuration = cold jit lower+compile of a user
core; PR = hot swap from the program cache. The paper's absolute numbers
(JTAG/USB bitstream loads) are hardware-bound; what must reproduce is the
ORDERING and the small middleware overhead: status ≪ PR ≪ full config, and
RC3E adds only bookkeeping overhead to each.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import ClusterSpec, Hypervisor
from repro.rc2f import CoreSpec, StreamSpec


def _timeit(fn, n=20, warmup=2):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6      # us


def run():
    hv = Hypervisor(ClusterSpec(n_nodes=2, devices_per_node=2))
    vs = hv.allocate_vslice("bench", 1)

    # --- status: local (monitor object) vs over RC3E middleware ---
    t_status_local = _timeit(lambda: hv.monitor.db.utilization())
    t_status_rc3e = _timeit(lambda: hv.status())

    # --- configuration: cold compile (unique core each time) ---
    def fresh_core(scale):
        def core(a, b):
            return (a @ b * scale,)
        core.__name__ = f"core_{scale}"
        return core

    ex = (jnp.ones((64, 64), jnp.float32), jnp.ones((64, 64), jnp.float32))
    cold_times = []
    for i in range(5):
        t0 = time.perf_counter()
        hv.program_slice(vs.slice_id, fresh_core(float(i + 2)), ex,
                         static_desc=f"cold{i}")
        cold_times.append((time.perf_counter() - t0) * 1e6)
    t_config = float(np.mean(cold_times))

    # --- partial reconfiguration: swap back to a cached core ---
    stable = fresh_core(1.0)
    hv.program_slice(vs.slice_id, stable, ex, static_desc="stable")
    t_pr = _timeit(lambda: hv.program_slice(vs.slice_id, stable, ex,
                                            static_desc="stable"), n=20)

    # direct (no middleware) variants
    t_pr_direct = _timeit(
        lambda: hv.reconfig.partial_reconfigure(stable, ex,
                                                static_desc="stable"), n=20)

    rows = [
        ("table1.status_local_us", t_status_local,
         "paper: 11 ms local"),
        ("table1.status_rc3e_us", t_status_rc3e,
         "paper: 80 ms over RC3E"),
        ("table1.full_configuration_us", t_config,
         "paper: ~29 s bitstream; here cold XLA compile"),
        ("table1.partial_reconfig_direct_us", t_pr_direct,
         "paper: 732 ms local PR"),
        ("table1.partial_reconfig_rc3e_us", t_pr,
         "paper: 912 ms PR over RC3E"),
        ("table1.pr_speedup_vs_full", t_config / max(t_pr, 1e-9),
         "paper: ~32x (29.5s/0.91s)"),
    ]
    assert t_pr < t_config, "PR must be faster than full configuration"
    return rows
