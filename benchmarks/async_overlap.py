"""Async overlap benchmark: the lockstep round barrier vs the
event-driven dataplane, on a fleet with one slow device class.

The lockstep loop (``GatewayFleet.step``) is a fleet-wide barrier: every
round waits for its slowest member, so a single speed-0.25 device makes
EVERY engine pay ``tick_s / 0.25`` event-seconds per round. The event
loop (``repro.runtime.events.EventLoop``) steps each engine every
``tick_s / device.speed`` — the slow device simply fires less often
while the rest of the fleet decodes at full cadence, with prefill
chunked and journal syncs batched off the critical path.

Fairness: both loops face the IDENTICAL open-loop workload in event
time. Trace steps are event-seconds; the event loop schedules each
arrival as a queue event at its step, the lockstep loop delivers the
arrivals whose steps fall inside each round's ``tick_s / min(speed)``
window. Completion times are read off the same clock (the queue's
FakeClock / the round boundary), so goodput (tokens per event-second of
makespan) and arrival->completion latency percentiles compare like for
like. Everything derives from deterministic round counts — no host
wall-clock — so ``BENCH_async.json`` is bit-stable across machines.

``--check`` enforces the acceptance gates on the mixed-speed cell:
event goodput >= 1.3x lockstep, a strictly lower event p95, the slow
device actually carried traffic (else the barrier comparison is
vacuous), and a direct cadence probe showing per-device step counts
proportional to speed — the slow device no longer gates the fleet, it
just steps less.

Run:
  PYTHONPATH=src python benchmarks/async_overlap.py --smoke --check
  PYTHONPATH=src python benchmarks/async_overlap.py   # mixed + uniform
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TICK_S = 1.0
SPEEDS = (1.0, 1.0, 1.0, 0.25)        # one slow device class, coldest slot
GOODPUT_GAIN_FLOOR = 1.3              # event must beat lockstep by >=30%
CADENCE_TOLERANCE = 0.2               # |steps/ticks - speed| per device
DRAIN_SLACK_S = 4096.0                # post-horizon drain bound (ev-s)


def _setup():
    import jax
    from repro.configs import get_config, reduced
    from repro.models import get_model
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def overlap_trace(smoke: bool):
    """Four single-slot tenants — one per device, so every device class
    carries live traffic. Zipf skew puts the hottest tenant on the
    fastest device and the coldest on the slow one (what any sane
    placement would do); the lockstep barrier still charges everyone the
    slow member's step time."""
    from repro.runtime.loadgen import TraceSpec
    return TraceSpec(name="overlap", horizon=16 if smoke else 32,
                     base_rate=1.2, burst_rate_mult=1.0, tenants=4,
                     zipf_s=1.1)


def overlap_fleet(speeds):
    from repro.runtime.loadgen import FleetSpec
    name = "mixed4" if len(set(speeds)) > 1 else "uniform4"
    return FleetSpec(name=name, n_nodes=4, devices_per_node=1,
                     n_slots=4, slo_p95_steps=None,
                     device_speeds=tuple(speeds))


def _speed_of(fleet_spec, dev: str) -> float:
    """Device id -> class speed (ClusterSpec cycles ``device_speeds``
    over the global device index; one device per node makes the node
    index THE device index)."""
    speeds = fleet_spec.device_speeds
    if not speeds:
        return 1.0
    node, k = int(dev.split("-")[1]), int(dev.split("-")[2])
    return speeds[(node * fleet_spec.devices_per_node + k) % len(speeds)]


def _percentiles(lat):
    from repro.runtime.loadgen import percentile
    return {"p50_s": percentile(lat, 50), "p95_s": percentile(lat, 95),
            "p99_s": percentile(lat, 99)}


def replay(loop: str, trace, fleet_spec, seed: int, model, params,
           reconfig=None) -> dict:
    """Drive one fleet through the trace under ``loop``, measuring in
    event time. Returns makespan, goodput, latency percentiles and
    per-device step counts."""
    from repro.rc2f import AdmissionError
    from repro.runtime.events import EventLoop
    from repro.runtime.loadgen import build_fleet, seeded_rng, synthesize
    from repro.runtime.loadgen import _mix
    fleet, _ = build_fleet(fleet_spec, model, params, seed,
                           reconfig=reconfig)
    # full-device RSaaS sessions: placement packs by device slot
    # capacity, so 4-slot sessions land one tenant per device — the
    # coldest tenant on the slow device (open order follows Zipf rank)
    for t in trace.tenant_ids():
        fleet.open_session(t, slots=4, service_model="rsaas")
    arrivals = synthesize(trace, seed)
    vocab = model.cfg.vocab_size
    prompt_rng = seeded_rng(_mix(seed, "prompts/" + trace.name))

    outstanding = []                   # (req, arrival ev-time)
    latencies = []
    rejected = completed = tokens_out = 0

    def submit(a):
        nonlocal rejected
        prompt = [prompt_rng.randrange(vocab) for _ in range(a.prompt_len)]
        try:
            req = fleet.submit(a.tenant, prompt, a.max_new_tokens)
        except (AdmissionError, ValueError, KeyError):
            rejected += 1
            return
        outstanding.append((req, a.step * TICK_S))

    speeds = fleet_spec.device_speeds or (1.0,) * fleet_spec.n_devices()
    barrier_s = TICK_S / min(speeds)   # lockstep: slowest member's step
    evloop = None
    if loop == "event":
        evloop = EventLoop(fleet, tick_s=TICK_S)
        for a in arrivals:
            evloop.queue.at(a.step * TICK_S, lambda a=a: submit(a),
                            kind="arrival")
    pending = sorted(arrivals, key=lambda a: a.step)
    steps_by_dev = {}
    engine_ids = {}
    now = 0.0
    makespan = None
    horizon_s = trace.horizon * TICK_S
    while (now < horizon_s or outstanding) \
            and now < horizon_s + DRAIN_SLACK_S:
        if evloop is None:
            # deliver every arrival inside this round's barrier window
            while pending and pending[0].step * TICK_S < now + barrier_s:
                submit(pending.pop(0))
            fleet.step()
            now += barrier_s
        else:
            evloop.run_ticks(1)
            now = evloop.queue.clock()
        for dev, eng in fleet._engines.items():
            engine_ids[id(eng)] = (dev, eng)
        still = []
        for req, t0 in outstanding:
            if not req.done.is_set():
                still.append((req, t0))
            elif req.finish_reason != "cancelled":
                completed += 1
                tokens_out += len(req.out_tokens)
                latencies.append(now - t0)
        outstanding = still
        if not outstanding and not pending and makespan is None \
                and now >= horizon_s:
            makespan = now
    if evloop is not None:
        fleet.flush_journal()
    fleet.verify_invariants()
    for dev, eng in engine_ids.values():
        steps_by_dev[dev] = steps_by_dev.get(dev, 0) + eng.steps
    span = makespan if makespan is not None else now
    rec = {
        "loop": loop,
        "arrivals": len(arrivals),
        "rejected": rejected,
        "completed": completed,
        "incomplete": len(outstanding),
        "tokens_out": tokens_out,
        "makespan_s": round(span, 6),
        "goodput_tokens_per_s": round(tokens_out / max(1e-9, span), 6),
        "per_device_steps": {d: steps_by_dev[d]
                             for d in sorted(steps_by_dev)},
        "slow_device_active": any(
            _speed_of(fleet_spec, d) < 1.0 and n > 0
            for d, n in steps_by_dev.items()),
    }
    rec.update(_percentiles(latencies))
    fleet.close()
    return rec


def run_cell(trace, fleet_spec, seed, model, params, reconfig=None) -> dict:
    lk = replay("lockstep", trace, fleet_spec, seed, model, params,
                reconfig=reconfig)
    ev = replay("event", trace, fleet_spec, seed, model, params,
                reconfig=reconfig)
    gain = (ev["goodput_tokens_per_s"]
            / max(1e-9, lk["goodput_tokens_per_s"]))
    return {
        "cell": {"trace": trace.name, "fleet": fleet_spec.name,
                 "seed": int(seed)},
        "device_speeds": list(fleet_spec.device_speeds
                              or (1.0,) * fleet_spec.n_devices()),
        "lockstep": lk,
        "event": ev,
        "goodput_gain": round(gain, 6),
    }


def cadence_probe(model, params, ticks: int = 24) -> dict:
    """Direct evidence that the slow device no longer gates: four
    always-busy single-tenant engines (one per device, mixed speeds),
    driven ``ticks`` control windows by the event loop. Each engine's
    step count must be ~``speed x ticks`` — and the workload must still
    drain afterwards."""
    from repro.core import ClusterSpec, Hypervisor, MonitorConfig
    from repro.runtime.events import EventLoop
    from repro.runtime.fleet import GatewayFleet
    hv = Hypervisor(ClusterSpec(n_nodes=4, devices_per_node=1,
                                device_speeds=SPEEDS),
                    MonitorConfig(heartbeat_interval_s=1.0,
                                  heartbeat_deadline_s=2.5))
    fleet = GatewayFleet(hv, model, params, n_slots=4, max_len=64,
                         paged=True)
    reqs = []
    for ti in range(4):
        fleet.open_session(f"t{ti}", slots=4,
                           service_model="rsaas")
        reqs.append(fleet.submit(f"t{ti}", [7, 11, 13, 17],
                                 max_new_tokens=40))
    assert len(fleet._engines) == 4    # one busy engine per device class
    ev = EventLoop(fleet, tick_s=TICK_S)
    ev.run_ticks(ticks)
    steps = {dev: eng.steps for dev, eng in sorted(fleet._engines.items())}
    speeds = {dev: SPEEDS[int(dev.split("-")[1]) % len(SPEEDS)]
              for dev in steps}
    drained = ev.run_until_idle(max_ticks=2000) \
        and all(r.done.is_set() for r in reqs)
    fleet.close()
    return {"ticks": ticks, "per_device_steps": steps, "speeds": speeds,
            "drained": bool(drained)}


def run_cells(smoke: bool, seed: int = 0, progress=None):
    from repro.core.reconfig import ProgramCache, Reconfigurator
    _, model, params = _setup()
    reconfig = Reconfigurator(ProgramCache())
    trace = overlap_trace(smoke)
    probe = cadence_probe(model, params)
    fleets = [overlap_fleet(SPEEDS)]
    if not smoke:
        fleets.append(overlap_fleet((1.0,) * 4))
    records = []
    for fspec in fleets:
        rec = run_cell(trace, fspec, seed, model, params,
                       reconfig=reconfig)
        rec["cadence_probe"] = probe
        records.append(rec)
        if progress is not None:
            progress(rec)
    return records


def check_gates(records) -> list:
    """The acceptance gates (mixed-speed cells; the uniform cell is
    report-only). Returns failure strings — empty means pass."""
    failures = []
    for rec in records:
        key = f"{rec['cell']['trace']}|{rec['cell']['fleet']}"
        lk, ev = rec["lockstep"], rec["event"]
        for side in (lk, ev):
            if side["completed"] != side["arrivals"] - side["rejected"] \
                    or side["incomplete"]:
                failures.append(
                    f"{key}: {side['loop']} completed {side['completed']}"
                    f"/{side['arrivals']} ({side['incomplete']} "
                    "incomplete)")
        probe = rec.get("cadence_probe")
        if probe is not None:
            if not probe["drained"]:
                failures.append(f"{key}: cadence probe did not drain")
            for dev, n in probe["per_device_steps"].items():
                speed = probe["speeds"][dev]
                got = n / max(1, probe["ticks"])
                if abs(got - speed) > CADENCE_TOLERANCE:
                    failures.append(
                        f"{key}: probe {dev} stepped {got:.2f}/tick, "
                        f"expected ~{speed:.2f} (speed-proportional "
                        "cadence)")
        if len(set(rec["device_speeds"])) == 1:
            continue
        for side in (lk, ev):
            if not side["slow_device_active"]:
                failures.append(
                    f"{key}: slow device hosted no engine under "
                    f"{side['loop']} — the barrier comparison is vacuous")
        if rec["goodput_gain"] < GOODPUT_GAIN_FLOOR:
            failures.append(
                f"{key}: goodput gain {rec['goodput_gain']:.3f} < "
                f"{GOODPUT_GAIN_FLOOR}")
        if not (ev["p95_s"] is not None and lk["p95_s"] is not None
                and ev["p95_s"] < lk["p95_s"]):
            failures.append(
                f"{key}: event p95 {ev['p95_s']} not below lockstep "
                f"p95 {lk['p95_s']}")
    return failures


def run():
    """benchmarks/run.py protocol: the smoke cell, as (name, value,
    derived) rows."""
    records = run_cells(smoke=True)
    rec = records[0]
    lk, ev = rec["lockstep"], rec["event"]
    return [
        ("async_overlap.mixed4.goodput_gain", rec["goodput_gain"],
         f"event={ev['goodput_tokens_per_s']};"
         f"lockstep={lk['goodput_tokens_per_s']}"),
        ("async_overlap.mixed4.event_p95_s", float(ev["p95_s"]),
         f"lockstep_p95_s={lk['p95_s']}"),
        ("async_overlap.mixed4.event_makespan_s", ev["makespan_s"],
         f"lockstep_makespan_s={lk['makespan_s']}"),
    ]


def main() -> int:
    sys.path.insert(0, _ROOT)
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="short trace, mixed-speed cell only (CI)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_async.json")
    ap.add_argument("--check", action="store_true",
                    help="fail unless event goodput >= 1.3x lockstep, "
                         "event p95 is lower, and cadence is "
                         "speed-proportional")
    args = ap.parse_args()

    t0 = time.perf_counter()

    def progress(rec):
        lk, ev = rec["lockstep"], rec["event"]
        print(f"  {rec['cell']['fleet']:10s} gain="
              f"{rec['goodput_gain']:.2f}x "
              f"p95 {lk['p95_s']} -> {ev['p95_s']} ev-s "
              f"makespan {lk['makespan_s']} -> {ev['makespan_s']} "
              f"steps={ev['per_device_steps']}", flush=True)

    records = run_cells(smoke=args.smoke, seed=args.seed,
                        progress=progress)
    with open(args.out, "w") as f:
        json.dump({"records": records}, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"{len(records)} cell(s) -> {args.out} "
          f"({time.perf_counter() - t0:.1f}s host wall)")
    if args.check:
        failures = check_gates(records)
        if failures:
            print("ASYNC OVERLAP GATE FAILED:", file=sys.stderr)
            for line in failures:
                print("  " + line, file=sys.stderr)
            return 1
        print("overlap gates: OK (goodput >= "
              f"{GOODPUT_GAIN_FLOOR}x, lower p95, speed-proportional "
              "cadence)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
