"""End-to-end scale-harness tests: ``replay_trace`` determinism under the
lifecycle sanitizer, the standing soak matrix (chaos seeds × trace specs ×
fleet sizes) running green with invariant checks, and the benchmark's
goodput regression gate against the committed baseline.

Latency/goodput are measured in fleet rounds, never wall time, so record
equality is BIT equality — the same guarantee CI's scale-smoke job leans
on when it diffs against ``benchmarks/BENCH_scale_baseline.json``.
"""
import json
import os
import sys

import jax
import pytest

from repro.analysis import sanitizer
from repro.configs import get_config, reduced
from repro.models import get_model
from repro.runtime.loadgen import (FleetSpec, SoakMatrix, TraceSpec,
                                   preset_fleets, preset_traces,
                                   replay_trace, smoke_cell)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(autouse=True)
def _sanitized():
    """Every replay in this module runs with the lifecycle sanitizer ON
    (request/slot/page/device/journal state machines hard-fail on any
    illegal transition), reset per test."""
    sanitizer.reset()
    sanitizer.enable()
    yield
    sanitizer.disable()


@pytest.fixture(scope="module")
def served_model():
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# a deliberately small cell so the determinism test replays it twice fast
MINI_TRACE = TraceSpec(name="mini", horizon=20, base_rate=0.8,
                       burst_rate_mult=3.0, burst_on_mean=3.0,
                       burst_off_mean=6.0, diurnal_period=10,
                       diurnal_amp=0.6, tenants=3, zipf_s=1.2)
MINI_FLEET = FleetSpec(name="mini2", n_nodes=2, devices_per_node=1,
                       slo_p95_steps=16.0, device_draws=(1.0, 2.0))


def _strip_volatile(record):
    """There is nothing volatile to strip — records carry no timestamps
    by construction. Kept as the explicit place a timing field would be
    excluded if one were ever added; asserts the invariant meanwhile."""
    blob = json.dumps(record, sort_keys=True)
    assert '"t":' not in blob and "wall" not in blob
    return blob


def test_replay_records_bit_identical(served_model):
    """Same (trace, fleet, seed) cell replayed twice — fresh hypervisor,
    fleet and injector each time — produces byte-identical records, with
    the sanitizer enforcing lifecycle legality throughout."""
    _, model, params = served_model
    a = replay_trace(MINI_TRACE, MINI_FLEET, 5, model, params, chaos=True)
    b = replay_trace(MINI_TRACE, MINI_FLEET, 5, model, params, chaos=True)
    assert _strip_volatile(a) == _strip_volatile(b)
    assert a["metrics"]["completed"] > 0


def test_replay_seed_changes_trace_and_faults(served_model):
    _, model, params = served_model
    a = replay_trace(MINI_TRACE, MINI_FLEET, 5, model, params, chaos=True)
    c = replay_trace(MINI_TRACE, MINI_FLEET, 6, model, params, chaos=True)
    assert a["cell"] != c["cell"]
    assert (a["faults"], a["metrics"]) != (c["faults"], c["metrics"])


def test_soak_matrix_green(served_model):
    """The standing matrix — 3 chaos seeds × 2 traces × 2 fleet sizes —
    runs to completion under the sanitizer. Every cell is
    invariant-checked inside ``replay_trace`` (``verify_invariants``:
    quota == journal, ``PagePoolManager.verify``); here the records'
    arithmetic must also close: every arrival is accounted for."""
    _, model, params = served_model
    from repro.core.reconfig import ProgramCache, Reconfigurator
    matrix = SoakMatrix(preset_traces(), preset_fleets(),
                        seeds=[0, 1, 2], chaos=True)
    records = matrix.run(model, params,
                         reconfig=Reconfigurator(ProgramCache()))
    assert len(records) == 12
    for rec in records:
        m = rec["metrics"]
        assert (m["completed"] + m["cancelled"] + m["incomplete"]
                + m["rejected"] == m["arrivals"]), rec["cell"]
        assert m["tokens_out"] > 0 and m["goodput_tokens_per_round"] > 0
        assert m["energy_device_steps"] > 0
        assert 1 <= m["peak_active_devices"] \
            <= rec["fleet_spec"]["n_nodes"] \
            * rec["fleet_spec"]["devices_per_node"]
        lat = m["latency_rounds"]
        assert lat["p50"] <= lat["p95"] <= lat["p99"] <= lat["max"]
        assert rec["faults"], "chaos cells must actually inject faults"
    # the fault schedule differs across seeds (it is the point of the
    # seed axis)
    by_seed = {}
    for rec in records:
        by_seed.setdefault(rec["cell"]["seed"], set()).add(
            json.dumps(rec["faults"]))
    assert len({frozenset(v) for v in by_seed.values()}) > 1


def test_smoke_cell_matches_committed_baseline(served_model):
    """The pinned CI cell replayed here must match the committed
    baseline's goodput within the benchmark's 10% gate — the same check
    the scale-smoke job runs, so a regression fails locally first."""
    from benchmarks.scale_soak import BASELINE, check_regression
    _, model, params = served_model
    trace, fleet, seed = smoke_cell()
    rec = replay_trace(trace, fleet, seed, model, params, chaos=False)
    assert os.path.exists(BASELINE), "committed baseline missing"
    assert check_regression([rec], BASELINE) == []
    with open(BASELINE) as f:
        base = json.load(f)["records"]
    assert rec["cell"] in [r["cell"] for r in base]


def test_event_loop_replay_bit_identical_and_tagged(served_model):
    """``loop="event"`` replays the cell through the event queue: two runs
    are byte-identical (the queue's (time, seq) ordering is a pure
    function of the schedule), the cell records its loop mode — lockstep
    cells stay untagged so committed baselines keep their keys — and
    chaos on the event path stays green under the sanitizer."""
    _, model, params = served_model
    a = replay_trace(MINI_TRACE, MINI_FLEET, 5, model, params, chaos=True,
                     loop="event")
    b = replay_trace(MINI_TRACE, MINI_FLEET, 5, model, params, chaos=True,
                     loop="event")
    assert _strip_volatile(a) == _strip_volatile(b)
    assert a["cell"]["loop"] == "event"
    assert a["metrics"]["completed"] > 0 and a["faults"]
    lockstep = replay_trace(MINI_TRACE, MINI_FLEET, 5, model, params,
                            chaos=True)
    assert "loop" not in lockstep["cell"]
    assert a["cell"] != lockstep["cell"]


def test_open_loop_overload_sheds_not_stalls(served_model):
    """A trace far beyond one small fleet's capacity must finish the
    replay bounded: quota breaches surface as rejections (load shed) and
    the drain cap reports stragglers as incomplete — never a hang."""
    _, model, params = served_model
    hot = TraceSpec(name="hot", horizon=16, base_rate=6.0,
                    burst_rate_mult=1.0, tenants=2, zipf_s=1.0)
    tiny = FleetSpec(name="tiny", n_nodes=1, devices_per_node=1,
                     n_slots=2, slo_p95_steps=None, autoscale_every=0)
    rec = replay_trace(hot, tiny, 0, model, params, chaos=False,
                       drain_slack=64)
    m = rec["metrics"]
    assert m["rejected"] > 0, "open-loop overload must shed load"
    assert m["completed"] > 0
    assert m["rounds"] <= 16 + 64
