"""Runtime tests: losses (chunked == full oracle), optimizer, gradient
compression, sharding specs, serving engine equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import get_model
from repro.optim import (AdamWConfig, adamw_update, dequantize_int8,
                         global_norm, init_opt_state, quantize_int8, schedule)
from repro.runtime import chunked_xent, full_xent
from repro.runtime.sharding import param_specs, zero1_specs


# ---------------------------------------------------------------------------
# Chunked xent == full oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seq,chunk", [(32, 8), (32, 32), (48, 16), (30, 7)])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_chunked_xent_matches_full(seq, chunk, softcap):
    cfg = reduced(get_config("smollm-135m")).replace(
        dtype="float32", final_softcap=softcap)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    h = jax.random.normal(jax.random.PRNGKey(1), (2, seq, cfg.d_model))
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, seq), 0,
                                cfg.vocab_size)
    a = chunked_xent(cfg, params, h, labels, chunk=chunk)
    b = full_xent(cfg, params, h, labels)
    assert abs(float(a) - float(b)) < 1e-4


def test_chunked_xent_grads_match_full():
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    h = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                cfg.vocab_size)
    ga = jax.grad(lambda hh: chunked_xent(cfg, params, hh, labels, chunk=8))(h)
    gb = jax.grad(lambda hh: full_xent(cfg, params, hh, labels))(h)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                               atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, grads, opt, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-6


def test_clip_bounds_update():
    cfg = AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params)
    _, _, metrics = adamw_update(cfg, {"w": jnp.full(3, 1e6)}, opt, params)
    assert metrics["grad_norm"] > 1e6  # reported pre-clip


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_int8_quant_bounded_error(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 10
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) / 2 + 1e-6


def test_error_feedback_preserves_mean_signal():
    """With error feedback, the quantization bias averages out: summed
    compressed updates converge to summed true gradients."""
    from repro.optim.compress import compressed_psum, init_residuals
    from jax.sharding import Mesh
    devs = np.array(jax.devices()[:1])
    mesh = Mesh(devs, ("data",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (128,))}
    res = init_residuals(g)
    total_c = jnp.zeros(128)
    total_t = jnp.zeros(128)

    def one_step(grads, res):
        from repro.runtime.sharding import shard_map
        return shard_map(
            lambda gg, rr: compressed_psum(gg, rr, "data"),
            mesh,
            in_specs=(jax.tree.map(lambda _: jax.sharding.PartitionSpec(), g),) * 2,
            out_specs=(jax.tree.map(lambda _: jax.sharding.PartitionSpec(), g),) * 2)(grads, res)

    for i in range(30):
        gi = {"w": jax.random.normal(jax.random.PRNGKey(i), (128,))}
        ci, res = one_step(gi, res)
        total_c += ci["w"]
        total_t += gi["w"]
    # residual carry-over keeps cumulative error at ~single-step scale
    assert float(jnp.abs(total_c - total_t).max()) < 0.2


# ---------------------------------------------------------------------------
# Sharding specs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible(arch):
    """Every sharded dim must divide by its mesh axis size (16)."""
    import os, subprocess, sys, textwrap
    # needs the 256-device mesh -> subprocess with forced host devices
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import jax
        from repro.configs import get_config
        from repro.models import get_model
        from repro.launch.mesh import make_production_mesh
        from repro.runtime.sharding import param_specs, zero1_specs
        cfg = get_config("{arch}").replace(param_dtype="bfloat16")
        mesh = make_production_mesh()
        shapes = jax.eval_shape(lambda: get_model(cfg).init(jax.random.key(0)))
        specs = param_specs(cfg, shapes, mesh)
        o = zero1_specs(cfg, specs, shapes, mesh)
        def check(tree, spec_tree):
            leaves = jax.tree.flatten(tree)[0]
            specs_l = jax.tree.flatten(spec_tree,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))[0]
            for leaf, sp in zip(leaves, specs_l):
                for dim, ax in zip(leaf.shape, tuple(sp) + (None,) * 9):
                    if ax is None: continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    n = 1
                    for a in axes: n *= mesh.shape[a]
                    assert dim % n == 0, (leaf.shape, sp)
        check(shapes, specs)
        check(shapes, o)
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, cwd=os.path.join(
                              os.path.dirname(__file__), ".."))
    assert "OK" in proc.stdout, proc.stderr[-1500:]


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------

def test_batching_engine_matches_single_stream():
    """Continuous batching returns the same greedy tokens as a dedicated
    single-request decode."""
    from repro.runtime import BatchingEngine
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompts = [np.array([3, 5, 7]), np.array([11, 2]), np.array([9, 9, 9, 4])]

    # reference: each prompt alone
    def solo(prompt, n=5):
        toks = jnp.asarray(prompt, jnp.int32)[None]
        _, caches = m.prefill(params, {"tokens": toks[:, :-1]}, 64) \
            if toks.shape[1] > 1 else (None, m.make_caches(1, 64))
        tok = toks[:, -1:]
        pos = jnp.asarray([toks.shape[1] - 1], jnp.int32)
        out = []
        for _ in range(n):
            logits, caches = m.decode(params, caches, tok, pos)
            tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
            out.append(int(tok[0, 0]))
            pos = pos + 1
        return out

    expected = [solo(p) for p in prompts]
    engine = BatchingEngine(m, params, n_slots=2, max_len=64)
    reqs = [engine.submit(p, max_new_tokens=5) for p in prompts]
    engine.run_until_idle()
    for req, exp in zip(reqs, expected):
        assert req.out_tokens == exp, (req.out_tokens, exp)


def test_engine_rejects_ssm():
    from repro.runtime import BatchingEngine
    cfg = reduced(get_config("mamba2-370m")).replace(dtype="float32")
    m = get_model(cfg)
    with pytest.raises(ValueError):
        BatchingEngine(m, m.init(jax.random.PRNGKey(0)))


def test_engine_rejects_empty_prompt():
    """A zero-length prompt used to crash _admit with IndexError on
    toks[-1]; it must be rejected up front with a clear error."""
    from repro.runtime import BatchingEngine
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    m = get_model(cfg)
    engine = BatchingEngine(m, m.init(jax.random.PRNGKey(0)), n_slots=2,
                            max_len=64)
    with pytest.raises(ValueError, match="empty prompt"):
        engine.submit([], max_new_tokens=4)
    with pytest.raises(ValueError, match="empty prompt"):
        engine.submit(np.zeros((0,), np.int32))
    assert engine.idle()


def test_batched_prefill_matches_legacy_token_loop():
    """Regression for the O(prompt_len x n_slots) prefill bug: prefilling a
    slot with ONE batched model.prefill call must produce exactly the
    tokens of the old one-full-batch-decode-per-prompt-token path.
    Prompt lengths straddle the pad-bucket boundaries (8, 16)."""
    from repro.runtime import BatchingEngine
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (5, 8, 9, 13, 17)]

    def serve(mode):
        engine = BatchingEngine(m, params, n_slots=2, max_len=64,
                                prefill_mode=mode)
        reqs = [engine.submit(p, max_new_tokens=6) for p in prompts]
        engine.run_until_idle()
        assert all(len(r.out_tokens) == 6 for r in reqs)
        return [r.out_tokens for r in reqs]

    assert serve("batched") == serve("legacy")
