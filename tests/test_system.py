"""End-to-end behaviour: the full RC3E story on one box — allocate via each
service model, train a real (reduced) model through the RAaaS batch system
with checkpointing, fail a node mid-run, restart elsewhere, and verify the
loss trajectory continues. Plus the HLO analyzer used by the roofline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import restore, save
from repro.configs import get_config, reduced
from repro.core import ClusterSpec, Hypervisor, MonitorConfig
from repro.data import DataConfig, DataPipeline
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.runtime import TrainOpts, init_train_state, make_train_step


def test_end_to_end_raas_training_with_failover(tmp_path):
    """A tenant trains via RAaaS; its node dies; the hypervisor requeues the
    job; training resumes from checkpoint and keeps improving."""
    class Clock:
        t = 0.0
        def __call__(self):
            return self.t

    clock = Clock()
    hv = Hypervisor(ClusterSpec(n_nodes=2, devices_per_node=1),
                    MonitorConfig(heartbeat_deadline_s=10), clock=clock)
    ckpt_dir = str(tmp_path / "ckpt")

    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32",
                                                     vocab_size=256)
    model = get_model(cfg)
    opts = TrainOpts(opt=AdamWConfig(lr=2e-3, warmup_steps=2,
                                     total_steps=40), loss_chunk=16)
    step = jax.jit(make_train_step(model, opts))
    data = DataPipeline(DataConfig(vocab_size=256, seq_len=32, batch_size=4))
    losses = []

    def train_job(slice_id, crash_at=None):
        like = jax.eval_shape(
            lambda: init_train_state(model, jax.random.PRNGKey(0), opts))
        try:
            state, start = restore(ckpt_dir, like)
        except FileNotFoundError:
            state, start = init_train_state(model, jax.random.PRNGKey(0),
                                            opts), 0
        for i in range(start, start + 10):
            if crash_at is not None and i == crash_at:
                raise RuntimeError("node lost")
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
            save(state, ckpt_dir, step=i + 1, keep=2)
        return float(losses[-1])

    job = hv.scheduler.submit("tenant", 4,
                              run=lambda s: train_job(s, crash_at=5))
    hv.scheduler.run_pending()            # crashes mid-run, requeued
    assert job.state.value == "requeued"
    assert len(losses) == 5

    # the node that hosted it dies entirely; node-1 keeps heartbeating
    for n in hv.db.nodes:
        hv.monitor.heartbeat(n)
    clock.t = 15.0
    hv.monitor.heartbeat("node-1")
    clock.t = 20.0
    hv.handle_failures()
    assert not hv.db.nodes["node-0"].alive
    assert hv.db.nodes["node-1"].alive

    job.run = lambda s: train_job(s)      # resume (no crash this time)
    hv.scheduler.run_pending()
    assert job.state.value == "done"
    assert len(losses) == 15
    assert losses[-1] < losses[0]


def test_three_service_models_coexist():
    import numpy as np
    from repro.core import BAaaSSession, RAaaSSession, RSaaSSession
    hv = Hypervisor(ClusterSpec(n_nodes=2, devices_per_node=2))
    rs = RSaaSSession(hv, "alice")                    # full device
    ra = RAaaSSession(hv, "bob", slots=2)             # vSlice
    hv.register_service("double", lambda: (
        lambda a: (a * 2,), (np.ones((4,), np.float32),)))
    ba = BAaaSSession(hv, "carol")
    out = ba.invoke("double", np.arange(4, dtype=np.float32))
    np.testing.assert_allclose(out[0], [0, 2, 4, 6])
    util = hv.db.utilization()
    assert sum(v > 0 for v in util.values()) == 2     # rsaas dev + raas dev
    rs.close(); ra.close()
    assert all(v == 0.0 for v in hv.db.utilization().values())


def test_hlo_analyzer_counts_loops_exactly():
    from repro.launch.hlo_analysis import analyze_hlo
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y
    x = jnp.ones((64, 64))
    w = jnp.ones((64, 64))
    compiled = jax.jit(f).lower(x, w).compile()
    costs = analyze_hlo(compiled.as_text(), 1)
    assert costs.flops == pytest.approx(7 * 2 * 64 ** 3, rel=1e-6)


def test_hlo_analyzer_collectives():
    from repro.launch.hlo_analysis import analyze_hlo
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.hlo_analysis import analyze_hlo
        from repro.runtime.sharding import shard_map
        mesh = jax.make_mesh((8,), ("d",))
        def f(x):
            return shard_map(lambda v: jax.lax.psum(v, "d"), mesh,
                             in_specs=P("d"), out_specs=P())(x)
        c = jax.jit(f).lower(jnp.ones((64, 128))).compile()
        costs = analyze_hlo(c.as_text(), 8)
        # ring all-reduce of an 8x128 f32 shard: 2*B*(n-1)/n
        exp = 2 * (8 * 128 * 4) * 7 / 8
        assert abs(costs.collective_bytes - exp) / exp < 0.5, costs.collective_bytes
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env,
                          cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "OK" in proc.stdout, proc.stderr[-1500:]
