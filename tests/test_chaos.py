"""Chaos suite: seeded fault schedules against a multi-device serving
fleet, with machine-checked invariants after EVERY step:

  * token-stream bit-exactness vs. a fault-free run of the same workload
    (greedy decode + journal prefix replay must make failover invisible);
  * page-pool conservation on every surviving engine
    (``PagePoolManager.verify``: free + referenced == total, no refcount
    leaks, no double-frees);
  * quota conservation per tenant (admission in-flight == unfinished
    journaled requests — nothing settled twice, nothing leaked).

Seeds come from ``CHAOS_SEEDS`` (comma-separated; CI pins a small fixed
matrix, local soak runs can widen it: ``CHAOS_SEEDS=$(seq -s, 0 99)``).
"""
import os

import jax
import numpy as np
import pytest

from repro.analysis import sanitizer
from repro.configs import get_config, reduced
from repro.core import ClusterSpec, DeviceState, Hypervisor, MonitorConfig
from repro.models import get_model
from repro.runtime import (BatchingEngine, EventLoop, FaultInjector,
                           GatewayFleet)
from repro.runtime.faults import FakeClock

SEEDS = [int(s) for s in
         os.environ.get("CHAOS_SEEDS", "0,1,2,3,4").split(",") if s.strip()]


@pytest.fixture(autouse=True)
def _sanitizer_reset():
    """Per-test sanitizer state: scope tokens are never reused, so clearing
    tracked objects between tests cannot alias a new fleet with an old one;
    it only keeps the per-run transition counts honest."""
    sanitizer.reset()
    yield

N_TENANTS = 6          # 2 slots each -> 3 active devices + 1 parked spare
REQS_PER_TENANT = 2
NEW_TOKENS = 8


@pytest.fixture(scope="module")
def served_model():
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=n).tolist()


def _build_fleet(model, params, injector=None, n_nodes=4, **kw):
    """Fleet whose hypervisor shares the injector's fake clock, so
    heartbeat deadlines advance one tick per decode step."""
    clock = injector.clock if injector is not None else FakeClock()
    hv = Hypervisor(ClusterSpec(n_nodes=n_nodes, devices_per_node=1),
                    MonitorConfig(heartbeat_interval_s=1.0,
                                  heartbeat_deadline_s=2.5),
                    clock=clock)
    fleet = GatewayFleet(hv, model, params, n_slots=4, max_len=64,
                         paged=True, faults=injector, **kw)
    return hv, fleet


def _run_workload(cfg, model, params, injector=None, max_steps=400,
                  loop="lockstep", prefill_chunk=4):
    """The fixed chaos workload (identical across seeds — only the fault
    schedule varies): 6 two-slot tenants packed onto 3 devices, 2 requests
    each, one spare PARKED device. Steps the fleet with invariant checks
    after every event until every request settles. ``loop="event"`` drives
    the same workload through the event queue (chunked prefill, batched
    journal syncs, overlapped hand-offs) instead of the round barrier."""
    hv, fleet = _build_fleet(model, params, injector)
    for ti in range(N_TENANTS):
        fleet.open_session(f"t{ti}", slots=2)
    assert len(fleet._engines) == 3          # packed, spare left parked
    reqs = {}
    for ti in range(N_TENANTS):
        for k in range(REQS_PER_TENANT):
            reqs[(ti, k)] = fleet.submit(
                f"t{ti}", _prompt(cfg, 5 + ti, seed=100 + ti * 10 + k),
                max_new_tokens=NEW_TOKENS)
    ev = EventLoop(fleet, prefill_chunk=prefill_chunk) \
        if loop == "event" else None
    for _ in range(max_steps):
        fleet.step() if ev is None else ev.run_ticks(1)
        fleet.verify_invariants()
        if all(r.done.is_set() for r in reqs.values()):
            break
    if ev is not None:
        fleet.flush_journal()                # drain the batched syncs
    assert all(r.done.is_set() for r in reqs.values()), \
        "workload did not drain"
    # post-drain conservation: every surviving pool returned every page,
    # every tenant's in-flight quota settled, no stale occupancy entries
    for eng in fleet._engines.values():
        eng.pool.verify()
        assert eng.pool.used_pages == 0
    for ti in range(N_TENANTS):
        if f"t{ti}" in fleet._sessions:
            assert hv.admission.usage(f"t{ti}")["inflight"] == 0
    assert set(hv.monitor.page_occupancy()) <= set(fleet._engines)
    if sanitizer.enabled:
        # the run exercised (and the sanitizer checked) every lifecycle
        # machine: requests, engine slots, pool pages, journal entries and
        # physical devices all made legal transitions only
        active = {m for m, n in sanitizer.stats().items() if n}
        assert {"request", "slot", "page", "journal", "device"} <= active
    tokens = {k: list(r.out_tokens) for k, r in reqs.items()}
    return tokens, reqs, hv, fleet


@pytest.fixture(scope="module")
def baseline_tokens(served_model):
    """The fault-free run every chaos schedule must be bit-exact against."""
    cfg, model, params = served_model
    tokens, reqs, hv, fleet = _run_workload(cfg, model, params)
    assert all(len(t) == NEW_TOKENS for t in tokens.values())
    fleet.close()
    return tokens


# ---------------------------------------------------------------------------
# Event-driven loop parity (satellite: lockstep vs event token exactness)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("loop,prefill_chunk",
                         [("lockstep", 4), ("event", 4), ("event", 2)],
                         ids=["lockstep", "event", "event-chunk2"])
def test_loop_modes_produce_identical_token_logs(served_model,
                                                 baseline_tokens, loop,
                                                 prefill_chunk):
    """Fault-free, the event-driven loop (chunked prefill, per-engine
    cadence, batched journal syncs) must emit token logs bit-identical to
    the lockstep barrier — the loop is a scheduling change, never a
    results change. Exercised at two prefill chunk sizes: chunking only
    reshapes WHEN prompt tokens are spliced, not what gets decoded."""
    cfg, model, params = served_model
    tokens, reqs, hv, fleet = _run_workload(
        cfg, model, params, loop=loop, prefill_chunk=prefill_chunk)
    assert tokens == baseline_tokens
    fleet.close()


@pytest.mark.parametrize("seed", SEEDS[:2])
def test_event_loop_device_kill_recovers_bit_exact(served_model,
                                                   baseline_tokens, seed):
    """Chaos on the async path: a seeded device kill under the EVENT loop
    (failover sweep runs on control ticks, not fleet rounds) still recovers
    every in-flight request bit-exact to the fault-free run."""
    cfg, model, params = served_model
    inj = FaultInjector(seed=seed)
    inj.plan_device_kill(["dev-0-0", "dev-1-0", "dev-2-0"], lo=2, hi=6)
    tokens, reqs, hv, fleet = _run_workload(cfg, model, params,
                                            injector=inj, loop="event")
    kills = [e for e in inj.log if e["kind"] == "kill_device"]
    assert len(kills) == 1
    assert hv.db.devices[kills[0]["target"]].state == DeviceState.DEAD
    assert fleet.recoveries and fleet.recoveries[0]["resumed"] == 4
    assert tokens == baseline_tokens
    fleet.close()


# ---------------------------------------------------------------------------
# Acceptance: seeded device kill mid-decode -> bit-exact recovery
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_device_kill_mid_decode_recovers_bit_exact(served_model,
                                                   baseline_tokens, seed):
    """A seeded FaultInjector kills one of the 3 active devices mid-decode
    (step and victim drawn from the seed). Every in-flight request must
    complete with tokens bit-exact to the fault-free run, and page/quota
    conservation must hold after every step."""
    cfg, model, params = served_model
    inj = FaultInjector(seed=seed)
    inj.plan_device_kill(["dev-0-0", "dev-1-0", "dev-2-0"], lo=2, hi=6)
    tokens, reqs, hv, fleet = _run_workload(cfg, model, params, injector=inj)

    kills = [e for e in inj.log if e["kind"] == "kill_device"]
    assert len(kills) == 1
    dead = kills[0]["target"]
    assert hv.db.devices[dead].state == DeviceState.DEAD
    # all 4 of the dead device's requests were mid-flight and resumed from
    # the journal — no live source engine existed to drain
    assert fleet.recoveries and fleet.recoveries[0]["device"] == dead
    assert fleet.recoveries[0]["resumed"] == 4
    assert not fleet.recoveries[0]["evicted"]
    # the spare PARKED device was woken to absorb the orphans (no other
    # device had 2 free slots)
    assert hv.db.devices["dev-3-0"].state == DeviceState.ACTIVE
    assert tokens == baseline_tokens
    fleet.close()


@pytest.mark.parametrize("seed", SEEDS[:2])
def test_node_kill_detected_by_heartbeat_deadline(served_model,
                                                  baseline_tokens, seed):
    """A node crash is only visible through silence: its engine freezes
    immediately, the monitor declares it dead one heartbeat deadline
    later, and recovery still lands bit-exact."""
    cfg, model, params = served_model
    inj = FaultInjector(seed=seed)
    ev = inj.plan_node_kill(["node-0", "node-1", "node-2"], lo=2, hi=5)
    tokens, reqs, hv, fleet = _run_workload(cfg, model, params, injector=inj)

    dead_events = [e for e in hv.monitor.events if e["kind"] == "node_dead"]
    assert len(dead_events) == 1 and dead_events[0]["node"] == ev.target
    # detection latency: the deadline runs from the node's LAST heartbeat
    # (clock == ev.step, one tick before the kill fires at ev.step + 1) —
    # death is declared only after it expires, never at the kill instant
    assert dead_events[0]["t"] - ev.step >= 2.5
    assert dead_events[0]["t"] > ev.step + 1
    assert not hv.db.nodes[ev.target].alive
    assert fleet.recoveries and fleet.recoveries[0]["resumed"] == 4
    assert tokens == baseline_tokens
    fleet.close()


def test_transient_partition_needs_no_recovery(served_model,
                                               baseline_tokens):
    """A partition shorter than the heartbeat deadline is survivable: the
    device kept decoding the whole time, so nothing must be declared dead
    and no recovery may fire."""
    cfg, model, params = served_model
    inj = FaultInjector(seed=0)
    inj.partition_node_at(1, "node-0")
    inj.heal_node_at(3, "node-0")        # silent for 2 ticks < 2.5 deadline
    tokens, reqs, hv, fleet = _run_workload(cfg, model, params, injector=inj)
    assert not [e for e in hv.monitor.events if e["kind"] == "node_dead"]
    assert not fleet.recoveries
    assert all(d.state != DeviceState.DEAD for d in hv.db.devices.values())
    assert tokens == baseline_tokens
    fleet.close()


# ---------------------------------------------------------------------------
# Degrade / evict paths (failover under capacity pressure)
# ---------------------------------------------------------------------------

def test_failover_degrades_slots_when_survivors_are_smaller(served_model):
    """A dead 4-slot tenant lands on a survivor with only 2 free slots:
    placement degrades 4 -> 2, the admission slot quota hands back the
    difference, and the requests still finish."""
    cfg, model, params = served_model
    inj = FaultInjector(seed=0)
    hv, fleet = _build_fleet(model, params, injector=inj, n_nodes=2)
    fleet.open_session("big", slots=4, service_model="rsaas")   # fills dev-0
    fleet.open_session("b1", slots=1)                           # dev-1
    fleet.open_session("b2", slots=1)                           # dev-1
    assert fleet.device_of("big") != fleet.device_of("b1")
    reqs = [fleet.submit("big", _prompt(cfg, 6, seed=i), max_new_tokens=6)
            for i in range(2)]
    other = fleet.submit("b1", _prompt(cfg, 6, seed=9), max_new_tokens=6)
    for _ in range(2):
        fleet.step()
        fleet.verify_invariants()
    inj.kill_device_at(2, fleet.device_of("big"))
    for _ in range(60):
        fleet.step()
        fleet.verify_invariants()
        if all(r.done.is_set() for r in reqs) and other.done.is_set():
            break
    assert fleet.session("big").slots == 2                      # degraded
    assert hv.admission.usage("big", "rsaas")["slots"] == 2
    assert all(len(r.out_tokens) == 6 for r in reqs)
    assert len(other.out_tokens) == 6
    places = [e for e in hv.log if e["kind"] == "failover_place"]
    assert places and places[0]["degraded"] is True
    fleet.close()


def test_failover_degrade_shrinks_page_grant(served_model):
    """Regression: on a page-METERED cluster, each degrade step must ask
    for the page grant matching ITS slot count. A 4-slot tenant whose
    device dies lands as a 2-slot slice with the 2-slot grant — neither
    evicted because the 4-slot grant can't fit, nor over-reserving the
    full grant after the degrade."""
    cfg, model, params = served_model
    inj = FaultInjector(seed=0)
    hv = Hypervisor(ClusterSpec(n_nodes=2, devices_per_node=1,
                                cache_pages_per_device=16),
                    MonitorConfig(heartbeat_interval_s=1.0,
                                  heartbeat_deadline_s=2.5),
                    clock=inj.clock)
    fleet = GatewayFleet(hv, model, params, n_slots=4, max_len=64,
                         paged=True, cache_pages=17, faults=inj)
    fleet.open_session("big", slots=4, service_model="rsaas")  # grant 16
    fleet.open_session("b1", slots=1)                          # grant 4
    fleet.open_session("b2", slots=1)                          # grant 4
    assert fleet.device_of("big") != fleet.device_of("b1")
    reqs = [fleet.submit("big", _prompt(cfg, 6, seed=i), max_new_tokens=6)
            for i in range(2)]
    for _ in range(2):
        fleet.step()
        fleet.verify_invariants()
    inj.kill_device_at(2, fleet.device_of("big"))
    for _ in range(60):
        fleet.step()
        fleet.verify_invariants()
        if all(r.done.is_set() for r in reqs):
            break
    # survivor device had 2 free slots and 8 free grant pages: 4 slots /
    # 16 pages could never fit, 2 slots with ITS 8-page grant does
    assert not fleet.recoveries[0]["evicted"]
    assert fleet.session("big").slots == 2
    vs = hv.db.find_slice(fleet.session("big").slice_id)
    assert vs.cache_pages == 8
    dev = hv.db.devices[vs.device_id]
    assert dev.granted_cache_pages() <= dev.cache_pages
    assert all(len(r.out_tokens) == 6 for r in reqs)
    fleet.close()


def test_cancel_after_device_failure_before_sweep(served_model):
    """Regression: an external ``Hypervisor.mark_device_failed`` between
    fleet steps leaves the dead engine registered until the next sweep;
    a client cancel arriving in that window must recover first and settle
    exactly once — not settle against the slice that died with the device
    (KeyError + leaked in-flight quota)."""
    cfg, model, params = served_model
    hv, fleet = _build_fleet(model, params, n_nodes=2)
    fleet.open_session("a", slots=2)
    victim = fleet.submit("a", _prompt(cfg, 6, seed=1), max_new_tokens=10)
    other = fleet.submit("a", _prompt(cfg, 6, seed=2), max_new_tokens=10)
    for _ in range(2):
        fleet.step()
    hv.mark_device_failed(fleet.device_of("a"), reason="status_error")
    assert fleet.cancel(victim) is True
    assert victim.finish_reason == "cancelled"
    assert fleet.recoveries and fleet.recoveries[0]["device"] == "dev-0-0"
    assert hv.admission.usage("a")["inflight"] == 1          # other only
    fleet.verify_invariants()
    for _ in range(60):
        fleet.step()
        fleet.verify_invariants()
        if other.done.is_set():
            break
    assert other.finish_reason == "length"
    assert hv.admission.usage("a")["inflight"] == 0
    assert fleet.session("a").served == 2
    fleet.close()


def test_no_capacity_eviction_settles_quota_once(served_model):
    """When a dead device's tenants fit NOWHERE (cluster full), they are
    evicted: requests cancelled, slot + in-flight quota settled exactly
    once, and the surviving tenants drain untouched."""
    cfg, model, params = served_model
    inj = FaultInjector(seed=0)
    hv, fleet = _build_fleet(model, params, injector=inj, n_nodes=2)
    for t in ("a0", "a1", "b0", "b1"):                # 4 x 2 slots: full
        fleet.open_session(t, slots=2)
    dead_dev = fleet.device_of("a0")
    victims = [t for t in ("a0", "a1", "b0", "b1")
               if fleet.device_of(t) == dead_dev]
    survivors = [t for t in ("a0", "a1", "b0", "b1") if t not in victims]
    reqs = {t: fleet.submit(t, _prompt(cfg, 6, seed=ord(t[0]) + int(t[1])),
                            max_new_tokens=6)
            for t in ("a0", "a1", "b0", "b1")}
    for _ in range(2):
        fleet.step()
        fleet.verify_invariants()
    inj.kill_device_at(2, dead_dev)
    for _ in range(60):
        fleet.step()
        fleet.verify_invariants()
        if all(r.done.is_set() for r in reqs.values()):
            break
    assert sorted(fleet.recoveries[0]["evicted"]) == sorted(victims)
    for t in victims:
        assert reqs[t].finish_reason == "cancelled"
        assert hv.admission.usage(t)["inflight"] == 0
        assert hv.admission.usage(t)["slots"] == 0
        assert t not in fleet._sessions
    for t in survivors:
        assert reqs[t].finish_reason == "length"
        assert len(reqs[t].out_tokens) == 6
    fleet.close()


# ---------------------------------------------------------------------------
# Hand-off fault paths
# ---------------------------------------------------------------------------

def test_page_copy_failure_falls_back_to_replay(served_model):
    """Every hand-off page copy fails (interconnect loss): migration must
    fall back to prompt-prefix replay, and the tokens still match an
    unmigrated run."""
    cfg, model, params = served_model
    prompt = _prompt(cfg, 20, seed=5)
    inj = FaultInjector(seed=0, page_copy_fail_rate=1.0)
    hv, fleet = _build_fleet(model, params, injector=inj, n_nodes=2)
    fleet.open_session("a", slots=2)
    req = fleet.submit("a", prompt, max_new_tokens=12)
    for _ in range(3):
        fleet.step()
    target = next(d for d in hv.db.devices if d != fleet.device_of("a"))
    assert hv.migrate_slice(fleet.session("a").slice_id,
                            target_device=target) is not None
    assert fleet.handoffs[-1]["page_copied"] == 0
    assert fleet.handoffs[-1]["moved_requests"] == 1
    assert [e for e in inj.log if e["kind"] == "page_copy_fail"]
    for _ in range(60):
        fleet.step()
        fleet.verify_invariants()
        if req.done.is_set():
            break
    fleet.close()

    hv2, fleet2 = _build_fleet(model, params, n_nodes=1)
    fleet2.open_session("a", slots=2)
    ref = fleet2.submit("a", prompt, max_new_tokens=12)
    assert fleet2.run_until_idle() is True
    assert req.out_tokens == ref.out_tokens
    fleet2.close()


def test_cancel_racing_handoff_settles_exactly_once(served_model,
                                                    monkeypatch):
    """Regression (satellite): a request cancelled BETWEEN page export and
    resume — drained from the source, held by no engine — must settle its
    quota and free its pages exactly once, and must not be resumed on the
    target by the in-progress hand-off."""
    cfg, model, params = served_model
    hv, fleet = _build_fleet(model, params, n_nodes=2)
    fleet.open_session("a", slots=2)
    victim = fleet.submit("a", _prompt(cfg, 20, seed=1), max_new_tokens=12)
    bystander = fleet.submit("a", _prompt(cfg, 6, seed=2), max_new_tokens=6)
    for _ in range(3):
        fleet.step()
    assert not victim.done.is_set()
    assert hv.admission.usage("a")["inflight"] == 2

    orig = BatchingEngine.drain_tenant

    def drain_and_cancel(self, tenant):
        moved = orig(self, tenant)
        # the client's cancel lands in the hand-off window: pages already
        # exported and freed by the drain, resume not yet issued
        assert fleet.cancel(victim) is True
        return moved

    monkeypatch.setattr(BatchingEngine, "drain_tenant", drain_and_cancel)
    target = next(d for d in hv.db.devices if d != fleet.device_of("a"))
    assert hv.migrate_slice(fleet.session("a").slice_id,
                            target_device=target) is not None
    monkeypatch.undo()

    assert victim.finish_reason == "cancelled"
    assert victim.request_id not in fleet.journal
    # not resumed anywhere: no engine queues or decodes it
    for eng in fleet._engines.values():
        assert victim not in eng.inflight()
        assert all(victim.request_id != r.request_id
                   for q in eng._queues.values() for r in q)
    assert hv.admission.usage("a")["inflight"] == 1      # bystander only
    assert fleet.cancel(victim) is False                 # second cancel no-ops
    for _ in range(60):
        fleet.step()
        fleet.verify_invariants()
        if bystander.done.is_set():
            break
    assert bystander.finish_reason == "length"
    assert hv.admission.usage("a")["inflight"] == 0
    assert fleet.session("a").served == 2                # victim + bystander
    for eng in fleet._engines.values():
        eng.pool.verify()
        assert eng.pool.used_pages == 0
    fleet.close()
