"""Per-kernel validation: Pallas interpret mode vs pure-jnp oracle across
shape/dtype sweeps (the container has no TPU; interpret executes the kernel
body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


@pytest.mark.parametrize("m,k,n", [(16, 16, 16), (32, 32, 32),
                                   (128, 128, 128), (200, 300, 150),
                                   (129, 257, 65)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stream_matmul(m, k, n, dtype):
    a = jax.random.normal(KEY, (m, k), dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
    out = ops.matmul(a, b, force="interpret")
    ref = ops.matmul(a, b, force="ref")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype) * k ** 0.5,
                               rtol=_tol(dtype))


def test_stream_matmul_batched_paper_sizes():
    """The paper's workload: a stream of 16x16 / 32x32 multiplications."""
    for size in (16, 32):
        a = jax.random.normal(KEY, (64, size, size), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(2), (64, size, size),
                              jnp.float32)
        out = ops.matmul_batched(a, b, force="interpret")
        ref = ops.matmul_batched(a, b, force="ref")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("s", [256, 512])
def test_flash_attention(hq, hkv, window, s):
    q = jax.random.normal(KEY, (2, hq, s, 64), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, hkv, s, 64), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, hkv, s, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, window=window, force="interpret")
    ref = ops.flash_attention(q, k, v, window=window, force="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-4)


def test_flash_attention_softcap():
    q = jax.random.normal(KEY, (1, 2, 256, 32), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 256, 32), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 256, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, softcap=50.0, force="interpret")
    ref = ops.flash_attention(q, k, v, softcap=50.0, force="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("s,chunk", [(64, 16), (128, 32), (96, 32), (256, 64)])
@pytest.mark.parametrize("n", [16, 64])
def test_ssd_chunk_scan(s, chunk, n):
    BH, P = 3, 16
    x = jax.random.normal(KEY, (BH, s, P), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (BH, s)))
    Bm = jax.random.normal(jax.random.PRNGKey(2), (BH, s, n)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(3), (BH, s, n)) * 0.3
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(4), (BH,)))
    d = jnp.ones((BH,))
    out = ops.ssd_chunk_scan(x, dt, Bm, Cm, a, d, chunk=chunk,
                             force="interpret")
    ref = ops.ssd_chunk_scan(x, dt, Bm, Cm, a, d, force="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-4, rtol=5e-3)


@pytest.mark.parametrize("hq,hkv,L", [(8, 2, 512), (4, 4, 1024), (16, 1, 512)])
@pytest.mark.parametrize("window", [0, 128])
def test_decode_attention(hq, hkv, L, window):
    B, D = 2, 64
    q = jax.random.normal(KEY, (B, hq, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, hkv, L, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, hkv, L, D), jnp.float32)
    kpos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    kpos = jnp.where(kpos < L - 100, kpos, -1)   # partially filled cache
    cur = jnp.array([L - 150, L // 3])
    out = ops.decode_attention(q, k, v, kpos, cur, window=window,
                               force="interpret")
    ref = ops.decode_attention(q, k, v, kpos, cur, window=window,
                               force="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-4)


def test_ssd_kernel_matches_layer_path():
    """The kernel oracle must agree with the model's SSD implementation."""
    from repro.layers.ssm import ssd_scan
    BH, s, P, n = 2, 64, 16, 16
    x = jax.random.normal(KEY, (1, s, BH, P), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (1, s, BH)))
    Bm = jax.random.normal(jax.random.PRNGKey(2), (1, s, 1, n)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(3), (1, s, 1, n)) * 0.3
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(4), (BH,)))
    d = jnp.ones((BH,))
    y_layer, _ = ssd_scan(x, dt, a, Bm, Cm, d, chunk=16)
    # kernel layout: (BH, S, P) with per-head a/d; groups pre-expanded
    xk = jnp.moveaxis(x[0], 1, 0)                      # (BH, S, P)
    dtk = jnp.moveaxis(dt[0], 1, 0)                    # (BH, S)
    Bk = jnp.broadcast_to(Bm[0, :, 0][None], (BH, s, n))
    Ck = jnp.broadcast_to(Cm[0, :, 0][None], (BH, s, n))
    y_kernel = ops.ssd_chunk_scan(xk, dtk, Bk, Ck, a, d, chunk=16,
                                  force="interpret")
    np.testing.assert_allclose(
        np.asarray(jnp.moveaxis(y_kernel, 0, 1)), np.asarray(y_layer[0]),
        atol=5e-4, rtol=5e-3)


def _scatter_to_pool(k, v, kpos, n_pages, page_size, seed=0):
    """Chop a dense (B, Hkv, L, D) cache into shuffled pool pages + block
    tables (page 0 left empty — the engine's reserved null page)."""
    B, Hkv, L, D = k.shape
    nb = L // page_size
    rng = np.random.default_rng(seed)
    pages = rng.permutation(np.arange(1, n_pages))[:B * nb] \
        .reshape(B, nb).astype(np.int32)
    k_pool = jnp.zeros((n_pages, Hkv, page_size, D), k.dtype)
    v_pool = jnp.zeros((n_pages, Hkv, page_size, D), v.dtype)
    kpos_pool = jnp.full((n_pages, page_size), -1, jnp.int32)
    for b in range(B):
        for j in range(nb):
            pid = int(pages[b, j])
            sl = slice(j * page_size, (j + 1) * page_size)
            k_pool = k_pool.at[pid].set(k[b, :, sl])
            v_pool = v_pool.at[pid].set(v[b, :, sl])
            kpos_pool = kpos_pool.at[pid].set(kpos[b, sl])
    return k_pool, v_pool, kpos_pool, jnp.asarray(pages)


@pytest.mark.parametrize("hq,hkv", [(8, 2), (4, 4)])
@pytest.mark.parametrize("window", [0, 128])
def test_paged_decode_attention(hq, hkv, window):
    """Block-table-indirect kernel == paged ref == dense ref on the same
    logical cache scattered across a shuffled page pool."""
    B, D, ps, nb = 2, 64, 64, 8
    L = nb * ps
    q = jax.random.normal(KEY, (B, hq, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, hkv, L, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, hkv, L, D), jnp.float32)
    kpos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    kpos = jnp.where(kpos < L - 70, kpos, -1)    # partially filled cache
    cur = jnp.array([L - 100, L // 3])
    k_pool, v_pool, kpos_pool, bt = _scatter_to_pool(k, v, kpos, 2 * B * nb,
                                                     ps)
    dense = ops.decode_attention(q, k, v, kpos, cur, window=window,
                                 force="ref")
    ref = ops.paged_decode_attention(q, k_pool, v_pool, kpos_pool, bt, cur,
                                     window=window, force="ref")
    kern = ops.paged_decode_attention(q, k_pool, v_pool, kpos_pool, bt, cur,
                                      window=window, force="interpret")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(dense),
                               atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(ref),
                               atol=2e-5, rtol=2e-4)


def test_paged_decode_attention_int8_pool():
    """int8 page pool: kernel == paged ref, bounded noise vs fp32 dense."""
    B, Hq, Hkv, D, ps, nb = 2, 8, 2, 64, 32, 8
    L = nb * ps
    q = jax.random.normal(KEY, (B, Hq, D))
    kf = jax.random.normal(jax.random.PRNGKey(5), (B, Hkv, L, D))
    vf = jax.random.normal(jax.random.PRNGKey(6), (B, Hkv, L, D))

    def quant(x):
        amax = jnp.max(jnp.abs(x), axis=-1)
        s = jnp.where(amax > 0, amax / 127.0, 1.0)
        return (jnp.clip(jnp.round(x / s[..., None]), -127, 127)
                .astype(jnp.int8), s)

    k8, ks = quant(kf)
    v8, vs = quant(vf)
    kpos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    cur = jnp.array([200, 77])
    k_pool, v_pool, kpos_pool, bt = _scatter_to_pool(k8, v8, kpos, 2 * B * nb,
                                                     ps)
    ks_pool, vs_pool, _, _ = _scatter_to_pool(ks[..., None], vs[..., None],
                                              kpos, 2 * B * nb, ps)
    ks_pool, vs_pool = ks_pool[..., 0], vs_pool[..., 0]
    o8 = ops.paged_decode_attention(q, k_pool, v_pool, kpos_pool, bt, cur,
                                    k_scale=ks_pool, v_scale=vs_pool,
                                    force="interpret")
    r8 = ops.paged_decode_attention(q, k_pool, v_pool, kpos_pool, bt, cur,
                                    k_scale=ks_pool, v_scale=vs_pool,
                                    force="ref")
    full = ops.decode_attention(q, kf, vf, kpos, cur, force="ref")
    np.testing.assert_allclose(np.asarray(o8), np.asarray(r8),
                               atol=2e-5, rtol=2e-4)
    assert float(jnp.abs(r8 - full).max()) < 0.01   # quantization noise


def test_decode_attention_int8_cache():
    """int8-quantized KV cache path: kernel == ref, bounded quant noise."""
    B, Hq, Hkv, D, L = 2, 8, 2, 64, 1024
    q = jax.random.normal(KEY, (B, Hq, D))
    kf = jax.random.normal(jax.random.PRNGKey(5), (B, Hkv, L, D))
    vf = jax.random.normal(jax.random.PRNGKey(6), (B, Hkv, L, D))

    def quant(x):
        amax = jnp.max(jnp.abs(x), axis=-1)
        s = jnp.where(amax > 0, amax / 127.0, 1.0)
        return (jnp.clip(jnp.round(x / s[..., None]), -127, 127)
                .astype(jnp.int8), s)

    k8, ks = quant(kf)
    v8, vs = quant(vf)
    kpos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    cur = jnp.array([800, 333])
    o8 = ops.decode_attention(q, k8, v8, kpos, cur, k_scale=ks, v_scale=vs,
                              force="interpret")
    r8 = ops.decode_attention(q, k8, v8, kpos, cur, k_scale=ks, v_scale=vs,
                              force="ref")
    full = ops.decode_attention(q, kf, vf, kpos, cur, force="ref")
    np.testing.assert_allclose(np.asarray(o8), np.asarray(r8),
                               atol=2e-5, rtol=2e-4)
    assert float(jnp.abs(r8 - full).max()) < 0.01   # quantization noise
