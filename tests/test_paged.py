"""Paged KV-cache pool tests: pool-manager accounting, paged-vs-dense
engine equivalence, COW prefix sharing, page-quota queue-on-exhaustion,
page-copy hand-off, monitor occupancy — plus the engine lifecycle
satellites (queue pruning, not-drained signal, in-flight cancel)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import ClusterSpec, Hypervisor
from repro.models import get_model
from repro.runtime import BatchingEngine, GatewayFleet, ServingGateway
from repro.runtime.paged import PagePoolManager


@pytest.fixture(scope="module")
def served_model():
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=n).tolist()


# ---------------------------------------------------------------------------
# PagePoolManager (pure host control plane)
# ---------------------------------------------------------------------------

def test_pool_alloc_free_refcount():
    pool = PagePoolManager(n_pages=9, page_size=4, n_slots=2, max_blocks=8)
    assert pool.total_pages == 8 and pool.free_pages == 8
    plan = pool.admit(0, "a", list(range(10)))       # 3 blocks (pos 0..9)
    assert len(plan.blocks) == 3 and plan.write_start == 0
    assert pool.used_pages == 3 and pool.tenant_pages("a") == 3
    assert list(pool.block_tables[0][:3]) == plan.blocks
    pool.release_slot(0)
    assert pool.used_pages == 0 and pool.tenant_pages("a") == 0
    assert pool.block_tables[0].sum() == 0


def test_pool_prefix_share_and_cow():
    pool = PagePoolManager(n_pages=17, page_size=4, n_slots=3, max_blocks=8)
    toks = list(range(11))                           # 2 full blocks + tail
    a = pool.admit(0, "t", toks)
    b = pool.admit(1, "t", toks)
    # b shares a's 2 full blocks AND the exact-content tail page
    assert b.matched_pages == 3 and b.skip_prefill
    assert b.blocks == a.blocks
    assert pool.used_pages == 3                      # one physical copy
    # write into the shared tail forces a COW detach for the writer
    assert pool.is_shared(0, 2)
    src, dst = pool.cow(0, 2, "t")
    assert src == a.blocks[2] and dst != src
    assert not pool.is_shared(0, 2) and pool.cow_copies == 1
    # a context differing only in its FINAL token still shares the tail:
    # position n-1 is written by decode, not prefill, so written content
    # is identical
    c = pool.admit(2, "t", toks[:-1] + [99])
    assert c.matched_pages == 3 and c.skip_prefill
    # ...but a context differing at a WRITTEN tail position shares only
    # the full blocks
    pool.release_slot(2)
    d = pool.admit(2, "t", toks[:-2] + [99, 10])
    assert d.matched_pages == 2 and not d.skip_prefill
    assert d.blocks[:2] == a.blocks[:2] and d.blocks[2] not in (src, dst)


def test_pool_sharing_is_tenant_scoped():
    pool = PagePoolManager(n_pages=17, page_size=4, n_slots=2, max_blocks=8)
    toks = list(range(9))
    a = pool.admit(0, "alice", toks)
    b = pool.admit(1, "bob", toks)
    assert b.matched_pages == 0
    assert not set(a.blocks) & set(b.blocks)
    assert pool.tenant_pages("alice") == 3 and pool.tenant_pages("bob") == 3


def test_pool_admit_exhaustion_rolls_back_cleanly():
    """admit() hitting NoPagesError mid-allocation must free the pages it
    already popped (and undo shared increfs) — no silent pool shrink."""
    from repro.runtime.paged import NoPagesError
    pool = PagePoolManager(n_pages=5, page_size=4, n_slots=2, max_blocks=8)
    pool.admit(0, "t", list(range(7)))               # 2 of 4 pages
    free_before = pool.free_pages
    with pytest.raises(NoPagesError):
        pool.admit(1, "u", list(range(10)))          # needs 3, only 2 free
    assert pool.free_pages == free_before
    assert pool.tenant_pages("u") == 0


def test_pool_pages_needed_counts_sharing():
    pool = PagePoolManager(n_pages=17, page_size=4, n_slots=2, max_blocks=8)
    toks = list(range(11))
    assert pool.pages_needed("t", toks) == 3
    pool.admit(0, "t", toks)
    assert pool.pages_needed("t", toks) == 0         # fully shareable now
    assert pool.pages_needed("t", toks, share=False) == 3
    assert pool.pages_needed("other", toks) == 3


# ---------------------------------------------------------------------------
# Paged engine == dense engine
# ---------------------------------------------------------------------------

def test_paged_engine_matches_dense(served_model):
    """Same greedy tokens with the page pool as with dense per-slot rows —
    prompt lengths straddle page boundaries (ps=16) and pad buckets."""
    cfg, model, params = served_model
    prompts = [_prompt(cfg, n, seed=n) for n in (2, 5, 15, 16, 17, 31, 33)]

    def serve(**kw):
        eng = BatchingEngine(model, params, n_slots=3, max_len=64, **kw)
        reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
        assert eng.run_until_idle() is True
        assert all(r.finish_reason == "length" for r in reqs)
        return [r.out_tokens for r in reqs]

    assert serve() == serve(paged=True, page_size=16)


def test_paged_engine_int8_pool_matches_dense_int8(served_model):
    """kv_quant engines agree paged-vs-dense (int8 pools + scales page)."""
    cfg, model, params = served_model
    qcfg = cfg.replace(kv_quant=True)
    qmodel = get_model(qcfg)
    prompts = [_prompt(cfg, n, seed=100 + n) for n in (5, 17, 23)]

    def serve(**kw):
        eng = BatchingEngine(qmodel, params, n_slots=2, max_len=64, **kw)
        reqs = [eng.submit(p, max_new_tokens=5) for p in prompts]
        assert eng.run_until_idle() is True
        return [r.out_tokens for r in reqs]

    assert serve() == serve(paged=True, page_size=16)


def test_cow_branches_decode_independently(served_model):
    """Two branches share prompt pages; after one finishes, the survivor
    keeps decoding correct tokens (COW detached its tail page)."""
    cfg, model, params = served_model
    prompt = _prompt(cfg, 34, seed=7)      # 2 full blocks + 1-token tail

    eng = BatchingEngine(model, params, n_slots=2, max_len=64, paged=True,
                        page_size=16)
    short = eng.submit(prompt, max_new_tokens=2, tenant="t")
    long = eng.submit(prompt, max_new_tokens=8, tenant="t")
    assert eng.run_until_idle() is True
    assert eng.pool.stats()["prefix_hits"] >= 3      # 2 full + tail shared
    assert eng.pool.stats()["cow_copies"] >= 1

    solo = BatchingEngine(model, params, n_slots=1, max_len=64)
    ref = solo.submit(prompt, max_new_tokens=8)
    solo.run_until_idle()
    assert long.out_tokens == ref.out_tokens
    assert short.out_tokens == ref.out_tokens[:2]
    # all pages returned once both branches finished
    assert eng.pool.used_pages == 0


def test_page_exhaustion_queues_not_oom(served_model):
    """A pool smaller than the offered load defers admissions (and preempts
    when growth fails) instead of erroring — every request completes."""
    cfg, model, params = served_model
    eng = BatchingEngine(model, params, n_slots=4, max_len=64, paged=True,
                        page_size=16, cache_pages=5)      # 4 usable pages
    reqs = [eng.submit(_prompt(cfg, 20, seed=i), max_new_tokens=20)
            for i in range(4)]
    assert eng.run_until_idle(max_steps=5000) is True
    assert all(len(r.out_tokens) == 20 for r in reqs)
    assert all(r.finish_reason == "length" for r in reqs)


def test_tenant_page_budget_queues(served_model):
    """A tenant at its page budget queues while another tenant's requests
    flow — per-tenant accounting of the shared memory fabric."""
    cfg, model, params = served_model
    eng = BatchingEngine(model, params, n_slots=4, max_len=64, paged=True,
                        page_size=16, cache_pages=17)
    eng.set_tenant_pages("greedy", 2)
    g1 = eng.submit(_prompt(cfg, 20, seed=1), max_new_tokens=4,
                    tenant="greedy")     # needs 2 pages: fills the budget
    g2 = eng.submit(_prompt(cfg, 20, seed=2), max_new_tokens=4,
                    tenant="greedy")     # must wait for g1's pages
    other = eng.submit(_prompt(cfg, 20, seed=3), max_new_tokens=4,
                       tenant="other")
    eng.step()
    assert eng.active_by_tenant() == {"greedy": 1, "other": 1}
    assert eng.queued_by_tenant() == {"greedy": 1}
    assert eng.run_until_idle() is True
    assert all(len(r.out_tokens) == 4 for r in (g1, g2, other))


def test_submit_rejects_impossible_request(served_model):
    cfg, model, params = served_model
    eng = BatchingEngine(model, params, n_slots=2, max_len=64, paged=True,
                        page_size=16, cache_pages=3)      # 2 usable pages
    with pytest.raises(ValueError, match="never be admitted"):
        eng.submit(_prompt(cfg, 40, seed=0), max_new_tokens=20)
    # a big pool doesn't help when the BLOCK TABLE can't hold the context:
    # this must reject at submit, not explode inside step() (regression)
    eng2 = BatchingEngine(model, params, n_slots=2, max_len=64, paged=True,
                         page_size=16, cache_pages=33)    # 32 usable pages
    with pytest.raises(ValueError, match="blocks"):
        eng2.submit(_prompt(cfg, 70, seed=0), max_new_tokens=4)
    ok = eng2.submit(_prompt(cfg, 10, seed=1), max_new_tokens=4)
    assert eng2.run_until_idle() is True
    assert len(ok.out_tokens) == 4


# ---------------------------------------------------------------------------
# Engine lifecycle satellites
# ---------------------------------------------------------------------------

def test_run_until_idle_signals_stall(served_model):
    """max_steps expiring with queued work returns False — a stall is not
    silently mistaken for completion."""
    cfg, model, params = served_model
    eng = BatchingEngine(model, params, n_slots=2, max_len=64)
    for i in range(3):
        eng.submit(_prompt(cfg, 5, seed=i), max_new_tokens=8)
    assert eng.run_until_idle(max_steps=2) is False
    assert eng.run_until_idle() is True


def test_cancel_in_flight_frees_slot_and_pages(served_model):
    """cancel() releases an in-flight request's slot and pool pages
    immediately (a timed-out client must not burn a slot until
    max_new_tokens) and stamps finish_reason."""
    cfg, model, params = served_model
    eng = BatchingEngine(model, params, n_slots=2, max_len=64, paged=True,
                        page_size=16)
    victim = eng.submit(_prompt(cfg, 17, seed=0), max_new_tokens=40)
    other = eng.submit(_prompt(cfg, 5, seed=1), max_new_tokens=4)
    for _ in range(2):
        eng.step()
    assert victim in eng.inflight()
    pages_before = eng.pool.used_pages
    assert eng.cancel(victim) is True
    assert victim.done.is_set() and victim.finish_reason == "cancelled"
    assert victim not in eng.inflight()
    assert eng.pool.used_pages < pages_before
    assert eng.cancel(victim) is False               # already finished
    assert eng.run_until_idle() is True
    assert other.finish_reason == "length"


def test_cancel_queued_request(served_model):
    cfg, model, params = served_model
    eng = BatchingEngine(model, params, n_slots=1, max_len=64)
    first = eng.submit(_prompt(cfg, 5, seed=0), max_new_tokens=3)
    queued = eng.submit(_prompt(cfg, 5, seed=1), max_new_tokens=3)
    assert eng.cancel(queued) is True
    assert queued.finish_reason == "cancelled" and not queued.out_tokens
    assert eng.run_until_idle() is True
    assert first.finish_reason == "length"
    assert eng.queued_by_tenant() == {}              # pruned, not zeroed


def test_finish_reason_eos(served_model):
    cfg, model, params = served_model
    eng = BatchingEngine(model, params, n_slots=1, max_len=64)
    probe = eng.submit(_prompt(cfg, 6, seed=2), max_new_tokens=8)
    eng.run_until_idle()
    eos = probe.out_tokens[0]
    eng2 = BatchingEngine(model, params, n_slots=1, max_len=64, eos_id=eos)
    req = eng2.submit(_prompt(cfg, 6, seed=2), max_new_tokens=8)
    eng2.run_until_idle()
    assert req.finish_reason == "eos"
    assert req.out_tokens == [eos]


# ---------------------------------------------------------------------------
# Control plane: gateway grants, monitor occupancy, fleet hand-off
# ---------------------------------------------------------------------------

def test_gateway_page_grants_and_monitor_occupancy(served_model):
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1,
                                cache_pages_per_device=64))
    gw = ServingGateway(hv, model, params, n_slots=4, max_len=64, paged=True)
    sess = gw.open_session("acme", slots=2)
    vs = hv.db.find_slice(sess.slice_id)
    assert vs.cache_pages == gw._session_page_grant(2)
    assert hv.db.page_grants()                        # device-level metering
    gw.submit("acme", _prompt(cfg, 17, seed=0), max_new_tokens=4)
    gw.step()
    pages = hv.status()["pages"]
    assert pages and next(iter(pages.values()))["used"] > 0
    assert gw.run_until_idle() is True
    gw.close()


def test_fleet_handoff_copies_pages(served_model):
    """A directed migration moves an in-flight request by copying its pool
    pages — decode continues without prefix replay and the final tokens
    match an unmigrated run."""
    cfg, model, params = served_model
    prompt = _prompt(cfg, 20, seed=5)

    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    fl = GatewayFleet(hv, model, params, n_slots=4, max_len=64, paged=True)
    fl.open_session("a", slots=2)
    req = fl.submit("a", prompt, max_new_tokens=12)
    for _ in range(3):
        fl.step()
    prefix = list(req.out_tokens)
    assert hv.migrate_slice(fl.session("a").slice_id,
                            target_device="dev-0-1") is not None
    assert fl.handoffs[-1]["page_copied"] == 1
    assert fl.handoffs[-1]["replayed_inflight"] == 0
    assert fl.run_until_idle() is True
    assert req.out_tokens[:len(prefix)] == prefix

    hv2 = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    fl2 = GatewayFleet(hv2, model, params, n_slots=4, max_len=64, paged=True)
    fl2.open_session("a", slots=2)
    ref = fl2.submit("a", prompt, max_new_tokens=12)
    assert fl2.run_until_idle() is True
    assert req.out_tokens == ref.out_tokens
    fl.close()
    fl2.close()


def test_elastic_page_pressure_scales_out(served_model):
    """A page-pressured device triggers elastic scale-out to a PARKED one;
    the hand-off carries the page-hungriest tenant's traffic."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    fl = GatewayFleet(hv, model, params, n_slots=2, max_len=64, paged=True,
                      cache_pages=9, autoscale_every=1, page_pressure=0.5)
    fl.open_session("big", slots=1)
    fl.open_session("small", slots=1)
    assert len(fl._engines) == 1                     # packed on one device
    fl.submit("big", _prompt(cfg, 33, seed=0), max_new_tokens=16)
    fl.submit("small", _prompt(cfg, 17, seed=1), max_new_tokens=8)
    for _ in range(6):
        fl.step()
    assert len(fl._engines) == 2, "page pressure should wake dev-0-1"
    woke = [e for e in hv.log if e["kind"] == "elastic_page_pressure"]
    assert woke
    assert fl.run_until_idle() is True
    fl.close()
