"""Hostile-tenant scenario suite: seeded adversarial behaviors (prompt
floods, page-pool squatting, cancel/resubmit churn, prefix-cache probing)
run against a well-behaved victim on one shared paged device, asserting

  * the victim's p95 latency stays within a configured fairness bound of
    a solo (attacker-free) run of the bit-identical victim workload, and
    no victim request starves past a patience bound;
  * pool conservation + cross-tenant page disjointness after EVERY step
    (``check_isolation`` inside the runner);
  * zero-on-free at the DEVICE: at teardown every free-list page reads as
    zeros (pos -1, scales 1) through the real caches;
  * the admission token bucket sheds a flood on the injected FakeClock —
    refusals are counted, never wall-clock-dependent;
  * tenant-scoped status views leak nothing about co-tenants while the
    operator views keep the full picture.

Scenario reports are pure functions of (model, seed, behavior): the
determinism test replays one and compares byte-for-byte.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.analysis import sanitizer
from repro.configs import get_config, reduced
from repro.core import ClusterSpec, Hypervisor, MonitorConfig
from repro.models import get_model
from repro.rc2f.admission import DEFAULT_QUOTAS
from repro.runtime.adversary import (HOSTILE, VICTIM, CancelChurn, PageSquat,
                                     PrefixProbe, PromptFlood,
                                     assert_free_pages_zeroed, run_scenario)
from repro.runtime.faults import FakeClock
from repro.runtime.gateway import ServingGateway

# Fairness bound: under ANY of the seeded attacks the victim's p95 may
# not exceed factor x solo-baseline p95 + slack steps (absolute slack
# absorbs the +-1-step quantization of tiny baselines).
FAIRNESS_FACTOR = 2.0
SLACK_STEPS = 6
PATIENCE_STEPS = 40          # no victim request may starve past this


@pytest.fixture(autouse=True)
def _sanitizer_reset():
    sanitizer.reset()
    yield


@pytest.fixture(scope="module")
def served_model():
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def solo_baseline(served_model):
    """The attacker-free run every scenario is judged against."""
    cfg, model, params = served_model
    report = run_scenario(model, params, behavior=None, seed=0)
    assert report.completed.get(VICTIM, 0) > 0
    return report


def test_solo_baseline_sane(solo_baseline):
    r = solo_baseline
    # every victim submission completed (nothing shed, nothing cancelled)
    assert r.completed[VICTIM] == r.submitted[VICTIM]
    assert not r.shed and not r.cancelled
    assert r.max_latency(VICTIM) <= PATIENCE_STEPS
    # the zero-on-free path actually ran and was actually checked: pages
    # were recycled and the teardown read a nonempty free list as zeros
    assert r.pages_scrubbed > 0
    assert r.free_pages_checked > 0


@pytest.mark.parametrize("behavior", [PromptFlood(), PageSquat(),
                                      CancelChurn(), PrefixProbe()],
                         ids=lambda b: b.name)
def test_victim_p95_bounded_under_attack(served_model, solo_baseline,
                                         behavior):
    """The tentpole acceptance gate: per-step isolation invariants hold,
    every victim request completes within the patience bound, and the
    victim's p95 stays within the fairness bound of the solo baseline —
    for every seeded hostile behavior."""
    cfg, model, params = served_model
    r = run_scenario(model, params, behavior=behavior, seed=0)
    assert r.completed[VICTIM] == solo_baseline.submitted[VICTIM], \
        "the attack shed or starved victim requests"
    assert r.max_latency(VICTIM) <= PATIENCE_STEPS
    bound = FAIRNESS_FACTOR * solo_baseline.p95(VICTIM) + SLACK_STEPS
    assert r.p95(VICTIM) <= bound, \
        f"{behavior.name}: victim p95 {r.p95(VICTIM)} exceeds bound " \
        f"{bound} (solo p95 {solo_baseline.p95(VICTIM)})"
    assert r.free_pages_checked > 0


def test_prompt_flood_self_penalizes(served_model, solo_baseline):
    """The flood pays for its prefill length: Mallory's goodput per
    submission collapses (quota + DRR debit shed most of the burst) while
    the victim's completions are untouched."""
    cfg, model, params = served_model
    r = run_scenario(model, params, behavior=PromptFlood(burst=4), seed=1)
    assert r.shed.get(HOSTILE, 0) > 0, "nothing shed — quota not engaged"
    assert r.completed[VICTIM] == r.submitted[VICTIM]
    # the flood cannot buy more than its fair share: victim goodput stays
    # at the baseline's (same seed-derived victim workload cadence)
    assert r.goodput(VICTIM) == pytest.approx(
        solo_baseline.goodput(VICTIM))


def test_page_squat_capped_by_grant(served_model):
    """Squatting saturates Mallory's own vSlice page grant, never the
    victim's: the squat requests queue at the cap (no OOM, no eviction of
    the co-tenant) and the victim still completes everything."""
    cfg, model, params = served_model
    r = run_scenario(model, params, behavior=PageSquat(keep=6), seed=2)
    assert r.completed[VICTIM] == r.submitted[VICTIM]
    assert r.max_latency(VICTIM) <= PATIENCE_STEPS


def test_rate_limit_sheds_flood_on_fake_clock(served_model):
    """Token-bucket admission rate limiting, driven entirely by the
    injected FakeClock (one tick per round): a 4/round flood against a
    1 rps / burst-2 bucket is mostly shed, refusals are counted as
    rate_limited, and the victim (0.25 rps) is never throttled."""
    cfg, model, params = served_model
    quota = dataclasses.replace(DEFAULT_QUOTAS["baas"],
                                rate_limit_rps=1.0, rate_limit_burst=2)
    r = run_scenario(model, params, behavior=PromptFlood(burst=4), seed=0,
                     quota=quota)
    assert r.rate_limited > 0
    assert r.shed.get(HOSTILE, 0) >= r.rate_limited
    # victim submits every fourth round — under the same quota its bucket
    # never empties, so every submission is admitted and completes
    assert not r.shed.get(VICTIM)
    assert r.completed[VICTIM] == r.submitted[VICTIM]


def test_cancel_churn_settles_and_scrubs(served_model):
    """Cancel/resubmit churn: every cancel settles exactly once (the
    runner's per-step pool.verify would catch a double-free) and each
    cancelled request's pages go through the scrub queue — churn makes
    the zero-on-free path HOTTER, not leakier."""
    cfg, model, params = served_model
    r = run_scenario(model, params, behavior=CancelChurn(burst=3), seed=3)
    assert r.cancelled.get(HOSTILE, 0) > 0
    assert r.pages_scrubbed > 0
    assert r.completed[VICTIM] == r.submitted[VICTIM]
    assert r.free_pages_checked > 0


def test_scenario_reports_are_deterministic(served_model):
    """Same (model, seed, behavior) -> byte-identical report: prompts
    come from seeded sub-rngs and time from the FakeClock, so there is
    nothing left to vary."""
    cfg, model, params = served_model
    a = run_scenario(model, params, behavior=CancelChurn(), seed=7,
                     rounds=16)
    b = run_scenario(model, params, behavior=CancelChurn(), seed=7,
                     rounds=16)
    assert dataclasses.asdict(a) == dataclasses.asdict(b)


# ---------------------------------------------------------------------------
# Tenant-scoped status views (satellite: no cross-tenant observability)
# ---------------------------------------------------------------------------

def test_tenant_status_hides_cotenants(served_model):
    """``tenant_status`` (the gateway-facing view) must leak nothing a
    hostile tenant could use to profile a co-resident: no co-tenant
    names, no shared-pool occupancy or scrub totals, no fleet medians.
    The operator views (``stats``/``Monitor.status``) keep it all."""
    cfg, model, params = served_model
    clock = FakeClock()
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1),
                    MonitorConfig(heartbeat_interval_s=1.0,
                                  heartbeat_deadline_s=2.5), clock=clock)
    gw = ServingGateway(hv, model, params, n_slots=4, max_len=64,
                        paged=True, page_size=8)
    gw.open_session(VICTIM, slots=2)
    gw.open_session(HOSTILE, slots=2)
    rng = np.random.default_rng(0)
    reqs = [gw.submit(t, rng.integers(0, cfg.vocab_size, size=6).tolist(),
                      max_new_tokens=4) for t in (VICTIM, HOSTILE)]
    for _ in range(3):
        gw.step()

    ts = gw.tenant_status(VICTIM)
    blob = json.dumps(ts)
    assert HOSTILE not in blob, "tenant view names a co-tenant"
    for sid in ts["slices"]:
        assert hv.db.find_slice(sid).owner == VICTIM
    # the cross-tenant side channels stay operator-only
    for leak in ("median_step_ms", "traffic", "page_grants", "scrub",
                 "utilization"):
        assert leak not in ts
    # but the tenant does see its own session, quota and page holdings
    assert ts["session"]["slots"] == 2
    assert ts["quota"]["inflight"] >= 0
    assert ts["pages_held"] == gw.engine.pool.tenant_pages(VICTIM)

    # operator views keep the full picture
    op = gw.stats()
    assert VICTIM in op and HOSTILE in op
    mon = hv.monitor.status()
    assert "pages" in mon and "scrub" in mon and "median_step_ms" in mon
    owners = {s.owner for d in hv.db.devices.values()
              for s in d.slices.values()}
    assert {VICTIM, HOSTILE} <= owners

    while not all(r.done.is_set() for r in reqs):
        gw.step()
    assert_free_pages_zeroed(gw.engine)
    gw.close()
