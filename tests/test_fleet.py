"""Serving fleet tests: placement that follows the DeviceDB, live session
hand-off on straggler migration (queued + in-flight requests complete on
the target engine, generated tokens preserved, quota balanced), and the
elastic scale-up / park lifecycle."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import ClusterSpec, DeviceState, Hypervisor
from repro.models import get_model
from repro.rc2f import AdmissionError
from repro.runtime import GatewayFleet


@pytest.fixture(scope="module")
def served_model():
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, n=6, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=n).tolist()


def _flag_straggler(hv, hot_slice, cold_slices, n=8):
    """Inject telemetry so exactly ``hot_slice`` trips the straggler policy."""
    for _ in range(n):
        hv.monitor.record_step(hot_slice, 400.0)
        for sid in cold_slices:
            hv.monitor.record_step(sid, 1.0)


# ---------------------------------------------------------------------------
# Placement
# ---------------------------------------------------------------------------

def test_sessions_decode_on_their_slices_device(served_model):
    """One engine per device actually hosting tenants; a tenant's requests
    run on the engine backing its vSlice's device."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    fleet = GatewayFleet(hv, model, params, n_slots=4, max_len=64)
    # 4 + 2 slots overflow the first device: placement must span both
    a = fleet.open_session("a", slots=2)
    b = fleet.open_session("b", slots=2)
    c = fleet.open_session("c", slots=2, service_model="raas")
    devs = {t: hv.db.find_slice(s.slice_id).device_id
            for t, s in (("a", a), ("b", b), ("c", c))}
    assert devs["a"] == devs["b"] != devs["c"]
    assert set(fleet._engines) == set(devs.values())
    for t in ("a", "b", "c"):
        assert fleet.device_of(t) == devs[t]
        fleet.submit(t, _prompt(cfg, seed=ord(t)), max_new_tokens=3)
    fleet.step()
    assert fleet.engine_for("a") is fleet.engine_for("b")
    assert fleet.engine_for("c") is not fleet.engine_for("a")
    assert fleet.engine_for("c").active_by_tenant() == {"c": 1}
    fleet.run_until_idle()
    assert all(s["served"] == 1 for s in fleet.stats().values())
    fleet.close()


def test_fleet_engines_share_one_decode_program(served_model):
    """The decode executable is compiled once; every further engine is a PR
    cache hit binding the same fingerprint."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    fleet = GatewayFleet(hv, model, params, n_slots=4, max_len=64)
    fleet.open_session("a", slots=4, service_model="rsaas")
    fleet.open_session("b", slots=4, service_model="rsaas")
    ups = [e for e in hv.log if e["kind"] == "engine_up"]
    assert len(ups) == 2 and all(u["cache_hit"] for u in ups)
    assert {u["fingerprint"] for u in ups} == {fleet.program_fingerprint}
    fleet.close()


def test_fleet_rejects_ssm_before_any_allocation():
    """The engine-family restriction must surface at construction, not
    from lazy engine creation inside open_session (which would strand an
    admitted tenant and its vSlice)."""
    cfg = reduced(get_config("mamba2-370m")).replace(dtype="float32")
    model = get_model(cfg)
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    with pytest.raises(ValueError, match="attention-family"):
        GatewayFleet(hv, model, model.init(jax.random.PRNGKey(0)))
    assert all(u == 0.0 for u in hv.db.utilization().values())


def test_open_session_failure_unwinds_allocation(served_model, monkeypatch):
    """If anything after the vSlice allocation fails (engine spin-up,
    program swap), open_session must return the quota and the slice."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    fleet = GatewayFleet(hv, model, params, n_slots=2, max_len=64)
    monkeypatch.setattr(fleet, "_ensure_engine",
                        lambda dev: (_ for _ in ()).throw(
                            RuntimeError("device wedged")))
    with pytest.raises(RuntimeError, match="device wedged"):
        fleet.open_session("t", slots=1)
    assert hv.admission.usage("t")["slots"] == 0
    assert all(u == 0.0 for u in hv.db.utilization().values())
    monkeypatch.undo()
    fleet.open_session("t", slots=1)            # clean retry succeeds
    fleet.close()


def test_fleet_empty_prompt_rejected(served_model):
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    fleet = GatewayFleet(hv, model, params, n_slots=2, max_len=64)
    fleet.open_session("t", slots=1)
    with pytest.raises(AdmissionError, match="empty prompt"):
        fleet.submit("t", [], max_new_tokens=4)
    assert hv.admission.usage("t")["inflight"] == 0
    fleet.close()


# ---------------------------------------------------------------------------
# Live migration hand-off
# ---------------------------------------------------------------------------

def test_migrated_tenant_decodes_on_target_engine(served_model):
    """THE fix this PR exists for: after migrate_stragglers flags a serving
    tenant, its subsequent decode steps execute on the TARGET device's
    engine — queued and in-flight requests complete there, the session
    rebinds, and the admission quota stays balanced."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    fleet = GatewayFleet(hv, model, params, n_slots=4, max_len=64)
    hot = fleet.open_session("hot", slots=1)
    cold = fleet.open_session("cold", slots=1)
    old_slice, old_dev = hot.slice_id, fleet.device_of("hot")

    reqs = [fleet.submit("hot", _prompt(cfg, seed=i), max_new_tokens=8)
            for i in range(3)]                 # 1 in flight + 2 queued
    fleet.submit("cold", _prompt(cfg, seed=9), max_new_tokens=8)
    for _ in range(3):
        fleet.step()
    assert reqs[0].out_tokens and not reqs[0].done.is_set()
    mid_tokens = [list(r.out_tokens) for r in reqs]
    assert hv.admission.usage("hot")["inflight"] == 3

    _flag_straggler(hv, hot.slice_id, [cold.slice_id])
    moved = fleet.rebalance()
    assert moved and moved[0][0] == old_slice
    # session rebinds; new slice is on the other device, program carried
    assert hot.slice_id != old_slice
    new_vs = hv.db.find_slice(hot.slice_id)
    assert new_vs.device_id != old_dev
    assert new_vs.program == fleet.program_fingerprint
    assert fleet.handoffs[-1]["moved_requests"] == 3
    # quota survives the hand-off: the 3 requests are still in flight
    assert hv.admission.usage("hot")["inflight"] == 3

    # subsequent decode steps demonstrably run on the target engine
    source, target = fleet._engines[old_dev], fleet._engines[new_vs.device_id]
    steps_before = target.steps
    fleet.step()
    assert target.active_by_tenant().get("hot", 0) == 1
    assert "hot" not in source.active_by_tenant()
    assert "hot" not in source.queued_by_tenant()
    assert target.steps == steps_before + 1

    fleet.run_until_idle()
    assert all(len(r.out_tokens) == 8 for r in reqs)
    # tokens generated before the move survived it (prefix replay)
    for r, mid in zip(reqs, mid_tokens):
        assert r.out_tokens[:len(mid)] == mid
    assert hv.admission.usage("hot")["inflight"] == 0
    assert fleet.session("hot").served == 3
    fleet.close()


def test_handoff_tokens_match_unmigrated_run(served_model):
    """Greedy decode is deterministic: a migrated request must produce
    exactly the tokens it would have produced had it never moved."""
    cfg, model, params = served_model
    prompts = [_prompt(cfg, n=6, seed=i) for i in range(3)]

    def serve(migrate: bool):
        hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
        fleet = GatewayFleet(hv, model, params, n_slots=4, max_len=64)
        hot = fleet.open_session("hot", slots=1)
        cold = fleet.open_session("cold", slots=1)
        reqs = [fleet.submit("hot", p, max_new_tokens=8) for p in prompts]
        fleet.submit("cold", _prompt(cfg, seed=9), max_new_tokens=8)
        for _ in range(3):
            fleet.step()
        if migrate:
            _flag_straggler(hv, hot.slice_id, [cold.slice_id])
            fleet.rebalance()
            assert fleet.handoffs, "migration must have happened"
        fleet.run_until_idle()
        fleet.close()
        return [list(r.out_tokens) for r in reqs]

    assert serve(migrate=True) == serve(migrate=False)


def test_directed_migration_api(served_model):
    """Hypervisor.migrate_slice moves one slice to a named device and the
    fleet hands the dataplane off; target == source is a no-op."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    fleet = GatewayFleet(hv, model, params, n_slots=2, max_len=64)
    t = fleet.open_session("t", slots=1)
    src = fleet.device_of("t")
    assert hv.migrate_slice(t.slice_id, target_device=src) is None
    dst = next(d for d in hv.db.devices if d != src)
    new = hv.migrate_slice(t.slice_id, target_device=dst, reason="ops")
    assert new is not None and new.device_id == dst
    assert fleet.device_of("t") == dst
    fleet.submit("t", _prompt(cfg), max_new_tokens=3)
    fleet.run_until_idle()
    assert fleet.session("t").served == 1
    fleet.close()


def test_cross_class_handoff_reresolves_geometry(served_model):
    """A hand-off between device CLASSES must re-resolve the tuned
    geometry on the destination: the target engine binds ITS class's
    winner from the ProgramCache tuned store (here seeded with two
    deliberately different geometries), pages cut at the source's page
    size are declined by the import guard (prefix replay instead), and
    the token stream stays bit-identical to an unmigrated default run."""
    from repro.tuning import TunedConfig, device_class, model_fingerprint
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2,
                                device_speeds=(1.0, 0.25)))
    fp = model_fingerprint(model.cfg, 64, True)
    hv.reconfig.cache.put_tuned(
        fp, device_class(1.0), TunedConfig(page_size=8,
                                           n_slots=4).to_dict())
    hv.reconfig.cache.put_tuned(
        fp, device_class(0.25), TunedConfig(decode_block_k=256,
                                            page_size=16,
                                            n_slots=2).to_dict())
    fleet = GatewayFleet(hv, model, params, n_slots=2, max_len=64,
                         paged=True, page_size=8, autotune=True)
    t = fleet.open_session("t", slots=1)
    src = fleet.device_of("t")
    assert fleet._engines[src].page_size == 8          # fast-class winner
    req = fleet.submit("t", _prompt(cfg), max_new_tokens=8)
    for _ in range(3):
        fleet.step()
    pre = list(req.out_tokens)
    assert pre and not req.done.is_set()

    dst = next(d for d in hv.db.devices if d != src)
    assert hv.db.devices[src].speed != hv.db.devices[dst].speed
    hv.migrate_slice(t.slice_id, target_device=dst, reason="ops")
    assert fleet.device_of("t") == dst
    # destination bound the 0.25x-class geometry, not the source's
    assert fleet._engines[dst].page_size == 16
    assert fleet._engines[dst].n_slots == 2
    ev = fleet.handoffs[-1]
    assert ev["src_geometry"] != ev["dst_geometry"]
    # page snapshot was cut at ps=8 — the ps=16 pool must decline it and
    # fall back to prefix replay (bit-exact greedy), never adopt raggedly
    assert ev["page_copied"] == 0 and ev["replayed_inflight"] == 1

    fleet.run_until_idle()
    assert req.out_tokens[:len(pre)] == pre            # tokens preserved
    assert len(req.out_tokens) == 8
    fleet.verify_invariants()
    fleet.close()

    # bit-exactness across the migration + both tuned geometries
    hv2 = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    fleet2 = GatewayFleet(hv2, model, params, n_slots=2, max_len=64,
                          paged=True, page_size=8)
    fleet2.open_session("t", slots=1)
    ref = fleet2.submit("t", _prompt(cfg), max_new_tokens=8)
    fleet2.run_until_idle()
    assert list(req.out_tokens) == list(ref.out_tokens)
    fleet2.close()


# ---------------------------------------------------------------------------
# Elastic scale-up / park lifecycle
# ---------------------------------------------------------------------------

def test_scale_up_wakes_parked_device_and_parks_after(served_model):
    """A deep aggregate backlog wakes a PARKED device and moves the
    deepest-queued tenant onto it; once drained and released, every device
    parks again and its engine is dropped."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    fleet = GatewayFleet(hv, model, params, n_slots=2, max_len=64,
                         autoscale_every=1, scale_up_queue_depth=3)
    fleet.open_session("deep", slots=1)
    fleet.open_session("shallow", slots=1)
    assert fleet.device_of("deep") == fleet.device_of("shallow")
    assert hv.db.devices["dev-0-1"].state == DeviceState.PARKED

    reqs = [fleet.submit("deep", _prompt(cfg, seed=i), max_new_tokens=4)
            for i in range(6)]                       # backlog >= threshold
    fleet.submit("shallow", _prompt(cfg, seed=99), max_new_tokens=4)
    fleet.step()                                     # autoscale fires
    assert hv.db.devices["dev-0-1"].state == DeviceState.ACTIVE
    assert fleet.device_of("deep") == "dev-0-1"
    assert fleet.handoffs[-1]["tenant"] == "deep"
    scale_events = [e for e in hv.log if e["kind"] == "elastic_scale_out"]
    assert scale_events

    fleet.run_until_idle()
    assert all(len(r.out_tokens) == 4 for r in reqs)
    fleet.close_session("deep")
    fleet.close_session("shallow")
    # released devices park; the in-step autoscale reaps idle engines
    fleet.step()
    assert all(d.state == DeviceState.PARKED
               for d in hv.db.devices.values())
    assert fleet._engines == {}
    parked = [e for e in hv.log if e["kind"] == "engine_park"]
    assert len(parked) >= 2
    fleet.close()


def test_request_ids_unique_across_engines(served_model):
    """Engines share one fleet-level id stream: the hypervisor audit log
    keys serve events by request id, so ids from different devices must
    never collide."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    fleet = GatewayFleet(hv, model, params, n_slots=4, max_len=64)
    fleet.open_session("a", slots=4, service_model="rsaas")
    fleet.open_session("b", slots=4, service_model="rsaas")
    assert fleet.device_of("a") != fleet.device_of("b")
    reqs = [fleet.submit(t, _prompt(cfg, seed=i), max_new_tokens=3)
            for i, t in enumerate(["a", "b"] * 3)]
    assert len({r.request_id for r in reqs}) == len(reqs)
    fleet.run_until_idle()
    serve_events = {e["request"] for e in hv.log if e["kind"] == "serve"}
    assert len(serve_events) == len(reqs)
    fleet.close()


def test_consolidate_infeasible_moves_nothing(served_model):
    """An infeasible drain is detected by the dry-run placement: no slice
    migrates (no tenant pays a hand-off) and False is returned."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    fleet = GatewayFleet(hv, model, params, n_slots=4, max_len=64)
    a = fleet.open_session("a", slots=2)
    b = fleet.open_session("b", slots=2)
    c = fleet.open_session("c", slots=2, service_model="raas")  # dev 1
    dev0 = fleet.device_of("a")
    assert fleet.device_of("c") != dev0
    # dev 1 has 2 free slots; draining dev 0 needs 4 -> infeasible
    assert not fleet.elastic.consolidate(dev0)
    assert fleet.device_of("a") == fleet.device_of("b") == dev0
    assert not fleet.handoffs
    fleet.close()


def test_consolidate_drains_device_for_parking(served_model):
    """ElasticController.consolidate migrates every slice off a device
    (scale-in); the fleet follows with live hand-offs."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    fleet = GatewayFleet(hv, model, params, n_slots=4, max_len=64)
    a = fleet.open_session("a", slots=4,             # fills dev 0
                           service_model="rsaas")
    b = fleet.open_session("b", slots=2)             # spills to dev 1
    dev_b = fleet.device_of("b")
    fleet.submit("b", _prompt(cfg), max_new_tokens=6)
    fleet.step()
    assert not fleet.elastic.consolidate(fleet.device_of("a")), \
        "a's 4-slot slice cannot fit next to b"
    fleet.close_session("a")
    assert fleet.elastic.consolidate(dev_b)          # b moves to dev 0
    assert fleet.device_of("b") != dev_b
    fleet.run_until_idle()
    assert fleet.session("b").served == 1
    fleet.park_idle_engines()
    assert list(fleet._engines) == [fleet.device_of("b")]
    fleet.close()


# ---------------------------------------------------------------------------
# Autoscale arbitration (one action per tick), SLO projection, down-ramp
# ---------------------------------------------------------------------------

def test_autoscale_one_action_when_multiple_signals_trip(served_model):
    """Regression: a burst wave trips queue depth AND page pressure on
    the same autoscale tick. Arbitration must act on exactly ONE signal —
    waking two devices for one overload would oscillate against the
    energy policy. With both signals hot and two PARKED devices
    available, one call wakes exactly one device."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=3))
    fleet = GatewayFleet(hv, model, params, n_slots=2, max_len=64,
                         paged=True, page_size=4,
                         scale_up_queue_depth=2, page_pressure=0.8)
    fleet.open_session("a", slots=1)
    fleet.open_session("b", slots=1)
    dev0 = fleet.device_of("a")
    assert fleet.device_of("b") == dev0
    for i in range(8):                       # deep backlog: queue depth trips
        fleet.submit("a", _prompt(cfg, seed=i), max_new_tokens=4)
    hv.monitor.record_pages(dev0, 95, 100)   # page pressure trips too

    active_before = len([d for d in hv.db.devices.values()
                         if d.state == DeviceState.ACTIVE])
    woken = fleet.autoscale()
    active_after = len([d for d in hv.db.devices.values()
                        if d.state == DeviceState.ACTIVE])
    assert woken is not None
    assert active_after == active_before + 1, \
        "both signals tripped but exactly one device may wake per tick"
    assert len(fleet.autoscale_log) == 1
    assert fleet.autoscale_log[0]["signal"] == "queue_depth"
    fleet.run_until_idle()
    fleet.close()


def test_autoscale_slo_projection_wakes_before_queue_threshold(served_model):
    """The SLO signal acts on the arrival/service-rate TREND: with a
    backlog far below the queue-depth threshold but arrivals outrunning
    measured service capacity, the projected p95 breaches the SLO and a
    PARKED device wakes (signal = slo_projection)."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    fleet = GatewayFleet(hv, model, params, n_slots=2, max_len=64,
                         scale_up_queue_depth=100,    # queue depth never trips
                         slo_p95_steps=8.0, slo_horizon=16)
    fleet.open_session("a", slots=1)
    fleet.open_session("b", slots=1)
    for i in range(4):                 # shallow backlog, but a real queue
        fleet.submit("a", _prompt(cfg, seed=i), max_new_tokens=4)
    # trend: 4 arrivals/step against 1 completion/device-step on 1 device
    for _ in range(8):
        hv.monitor.record_traffic(4, 1, 1)
    projected = fleet.elastic.projected_p95_steps(2, 16)
    assert projected is not None and projected > 8.0

    woken = fleet.autoscale()
    assert woken is not None
    assert fleet.autoscale_log[-1]["signal"] == "slo_projection"
    assert [e for e in hv.log if e["kind"] == "elastic_slo_scale_out"]
    assert hv.db.devices[woken].state == DeviceState.ACTIVE
    fleet.run_until_idle()
    fleet.close()


def test_autoscale_slo_quiet_trend_no_wake(served_model):
    """Under-SLO projection must NOT wake anything: same queue, but the
    measured service rate comfortably covers the arrivals."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    fleet = GatewayFleet(hv, model, params, n_slots=2, max_len=64,
                         scale_up_queue_depth=100,
                         slo_p95_steps=50.0, slo_horizon=4)
    fleet.open_session("a", slots=1)
    fleet.submit("a", _prompt(cfg), max_new_tokens=4)
    for _ in range(8):
        hv.monitor.record_traffic(1, 2, 1)   # mu covers lambda twice over
    assert fleet.autoscale() is None
    assert hv.db.devices["dev-0-1"].state == DeviceState.PARKED
    fleet.run_until_idle()
    fleet.close()


def test_downramp_consolidates_in_draw_order(served_model):
    """Diurnal down-ramp: with the backlog gone and the projection under
    margin, autoscale drains ONE device per tick, highest class draw
    first (3.0 parks before 2.0), re-packing tenants onto the cheap
    device; post-trough requests still complete within the SLO."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=3,
                                device_draws=(1.0, 3.0, 2.0)))
    fleet = GatewayFleet(hv, model, params, n_slots=4, max_len=64,
                         slo_p95_steps=20.0)
    for t in ("a", "b", "c", "d"):
        fleet.open_session(t, slots=1)
    assert len(fleet._engines) == 1          # pack-first: all on dev-0-0
    # burst half: spread the fleet across all three devices
    for t in ("a", "b"):
        assert fleet.elastic.scale_out(fleet.session(t).slice_id)
    assert len(set(fleet.device_of(t) for t in "abcd")) == 3
    assert hv.db.devices["dev-0-1"].draw == 3.0

    # trough: no queue, no trend -> one drain per tick, draw order
    drained1 = fleet._maybe_scale_in()
    assert drained1 == "dev-0-1", "the 3.0-draw device must park first"
    assert hv.db.devices["dev-0-1"].state == DeviceState.PARKED
    drained2 = fleet._maybe_scale_in()
    assert drained2 == "dev-0-2", "the 2.0-draw device parks second"
    assert fleet._maybe_scale_in() is None   # min_active floor holds
    assert [e["device"] for e in fleet.autoscale_log
            if e["action"] == "scale_in"] == ["dev-0-1", "dev-0-2"]
    assert all(fleet.device_of(t) == "dev-0-0" for t in "abcd")

    # through the trough the survivors still serve within the SLO
    start = fleet.steps
    reqs = [fleet.submit(t, _prompt(cfg, seed=ord(t)), max_new_tokens=4)
            for t in "abcd"]
    assert fleet.run_until_idle()
    assert all(len(r.out_tokens) == 4 for r in reqs)
    assert fleet.steps - start <= 20, "post-consolidation p95 within SLO"
    fleet.close()


def test_downramp_blocked_while_projection_above_margin(served_model):
    """Scale-in must NOT fire while the projected p95 sits above the
    scale-in margin — consolidating into a still-warm ramp would bounce
    straight back out."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    fleet = GatewayFleet(hv, model, params, n_slots=4, max_len=64,
                         slo_p95_steps=10.0, scale_in_margin=0.5)
    fleet.open_session("a", slots=1)
    fleet.open_session("b", slots=1)
    assert fleet.elastic.scale_out(fleet.session("a").slice_id)
    assert len(fleet._engines) == 2
    for _ in range(8):                       # projection ~ lambda*h/mu = 8
        hv.monitor.record_traffic(1, 1, 2)   # > margin (5) but under SLO
    assert fleet._maybe_scale_in() is None
    assert len(fleet._engines) == 2
    fleet.close()
