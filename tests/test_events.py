"""Event-driven dataplane tests: the deterministic event queue (stable
(time, seq) tie-breaking, clock ownership), chunked-prefill parity with
the synchronous engine, lockstep-vs-event fleet token exactness on a
mixed-speed fleet, the batched-journal flush barrier, overlapped live
hand-off (source keeps decoding during the page copy), and the satellite
regressions (autoscale ignores draining backlog; dead-device traffic
windows are swept)."""
import jax
import numpy as np
import pytest

from repro.analysis import sanitizer
from repro.configs import get_config, reduced
from repro.core import ClusterSpec, DeviceState, Hypervisor, MonitorConfig
from repro.models import get_model
from repro.runtime import BatchingEngine, EventLoop, GatewayFleet
from repro.runtime.events import EventQueue
from repro.runtime.faults import FakeClock


@pytest.fixture(autouse=True)
def _sanitized():
    sanitizer.reset()
    sanitizer.enable()
    yield
    sanitizer.disable()


@pytest.fixture(scope="module")
def served_model():
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, n=6, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=n).tolist()


# ---------------------------------------------------------------------------
# EventQueue: ordering, clock ownership, cancellation
# ---------------------------------------------------------------------------

def test_event_queue_orders_by_time_then_schedule():
    """Equal-time events fire strictly in schedule order — firing order is
    a pure function of the schedule, never of heap internals."""
    q = EventQueue()
    fired = []
    q.at(2.0, lambda: fired.append("late"))
    q.at(1.0, lambda: fired.append("a"))
    q.at(1.0, lambda: fired.append("b"))
    q.after(0.0, lambda: fired.append("now"))
    while q.step() is not None:
        pass
    assert fired == ["now", "a", "b", "late"]
    assert q.clock() == 2.0 and q.fired == 4


def test_event_queue_owns_the_clock():
    """Popping an event advances the shared clock to its time; scheduling
    in the past clamps to now (the past is not schedulable)."""
    clock = FakeClock()
    clock.t = 10.0
    q = EventQueue(clock)
    ev = q.at(3.0, lambda: None)
    assert ev.time == 10.0                      # clamped to now
    q.at(12.5, lambda: None)
    q.run()
    assert clock() == 12.5


def test_event_queue_cancellation_is_lazy_and_invisible():
    """Cancelled events are skipped at pop time without perturbing the
    ordering (or the clock advancement) of live events."""
    q = EventQueue()
    fired = []
    keep = q.at(1.0, lambda: fired.append("keep"))
    drop = q.at(0.5, lambda: fired.append("drop"))
    q.cancel(drop)
    assert len(q) == 1 and q.peek() is keep
    q.run()
    assert fired == ["keep"] and q.clock() == 1.0


def test_event_queue_run_until_leaves_clock_at_horizon():
    q = EventQueue()
    fired = []
    q.at(1.0, lambda: fired.append(1))
    q.at(5.0, lambda: fired.append(5))
    assert q.run(until=3.0) == 1
    assert fired == [1] and q.clock() == 3.0    # horizon, not last event
    q.run()
    assert fired == [1, 5]


def test_event_queue_firing_order_deterministic():
    def one_run():
        order = []
        q = EventQueue()
        for i, t in enumerate([2.0, 1.0, 1.0, 0.5, 2.0, 1.0]):
            q.at(t, lambda i=i: order.append(i), kind=f"e{i}")
        q.run()
        return order
    assert one_run() == one_run()


# ---------------------------------------------------------------------------
# Chunked prefill: step_async is token-exact with the sync engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True],
                         ids=["dense", "paged"])
def test_chunked_prefill_matches_sync_engine(served_model, paged):
    """step_async (chunked prefill interleaved with decode) must produce
    bit-identical token streams to the synchronous engine — including on
    recycled KV pages, where stale position metadata once leaked previous
    occupants' K/V into attention."""
    cfg, model, params = served_model

    def run(mode):
        sanitizer.reset()
        eng = BatchingEngine(model, params, n_slots=4, max_len=64,
                             paged=paged)
        reqs = [eng.submit(_prompt(cfg, 5 + i % 3, seed=100 + i), 8,
                           tenant=f"t{i % 2}") for i in range(6)]
        for _ in range(400):
            eng.step() if mode == "sync" else eng.step_async(prefill_chunk=4)
            if all(r.done.is_set() for r in reqs):
                break
        assert all(r.done.is_set() for r in reqs)
        return [list(r.out_tokens) for r in reqs]

    assert run("sync") == run("async")


# ---------------------------------------------------------------------------
# EventLoop: fleet-level parity, cadence, flush barrier, overlapped hand-off
# ---------------------------------------------------------------------------

def _mixed_fleet(model, params, speeds=(1.0, 1.0, 1.0, 0.25), **kw):
    hv = Hypervisor(ClusterSpec(n_nodes=len(speeds), devices_per_node=1,
                                device_speeds=tuple(speeds)),
                    MonitorConfig(heartbeat_interval_s=1.0,
                                  heartbeat_deadline_s=2.5),
                    clock=FakeClock())
    fleet = GatewayFleet(hv, model, params, n_slots=4, max_len=64, **kw)
    return hv, fleet


def test_event_loop_matches_lockstep_on_mixed_speeds(served_model):
    """Device speed changes the event SCHEDULE, never the tokens: a fleet
    with a 4x-slower device produces the same per-request streams under
    the event loop as under the lockstep barrier."""
    cfg, model, params = served_model

    def run(loop):
        sanitizer.reset()
        hv, fleet = _mixed_fleet(model, params, paged=True)
        reqs = {}
        for ti in range(4):
            fleet.open_session(f"t{ti}", slots=4, service_model="rsaas")
            for k in range(2):
                reqs[(ti, k)] = fleet.submit(
                    f"t{ti}", _prompt(cfg, 5 + ti, seed=10 * ti + k),
                    max_new_tokens=8)
        ev = EventLoop(fleet) if loop == "event" else None
        for _ in range(400):
            fleet.step() if ev is None else ev.run_ticks(1)
            fleet.verify_invariants()
            if all(r.done.is_set() for r in reqs.values()):
                break
        assert all(r.done.is_set() for r in reqs.values())
        toks = {k: list(r.out_tokens) for k, r in reqs.items()}
        fleet.close()
        return toks

    assert run("lockstep") == run("event")


def test_slow_device_steps_on_its_own_cadence(served_model):
    """Four always-busy engines under the event loop: each device fires
    ~speed x ticks engine events — the slow class runs at quarter rate
    WITHOUT gating the rest (fast devices still step every tick)."""
    cfg, model, params = served_model
    speeds = {"dev-0-0": 1.0, "dev-1-0": 1.0, "dev-2-0": 1.0,
              "dev-3-0": 0.25}
    hv, fleet = _mixed_fleet(model, params)
    reqs = []
    for ti in range(4):
        fleet.open_session(f"t{ti}", slots=4, service_model="rsaas")
        reqs.append(fleet.submit(f"t{ti}", _prompt(cfg, 7, seed=ti),
                                 max_new_tokens=40))
    assert len(fleet._engines) == 4             # one tenant per device
    ev = EventLoop(fleet)
    ticks = 24
    ev.run_ticks(ticks)
    for dev, eng in fleet._engines.items():
        assert abs(eng.steps / ticks - speeds[dev]) <= 0.2, \
            f"{dev}: {eng.steps} steps in {ticks} ticks"
    assert ev.run_until_idle(max_ticks=2000)
    assert all(r.done.is_set() for r in reqs)
    fleet.close()


def test_journal_flush_barrier(served_model):
    """Lazy journal mode: engine steps only MARK entries dirty — the token
    copy happens on the loop's flush cadence, and the retire path forces a
    per-request flush so a settled entry is never stale."""
    cfg, model, params = served_model
    hv, fleet = _mixed_fleet(model, params, speeds=(1.0,))
    fleet.open_session("t", slots=2)
    req = fleet.submit("t", _prompt(cfg, 5), max_new_tokens=12)
    ev = EventLoop(fleet, flush_every=10_000)   # periodic flush never fires
    ev.run_ticks(6)
    entry = fleet.journal[req.request_id]
    assert req.out_tokens                        # decode made progress...
    assert entry.tokens == []                    # ...but the copy is batched
    assert req.request_id in fleet._dirty
    fleet.flush_journal()
    assert entry.tokens == list(req.out_tokens) and not fleet._dirty
    assert ev.run_until_idle()
    # the finish settle flushed-then-retired: no dirty orphan, quota clean
    assert req.request_id not in fleet.journal
    assert req.request_id not in fleet._dirty
    assert hv.admission.usage("t")["inflight"] == 0
    fleet.close()


def test_overlapped_handoff_source_decodes_during_copy(served_model):
    """A directed migration under the event loop exports the snapshot
    immediately but keeps decoding on the source for the copy window;
    adoption catches up the mid-copy tokens and the final streams are
    bit-exact with an unmigrated run."""
    cfg, model, params = served_model

    def run(migrate):
        sanitizer.reset()
        hv, fleet = _mixed_fleet(model, params, speeds=(1.0, 1.0),
                                 paged=True)
        sess = fleet.open_session("t", slots=2)
        reqs = [fleet.submit("t", _prompt(cfg, 5 + i, seed=i),
                             max_new_tokens=24) for i in range(3)]
        ev = EventLoop(fleet, copy_ticks=2)
        ev.run_ticks(4)
        if migrate:
            src = fleet.device_of("t")
            dst = next(d for d in sorted(hv.db.devices) if d != src)
            before = [len(r.out_tokens) for r in reqs]
            hv.migrate_slice(sess.slice_id, target_device=dst,
                             reason="ops")
            assert fleet._inflight_handoffs     # copy is in flight...
            ev.run_ticks(1)                     # ...and the source still
            after = [len(r.out_tokens) for r in reqs]       # decodes
            assert sum(after) > sum(before)
        assert ev.run_until_idle()
        assert all(r.done.is_set() for r in reqs)
        if migrate:
            ho = fleet.handoffs[-1]
            assert ho["overlapped"] is True and ho["moved_requests"] > 0
            assert fleet.device_of("t") == dst
            assert not fleet._inflight_handoffs and not fleet._draining
        toks = [list(r.out_tokens) for r in reqs]
        fleet.close()
        return toks

    assert run(migrate=True) == run(migrate=False)


# ---------------------------------------------------------------------------
# Satellite regressions
# ---------------------------------------------------------------------------

def test_autoscale_ignores_draining_device_backlog(served_model):
    """Backlog queued on a hand-off source mid-copy is already on its way
    elsewhere: counting it would wake a device for traffic that is about
    to move (the wake/park flap). Once the copy completes, the same
    backlog counts again."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    fleet = GatewayFleet(hv, model, params, n_slots=1, max_len=64,
                         scale_up_queue_depth=3)
    fleet.open_session("t", slots=1)
    dev = fleet.device_of("t")
    for i in range(6):                          # deep backlog: 5 queued
        fleet.submit("t", _prompt(cfg, seed=i), max_new_tokens=4)
    assert hv.db.devices["dev-0-1"].state == DeviceState.PARKED

    fleet._handoff_begun(dev)                   # source mid-copy: draining
    assert fleet.autoscale() is None
    assert hv.db.devices["dev-0-1"].state == DeviceState.PARKED
    assert not fleet.autoscale_log

    fleet._handoff_done(dev)                    # copy done: backlog counts
    assert fleet.autoscale() == "dev-0-1"
    assert fleet.autoscale_log[-1]["signal"] == "queue_depth"
    fleet.run_until_idle()
    fleet.close()


def test_dead_device_sweep_clears_traffic_windows():
    """Per-device traffic windows must die with the device: the heartbeat
    sweep drops the dead node's device samples (so churn can never grow
    the windows) while survivors keep theirs."""
    clock = FakeClock()
    hv = Hypervisor(ClusterSpec(n_nodes=2, devices_per_node=1),
                    MonitorConfig(heartbeat_interval_s=1.0,
                                  heartbeat_deadline_s=2.5),
                    clock=clock)
    mon = hv.monitor
    mon.record_traffic(4, 3, 2, by_device={"dev-0-0": 2, "dev-1-0": 1})
    assert mon.device_completion_rate("dev-1-0") is not None
    clock.t = 3.0                               # node-1 misses its deadline
    mon.heartbeat("node-0")
    mon.check_heartbeats()
    assert not hv.db.nodes["node-1"].alive
    assert mon.device_completion_rate("dev-1-0") is None
    assert mon.device_completion_rate("dev-0-0") is not None
