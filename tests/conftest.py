import os
import sys
import types

# tests run on the single real CPU device (the dry-run sets its own flags in
# a subprocess); keep compilation deterministic and quiet
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------------------
# hypothesis fallback: several test modules use property-based tests. When
# hypothesis is unavailable (it is not baked into the runtime image), install
# a stub so collection succeeds and @given tests skip instead of erroring.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    import pytest

    class _Anything:
        """Stands in for strategy builders: any call/attr returns itself."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    def _given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(fn)
        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    stub = types.ModuleType("hypothesis")
    stub.given = _given
    stub.settings = _settings
    stub.strategies = _Anything()
    stub.__version__ = "0.0-stub"
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = stub.strategies
