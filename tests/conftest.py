import os
import sys

# tests run on the single real CPU device (the dry-run sets its own flags in
# a subprocess); keep compilation deterministic and quiet
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
