"""Fault-tolerance drills: heartbeat failure -> migration/requeue,
straggler re-placement, elastic resize, checkpoint/restart continuation."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import available_steps, latest_step, reshard, restore, save
from repro.configs import get_config, reduced
from repro.core import (ClusterSpec, DeviceState, ElasticController,
                        Hypervisor, JobState, MonitorConfig, SliceState)
from repro.data import DataConfig, DataPipeline
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.runtime import TrainOpts, init_train_state, make_train_step


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_failure_requeues_jobs():
    clock = FakeClock()
    hv = Hypervisor(ClusterSpec(n_nodes=2, devices_per_node=1),
                    MonitorConfig(heartbeat_deadline_s=10), clock=clock)
    job = hv.scheduler.submit("u", 4, run=None)
    hv.scheduler.schedule_once()
    assert job.state == JobState.RUNNING
    dead_node = hv.db.devices[hv.db.find_slice(job.slice_id).device_id].node_id
    # all nodes heartbeat at t=0; the job's node then goes silent
    for n in hv.db.nodes:
        hv.monitor.heartbeat(n)
    clock.t = 8.0
    for n in hv.db.nodes:
        if n != dead_node:
            hv.monitor.heartbeat(n)
    clock.t = 15.0
    orphans = hv.handle_failures()
    assert orphans and not hv.db.nodes[dead_node].alive
    assert job.state == JobState.REQUEUED
    # rescheduling lands on the surviving node
    hv.scheduler.schedule_once()
    assert job.state == JobState.RUNNING
    new_node = hv.db.devices[hv.db.find_slice(job.slice_id).device_id].node_id
    assert new_node != dead_node


def test_dead_node_sweep_clears_monitor_state():
    """Regression: the dead-device sweep used to leave the Monitor's
    step-telemetry and page-occupancy entries stale — a dead slice kept
    feeding the fleet median and a dead pool stayed 'page-pressured'
    forever."""
    clock = FakeClock()
    hv = Hypervisor(ClusterSpec(n_nodes=2, devices_per_node=1),
                    MonitorConfig(heartbeat_deadline_s=10), clock=clock)
    vs = hv.allocate_vslice("t", 1)
    dead_node = hv.db.devices[vs.device_id].node_id
    for _ in range(4):
        hv.monitor.record_step(vs.slice_id, 400.0)
    hv.monitor.record_pages(vs.device_id, 7, 8)
    assert hv.monitor.find_page_pressure()
    for n in hv.db.nodes:
        hv.monitor.heartbeat(n)
    clock.t = 8.0
    for n in hv.db.nodes:
        if n != dead_node:
            hv.monitor.heartbeat(n)
    clock.t = 15.0
    assert vs.slice_id in hv.handle_failures()
    assert vs.slice_id not in hv.monitor._step_times
    assert hv.monitor.median_step_ms() is None
    assert vs.device_id not in hv.monitor.page_occupancy()
    assert not hv.monitor.find_page_pressure()
    assert not hv.monitor.find_stragglers()


def test_device_failure_is_device_granular():
    """mark_device_failed kills ONE device: its node survives, its sibling
    devices keep serving, its batch jobs requeue, and its telemetry is
    cleared exactly like the node-death path."""
    clock = FakeClock()
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2), clock=clock)
    job = hv.scheduler.submit("u", 1, run=None)
    hv.scheduler.schedule_once()
    sid = job.slice_id
    dev = hv.db.find_slice(sid).device_id
    hv.monitor.record_step(sid, 50.0)
    hv.monitor.record_pages(dev, 3, 8)
    orphans = hv.mark_device_failed(dev, reason="status_error")
    assert orphans == [sid]
    assert hv.db.devices[dev].state == DeviceState.DEAD
    assert hv.db.nodes["node-0"].alive                    # node survives
    assert job.state == JobState.REQUEUED
    assert sid not in hv.monitor._step_times
    assert dev not in hv.monitor.page_occupancy()
    assert any(e["kind"] == "device_dead" for e in hv.monitor.events)
    # rescheduling lands on the surviving sibling device
    hv.scheduler.schedule_once()
    assert job.state == JobState.RUNNING
    assert hv.db.find_slice(job.slice_id).device_id != dev


def test_straggler_migration():
    clock = FakeClock()
    hv = Hypervisor(ClusterSpec(n_nodes=2, devices_per_node=1),
                    MonitorConfig(straggler_factor=1.5, straggler_patience=3),
                    clock=clock)
    fast = hv.allocate_vslice("fast", 1)
    slow = hv.allocate_vslice("slow", 1)
    for _ in range(8):
        hv.monitor.record_step(fast.slice_id, 100.0)
        hv.monitor.record_step(slow.slice_id, 400.0)
    moved = hv.migrate_stragglers()
    assert len(moved) == 1
    new = hv.db.find_slice(moved[0])
    assert new.owner == "slow"
    assert new.device_id != slow.device_id
    with pytest.raises(KeyError):
        hv.db.find_slice(slow.slice_id)   # old slice released


def test_failed_directed_migration_restores_prior_state():
    """migrate_slice with no room elsewhere must leave the slice in its
    ORIGINAL state — a never-executed slice must not come back RUNNING."""
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    vs = hv.allocate_vslice("t", 1)                  # ALLOCATED, never ran
    hv.allocate_vslice("hog", 4)                     # fills the other device
    assert hv.migrate_slice(vs.slice_id) is None
    assert hv.db.find_slice(vs.slice_id).state == SliceState.ALLOCATED


def test_elastic_resize_carries_program():
    hv = Hypervisor(ClusterSpec(n_nodes=2, devices_per_node=2))
    ec = ElasticController(hv)
    vs = hv.allocate_vslice("u", 1)
    hv.db.set_slice_state(vs.slice_id, SliceState.CONFIGURED, program="abc")
    new = ec.resize("u", 4)
    assert len(new) == 1 and new[0].slots == 4
    assert new[0].program == "abc"
    assert len(hv.db.slices_of("u")) == 1


def _train_setup(tmp_path, lr=1e-3):
    cfg = reduced(get_config("smollm-135m")).replace(
        dtype="float32", vocab_size=256)
    m = get_model(cfg)
    opts = TrainOpts(opt=AdamWConfig(lr=lr, warmup_steps=2, total_steps=50),
                     loss_chunk=16)
    state = init_train_state(m, jax.random.PRNGKey(0), opts)
    step = jax.jit(make_train_step(m, opts))
    dp = DataPipeline(DataConfig(vocab_size=256, seq_len=32, batch_size=4))
    return m, opts, state, step, dp


def test_checkpoint_restart_bitexact(tmp_path):
    """Train 6 steps straight vs 3 + crash + restore + 3: identical state."""
    d = str(tmp_path / "ckpt")
    m, opts, state, step, dp = _train_setup(tmp_path)
    # run A: straight through
    sa = state
    for i in range(6):
        sa, _ = step(sa, dp.batch_at(i))
    # run B: crash after 3, restore, resume (data pipeline is step-addressed)
    sb = state
    for i in range(3):
        sb, _ = step(sb, dp.batch_at(i))
    save(sb, d, step=3)
    del sb
    restored, at = restore(d, jax.eval_shape(lambda: state))
    assert at == 3
    for i in range(3, 6):
        restored, _ = step(restored, dp.batch_at(i))
    for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_atomicity(tmp_path):
    d = str(tmp_path / "ckpt")
    state = {"w": jnp.arange(8.0)}
    for s in range(5):
        save({"w": jnp.arange(8.0) + s}, d, step=s, keep=2)
    assert available_steps(d) == [3, 4]
    got, s = restore(d, state)
    assert s == 4
    np.testing.assert_allclose(np.asarray(got["w"]), np.arange(8.0) + 4)


def test_checkpoint_structure_mismatch_rejected(tmp_path):
    d = str(tmp_path / "ckpt")
    save({"w": jnp.ones(4)}, d, step=0)
    with pytest.raises(ValueError):
        restore(d, {"w": jnp.ones(4), "extra": jnp.ones(2)})


def test_elastic_reshard_roundtrip():
    """Checkpoint trained on mesh A restores onto a different layout."""
    from jax.sharding import Mesh, PartitionSpec as P
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    state = {"w": jnp.arange(16.0).reshape(4, 4), "b": jnp.ones(4)}
    specs = {"w": P(None, None), "b": P(None)}
    moved = reshard(state, mesh, specs)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(moved)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
