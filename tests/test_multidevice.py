"""Multi-device behaviours, run in subprocesses with 8 forced host devices:
elastic mesh shrink mid-training (checkpoint -> reshard -> continue),
int8-compressed DP gradient exchange across real shards, SpatialShell
sub-meshes. These prove the distribution logic with actual device counts,
not just compile-time sharding."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, cwd=ROOT, timeout=560)
    assert proc.returncode == 0, proc.stderr[-2500:]
    return proc.stdout


HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
"""


def test_elastic_shrink_mid_training():
    """Train on 8-way DP, checkpoint, 'lose' 4 devices, reshard to 4-way DP,
    continue — loss keeps falling and state is numerically continued."""
    out = _run(HEADER + textwrap.dedent("""
        from repro.configs import get_config, reduced
        from repro.models import get_model
        from repro.optim import AdamWConfig
        from repro.runtime import TrainOpts, init_train_state, make_train_step
        from repro.runtime.sharding import batch_specs, named, param_specs
        from repro.ckpt import reshard, restore, save
        from repro.data import DataConfig, DataPipeline
        import tempfile

        cfg = reduced(get_config("smollm-135m")).replace(dtype="float32",
                                                         vocab_size=256)
        model = get_model(cfg)
        opts = TrainOpts(opt=AdamWConfig(lr=2e-3, warmup_steps=2,
                                         total_steps=40), loss_chunk=16)
        step = jax.jit(make_train_step(model, opts))
        data = DataPipeline(DataConfig(vocab_size=256, seq_len=32,
                                       batch_size=8))

        def mesh_of(n):
            return Mesh(np.array(jax.devices()[:n]).reshape(n, 1),
                        ("data", "model"))

        state = init_train_state(model, jax.random.PRNGKey(0), opts)
        state_specs = jax.tree.map(lambda _: P(), state)

        big = mesh_of(8)
        state = jax.device_put(state, NamedSharding(big, P()))
        losses = []
        with big:
            for i in range(5):
                b = jax.device_put(
                    data.batch_at(i),
                    NamedSharding(big, P("data", None)))
                state, m = step(state, b)
                losses.append(float(m["loss"]))
        d = tempfile.mkdtemp()
        save(state, d, step=5)

        # cluster shrinks to 4 devices: restore + reshard + continue
        small = mesh_of(4)
        restored, at = restore(d, jax.eval_shape(lambda: state))
        restored = reshard(restored, small, state_specs)
        with small:
            for i in range(5, 10):
                b = jax.device_put(
                    data.batch_at(i),
                    NamedSharding(small, P("data", None)))
                restored, m = step(restored, b)
                losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        assert int(restored["step"]) == 10
        print("OK", [round(x, 3) for x in losses])
    """))
    assert "OK" in out


def test_compressed_dp_training_across_shards():
    """shard_map DP with int8+error-feedback gradient exchange on 8 real
    shards: loss falls, and matches uncompressed within tolerance."""
    out = _run(HEADER + textwrap.dedent("""
        from repro.configs import get_config, reduced
        from repro.models import get_model
        from repro.optim import AdamWConfig
        from repro.runtime import TrainOpts, init_train_state
        from repro.runtime.train import make_dp_train_step
        from repro.data import DataConfig, DataPipeline

        cfg = reduced(get_config("smollm-135m")).replace(dtype="float32",
                                                         vocab_size=256)
        model = get_model(cfg)
        mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
        data = DataPipeline(DataConfig(vocab_size=256, seq_len=32,
                                       batch_size=8))

        def train(compress, steps=8):
            opts = TrainOpts(opt=AdamWConfig(lr=2e-3, warmup_steps=2,
                                             total_steps=40),
                             loss_chunk=16, compress_grads=compress)
            state = init_train_state(model, jax.random.PRNGKey(0), opts)
            step = make_dp_train_step(model, mesh, opts)
            losses = []
            for i in range(steps):
                state, m = step(state, data.batch_at(i))
                losses.append(float(m["loss"]))
            return losses

        lc = train(True)
        lu = train(False)
        assert lc[-1] < lc[0], lc
        # int8+EF tracks the uncompressed trajectory closely
        assert abs(lc[-1] - lu[-1]) < 0.25 * lu[0], (lc[-1], lu[-1])
        print("OK compressed", [round(x,3) for x in lc[-3:]],
              "uncompressed", [round(x,3) for x in lu[-3:]])
    """))
    assert "OK" in out


def test_spatial_shell_submeshes():
    """SpatialShell carves a physical device set into per-slot sub-meshes
    and runs isolated cores on each."""
    out = _run(HEADER + textwrap.dedent("""
        from repro.rc2f import CoreSpec, SpatialShell, StreamSpec

        shell = SpatialShell(jax.devices(), n_slots=4)
        assert len(set(d for g in shell._groups for d in g)) == 8
        spec = CoreSpec("t", (StreamSpec((8, 8)),), (StreamSpec((8, 8)),))
        shell.load(0, lambda a: a * 2, spec, "u0")
        shell.load(3, lambda a: a + 1, spec, "u3")
        mesh0 = shell.slot_mesh(0)
        assert mesh0.devices.size == 2       # 8 devices / 4 slots
        out0 = shell.run(0, np.ones((8, 8), np.float32))
        out3 = shell.run(3, np.ones((8, 8), np.float32))
        assert np.allclose(out0, 2.0) and np.allclose(out3, 2.0)
        print("OK")
    """))
    assert "OK" in out
