"""Property-based PagePoolManager tests: random alloc / grow / share-COW /
free / cancel sequences must never leak a page, never double-free, and keep
``free + referenced == total`` (with per-tenant accounting and the prefix
cache consistent) after EVERY operation.

Zero-on-free property: the walk models the device pool as a per-page dirty
bit (written by admit/grow/COW exactly where the engine writes K/V) and
drains ``take_scrub()`` before every allocation, the same contract
``BatchingEngine._flush_scrub`` implements. After ANY operation, every
free-list page is either already scrubbed or still queued for scrub —
never silently dirty — and after the final flush the whole free list reads
clean. (The device-side half — recycled pages literally reading as zeros
through the real jitted scrub — is ``tests/test_adversary.py``.)

Two drivers over the same random walk: a hypothesis ``@given`` (skipped via
the conftest stub when hypothesis is not installed) and a fixed seeded soak
that always runs.
"""
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.paged import NoPagesError, PagePoolManager

N_SLOTS = 4
MAX_BLOCKS = 6
PAGE_SIZE = 4
N_PAGES = 14                       # 13 usable: forces exhaustion regularly
TENANTS = ("alice", "bob")


def _random_context(rng):
    """Token contexts drawn from a tiny alphabet so prefix collisions (and
    therefore sharing + COW) actually happen."""
    n = rng.randrange(1, MAX_BLOCKS * PAGE_SIZE)
    return [rng.randrange(4) for _ in range(n)]


def _flush_scrub(pool, dirty):
    """The engine's scrub contract, modeled host-side: every page the pool
    queued gets its dirty bit cleared (the engine runs one batched jitted
    zeroing over exactly these pages). Clean pages may be queued too — an
    admit that rolled back on exhaustion frees pages nothing ever wrote."""
    for pid in pool.take_scrub():
        dirty[pid] = False


def _assert_scrub_invariant(pool, dirty):
    """Freed pages are scrubbed: no free-list page may be dirty unless its
    scrub is still queued (the engine drains the queue before any page can
    be reallocated — ``_alloc_one`` asserts it)."""
    pending = set(pool._pending_scrub)
    for pid in pool._free:
        assert not dirty[pid] or pid in pending, \
            f"free page {pid} holds stale content with no scrub queued"


def _random_walk(seed: int, n_ops: int = 120):
    rng = random.Random(seed)
    pool = PagePoolManager(N_PAGES, PAGE_SIZE, N_SLOTS, MAX_BLOCKS)
    occupied = {}                  # slot -> tenant
    dirty = [False] * N_PAGES      # device-pool model: page holds K/V
    for _ in range(n_ops):
        op = rng.choice(("admit", "admit", "grow", "cow", "release",
                         "double_release"))
        if op == "admit":
            free_slots = [s for s in range(N_SLOTS) if s not in occupied]
            if not free_slots:
                continue
            slot, tenant = rng.choice(free_slots), rng.choice(TENANTS)
            toks = _random_context(rng)
            _flush_scrub(pool, dirty)    # engine scrubs before allocating
            if pool.pages_needed(tenant, toks) > pool.free_pages:
                # the engine's queue-on-exhaustion gate; admitting anyway
                # must raise AND roll back cleanly
                free_before = pool.free_pages
                with pytest.raises(NoPagesError):
                    pool.admit(slot, tenant, toks)
                assert pool.free_pages == free_before
            else:
                plan = pool.admit(slot, tenant, toks)
                for pid in plan.blocks:
                    dirty[pid] = True    # prefill writes K/V here
                occupied[slot] = tenant
        elif op == "grow" and occupied:
            slot = rng.choice(sorted(occupied))
            if pool.free_pages >= 1 \
                    and len(pool.slot_blocks(slot)) < MAX_BLOCKS:
                _flush_scrub(pool, dirty)
                dirty[pool.grow(slot, occupied[slot])] = True
        elif op == "cow" and occupied:
            slot = rng.choice(sorted(occupied))
            shared = [b for b in range(len(pool.slot_blocks(slot)))
                      if pool.is_shared(slot, b)]
            if shared and pool.free_pages >= 1:
                _flush_scrub(pool, dirty)
                src, dst = pool.cow(slot, rng.choice(shared),
                                    occupied[slot])
                assert src != dst
                dirty[dst] = True        # the COW copy lands here
            elif pool.slot_blocks(slot):
                pool.touch_write(slot, len(pool.slot_blocks(slot)) - 1)
        elif op == "release" and occupied:
            slot = rng.choice(sorted(occupied))
            pool.release_slot(slot)
            del occupied[slot]
        elif op == "double_release":
            # cancel/release of an already-free slot must be a no-op,
            # never an underflow
            free_slots = [s for s in range(N_SLOTS) if s not in occupied]
            if free_slots:
                before = pool.free_pages
                pool.release_slot(rng.choice(free_slots))
                assert pool.free_pages == before
        pool.verify()
        _assert_scrub_invariant(pool, dirty)
    # teardown: releasing everything returns — and scrubs — every page
    for slot in list(occupied):
        pool.release_slot(slot)
    pool.verify()
    _flush_scrub(pool, dirty)
    assert pool.used_pages == 0
    assert pool.free_pages == pool.total_pages
    assert pool.pages_by_tenant() == {}
    assert not any(dirty[1:]), \
        f"pages {[p for p in range(1, N_PAGES) if dirty[p]]} left unscrubbed"


@pytest.mark.parametrize("seed", range(10))
def test_pool_random_walk_seeded(seed):
    _random_walk(seed)


@given(st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_pool_random_walk_hypothesis(seed):
    _random_walk(seed)


def test_scrub_off_queues_nothing():
    """scrub_on_free=False is a real policy knob: frees queue no scrub
    work (the benchmark's baseline arm and any trusted-tenant deploy)."""
    pool = PagePoolManager(N_PAGES, PAGE_SIZE, N_SLOTS, MAX_BLOCKS,
                           scrub_on_free=False)
    pool.admit(0, "alice", list(range(9)))
    pool.release_slot(0)
    assert pool.scrub_pending == 0
    assert pool.take_scrub() == []
    pool.verify()


def test_cow_last_holder_takes_free_path():
    """Regression (COW + eviction interleaving): ``cow`` must route its
    source decref through the full free path. If the *other* holder
    released between the engine's ``is_shared`` check and the ``cow``
    call, the source page's refcount hits zero inside ``cow`` — a bare
    decrement would strand a dangling ``_page_key`` entry on a free page
    and ``verify()`` must be able to catch exactly that."""
    pool = PagePoolManager(N_PAGES, PAGE_SIZE, N_SLOTS, MAX_BLOCKS)
    toks = [1, 2, 3, 0, 1, 2, 3, 0, 2]      # two full blocks + one block
    a = pool.admit(0, "alice", toks)
    b = pool.admit(1, "alice", toks)        # shares both full blocks
    assert b.matched_pages >= 2
    shared_pid = pool.slot_blocks(1)[0]
    assert pool.is_shared(1, 0)
    # eviction interleaves: slot 0 releases, leaving slot 1 the sole
    # holder of the previously shared (still registered) page
    pool.release_slot(0)
    pool.verify()
    assert not pool.is_shared(1, 0)
    pool.take_scrub()      # the engine flushes before any allocation
    # a writer that already decided to detach COWs anyway: the source's
    # refcount hits zero INSIDE cow() — it must take the full free path
    # (prefix key retired, owner dropped, scrub queued); the pre-fix bare
    # decrement stranded it off the free list with a dangling key
    src, dst = pool.cow(1, 0, "alice")
    assert src == shared_pid and dst != src
    pool.verify()
    assert shared_pid not in pool.slot_blocks(1)
    pool.release_slot(1)
    pool.verify()
    assert pool.used_pages == 0
    assert shared_pid in pool.take_scrub()
    pool.verify()


def test_cross_tenant_prompts_never_share_pages():
    """Negative test for the per-tenant salted hash chain: identical
    prompts from DIFFERENT tenants must neither match the prefix cache
    nor COW-share a page (a cross-tenant share would let tenant B probe
    whether tenant A recently ran a given prompt, and hand B pages whose
    content A wrote)."""
    pool = PagePoolManager(N_PAGES, PAGE_SIZE, N_SLOTS, MAX_BLOCKS)
    toks = [3, 1, 2, 0, 1, 3, 2, 1, 0, 2]
    a = pool.admit(0, "alice", toks)
    b = pool.admit(1, "bob", toks)
    assert b.matched_pages == 0, "cross-tenant prefix match"
    assert not set(pool.slot_blocks(0)) & set(pool.slot_blocks(1)), \
        "tenants share a physical page for the same prompt"
    # same tenant DOES share — the salt must not break intra-tenant reuse
    c = pool.admit(2, "alice", toks)
    assert c.matched_pages > 0
    assert set(pool.slot_blocks(2)) & set(pool.slot_blocks(0))
    pool.verify()


def test_tenant_salt_is_keyed_and_distinct():
    """The chain seed is a keyed BLAKE2b digest: distinct per tenant,
    stable across processes (unlike PYTHONHASHSEED-salted ``hash``), and
    not forgeable by a tenant named after another's digest."""
    s_alice = PagePoolManager._chain_seed("alice")
    s_bob = PagePoolManager._chain_seed("bob")
    assert s_alice != s_bob
    # stable value (process-independent): pin it so an accidental switch
    # back to builtin hash() — or an unsalted digest — fails loudly
    assert s_alice == PagePoolManager._chain_seed("alice")
    assert PagePoolManager._chain_step(s_alice, [1, 2, 3]) != \
        PagePoolManager._chain_step(s_bob, [1, 2, 3])
