"""Property-based PagePoolManager tests: random alloc / grow / share-COW /
free / cancel sequences must never leak a page, never double-free, and keep
``free + referenced == total`` (with per-tenant accounting and the prefix
cache consistent) after EVERY operation.

Two drivers over the same random walk: a hypothesis ``@given`` (skipped via
the conftest stub when hypothesis is not installed) and a fixed seeded soak
that always runs.
"""
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.paged import NoPagesError, PagePoolManager

N_SLOTS = 4
MAX_BLOCKS = 6
PAGE_SIZE = 4
N_PAGES = 14                       # 13 usable: forces exhaustion regularly
TENANTS = ("alice", "bob")


def _random_context(rng):
    """Token contexts drawn from a tiny alphabet so prefix collisions (and
    therefore sharing + COW) actually happen."""
    n = rng.randrange(1, MAX_BLOCKS * PAGE_SIZE)
    return [rng.randrange(4) for _ in range(n)]


def _random_walk(seed: int, n_ops: int = 120):
    rng = random.Random(seed)
    pool = PagePoolManager(N_PAGES, PAGE_SIZE, N_SLOTS, MAX_BLOCKS)
    occupied = {}                  # slot -> tenant
    for _ in range(n_ops):
        op = rng.choice(("admit", "admit", "grow", "cow", "release",
                         "double_release"))
        if op == "admit":
            free_slots = [s for s in range(N_SLOTS) if s not in occupied]
            if not free_slots:
                continue
            slot, tenant = rng.choice(free_slots), rng.choice(TENANTS)
            toks = _random_context(rng)
            if pool.pages_needed(tenant, toks) > pool.free_pages:
                # the engine's queue-on-exhaustion gate; admitting anyway
                # must raise AND roll back cleanly
                free_before = pool.free_pages
                with pytest.raises(NoPagesError):
                    pool.admit(slot, tenant, toks)
                assert pool.free_pages == free_before
            else:
                pool.admit(slot, tenant, toks)
                occupied[slot] = tenant
        elif op == "grow" and occupied:
            slot = rng.choice(sorted(occupied))
            if pool.free_pages >= 1 \
                    and len(pool.slot_blocks(slot)) < MAX_BLOCKS:
                pool.grow(slot, occupied[slot])
        elif op == "cow" and occupied:
            slot = rng.choice(sorted(occupied))
            shared = [b for b in range(len(pool.slot_blocks(slot)))
                      if pool.is_shared(slot, b)]
            if shared and pool.free_pages >= 1:
                src, dst = pool.cow(slot, rng.choice(shared),
                                    occupied[slot])
                assert src != dst
            elif pool.slot_blocks(slot):
                pool.touch_write(slot, len(pool.slot_blocks(slot)) - 1)
        elif op == "release" and occupied:
            slot = rng.choice(sorted(occupied))
            pool.release_slot(slot)
            del occupied[slot]
        elif op == "double_release":
            # cancel/release of an already-free slot must be a no-op,
            # never an underflow
            free_slots = [s for s in range(N_SLOTS) if s not in occupied]
            if free_slots:
                before = pool.free_pages
                pool.release_slot(rng.choice(free_slots))
                assert pool.free_pages == before
        pool.verify()
    # teardown: releasing everything returns every page
    for slot in list(occupied):
        pool.release_slot(slot)
    pool.verify()
    assert pool.used_pages == 0
    assert pool.free_pages == pool.total_pages
    assert pool.pages_by_tenant() == {}


@pytest.mark.parametrize("seed", range(10))
def test_pool_random_walk_seeded(seed):
    _random_walk(seed)


@given(st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_pool_random_walk_hypothesis(seed):
    _random_walk(seed)
