"""Whisper enc-dec specifics: cross-attention caching, encoder invariance,
decode-vs-teacher-forcing over multiple steps."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import get_model
from repro.models.encdec import encode


def _setup():
    cfg = reduced(get_config("whisper-tiny")).replace(dtype="float32")
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(1), (2, 40, cfg.d_model)) * 0.1
    return cfg, m, params, frames


def test_encoder_is_causal_free():
    """Permuting later frames must change earlier encoder outputs (bidir)."""
    cfg, m, params, frames = _setup()
    e1 = encode(cfg, params, frames)
    frames2 = frames.at[:, -1].set(frames[:, -1] + 1.0)
    e2 = encode(cfg, params, frames2)
    # non-causal: early positions see the change too
    assert float(jnp.abs(e1[:, 0] - e2[:, 0]).max()) > 1e-6


def test_multi_step_decode_matches_teacher_forcing():
    cfg, m, params, frames = _setup()
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab_size)
    h, _ = m.forward(params, {"frames": frames, "tokens": toks})
    tf_logits = m.logits(params, h)
    _, caches = m.prefill(params, {"frames": frames, "tokens": toks[:, :8]}, 0)
    for i in range(8, 12):
        logits, caches = m.decode(params, caches, toks[:, i:i + 1],
                                  jnp.full((2,), i, jnp.int32))
        err = float(jnp.abs(logits[:, 0] - tf_logits[:, i]).max())
        assert err < 2e-4, (i, err)


def test_cross_kv_cache_matches_encoder():
    cfg, m, params, frames = _setup()
    _, caches = m.prefill(params, {"frames": frames,
                                   "tokens": jnp.zeros((2, 4), jnp.int32)}, 0)
    assert caches["cross_k"].shape[0] == cfg.n_layers
    assert caches["cross_k"].shape[2] == frames.shape[1]
