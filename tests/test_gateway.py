"""Serving gateway tests: tenant sessions through the hypervisor, quota
admission, slice-aware slot shares, straggler telemetry/migration, and the
program-cache binding of the decode executable."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import ClusterSpec, Hypervisor, SliceState
from repro.models import get_model
from repro.rc2f import AdmissionController, AdmissionError, ServiceQuota
from repro.runtime import BatchingEngine, ServingGateway


@pytest.fixture(scope="module")
def served_model():
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=n).tolist()


# ---------------------------------------------------------------------------
# Request path: everything routed through the hypervisor
# ---------------------------------------------------------------------------

def test_every_request_bound_to_a_vslice(served_model):
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    gw = ServingGateway(hv, model, params, n_slots=4, max_len=64)
    a = gw.open_session("alice", slots=2)
    b = gw.open_session("bob", slots=1)

    reqs = [gw.submit("alice" if i % 2 == 0 else "bob",
                      _prompt(cfg, seed=i), max_new_tokens=5)
            for i in range(6)]
    gw.run_until_idle()

    assert all(len(r.out_tokens) == 5 for r in reqs)
    serve = [e for e in hv.log if e["kind"] == "serve"]
    assert len(serve) == 6
    by_tenant = {e["request"]: e for e in serve}
    for r in reqs:
        e = by_tenant[r.request_id]
        assert e["tenant"] == r.tenant
        assert e["slice"] == (a if r.tenant == "alice" else b).slice_id
        assert e["new_tokens"] == 5
    # per-tenant step telemetry reached the monitor
    assert hv.monitor.median_step_ms() is not None
    assert set(hv.monitor._step_times) == {a.slice_id, b.slice_id}
    # slices went through the lifecycle: CONFIGURED on program, RUNNING on steps
    assert hv.db.find_slice(a.slice_id).state == SliceState.RUNNING
    gw.close()
    assert all(u == 0.0 for u in hv.db.utilization().values())
    assert hv.admission.usage("alice")["slots"] == 0


def test_decode_program_shared_via_program_cache(served_model):
    """The decode executable is compiled once (full configuration) and every
    session/gateway after that is a PR cache hit."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    gw = ServingGateway(hv, model, params, n_slots=2, max_len=64)
    gw.open_session("a", slots=1)
    gw.open_session("b", slots=1)
    programs = [e for e in hv.log if e["kind"] == "program"]
    assert len(programs) == 2 and all(p["cache_hit"] for p in programs)
    assert {p["fingerprint"] for p in programs} == {gw.program_fingerprint}
    # same hypervisor, second gateway: construction is also a cache hit
    gw2 = ServingGateway(hv, model, params, n_slots=2, max_len=64)
    up = [e for e in hv.log if e["kind"] == "gateway_up"]
    assert not up[0]["cache_hit"] and up[1]["cache_hit"]
    gw.close()


# ---------------------------------------------------------------------------
# Admission quotas
# ---------------------------------------------------------------------------

def test_session_quota_rejected_without_allocation(served_model):
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    gw = ServingGateway(hv, model, params, n_slots=2, max_len=64)
    with pytest.raises(AdmissionError):
        gw.open_session("greedy", slots=4)      # baas quota: 2 slots
    assert all(u == 0.0 for u in hv.db.utilization().values())
    assert hv.admission.usage("greedy")["rejected"] == 1
    # a conforming session still fits afterwards
    gw.open_session("greedy", slots=2)
    gw.close()


def test_request_quotas_per_service_model(served_model):
    cfg, model, params = served_model
    adm = AdmissionController({"baas": ServiceQuota(
        max_slots_per_tenant=2, max_inflight_requests=2,
        max_prompt_tokens=8, max_new_tokens=4)})
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1),
                    admission=adm)
    gw = ServingGateway(hv, model, params, n_slots=2, max_len=64)
    gw.open_session("t", slots=1)
    gw.submit("t", _prompt(cfg), max_new_tokens=4)
    gw.submit("t", _prompt(cfg), max_new_tokens=4)
    with pytest.raises(AdmissionError):        # in-flight ceiling
        gw.submit("t", _prompt(cfg), max_new_tokens=4)
    gw.run_until_idle()                        # drains -> inflight freed
    with pytest.raises(AdmissionError):        # prompt too long
        gw.submit("t", _prompt(cfg, n=9), max_new_tokens=4)
    with pytest.raises(AdmissionError):        # too many new tokens
        gw.submit("t", _prompt(cfg), max_new_tokens=5)
    gw.submit("t", _prompt(cfg), max_new_tokens=4)   # back under quota
    gw.run_until_idle()
    assert gw.session("t").served == 3
    gw.close()


def test_close_with_outstanding_requests_returns_quota(served_model):
    """Closing a session mid-backlog must not leak in-flight quota: queued
    requests are cancelled, decoding ones settle on completion."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    gw = ServingGateway(hv, model, params, n_slots=2, max_len=64)
    gw.open_session("t", slots=1)
    reqs = [gw.submit("t", _prompt(cfg, seed=i), max_new_tokens=4)
            for i in range(4)]
    gw.step()                                  # one request starts decoding
    gw.close_session("t")                      # 3 still queued -> cancelled
    gw.run_until_idle()                        # in-flight one drains
    assert hv.admission.usage("t")["inflight"] == 0
    assert sum(r.done.is_set() for r in reqs) == 4
    # a fresh session still has full quota
    gw.open_session("t", slots=1)
    gw.submit("t", _prompt(cfg), max_new_tokens=4)
    gw.run_until_idle()
    gw.close()


def test_reopened_session_not_charged_for_orphan_requests(served_model):
    """A request still decoding when its session closes must not be
    attributed (or quota-settled) against a reopened session of the same
    tenant name."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    gw = ServingGateway(hv, model, params, n_slots=2, max_len=64)
    gw.open_session("t", slots=1)
    gw.submit("t", _prompt(cfg), max_new_tokens=6)
    gw.step()                                   # request enters a slot
    gw.close_session("t")                       # settles its quota
    new_sess = gw.open_session("t", slots=1)
    gw.run_until_idle()                         # orphan finishes now
    assert new_sess.served == 0 and new_sess.tokens_out == 0
    assert hv.admission.usage("t")["inflight"] == 0
    # the orphan must not appear in the audit log bound to the new slice
    assert not any(e["kind"] == "serve" and e["slice"] == new_sess.slice_id
                   for e in hv.log)
    # the new session still works normally
    gw.submit("t", _prompt(cfg, seed=7), max_new_tokens=3)
    gw.run_until_idle()
    assert new_sess.served == 1
    gw.close()


def test_empty_prompt_rejected_before_quota(served_model):
    """A zero-length prompt used to reach BatchingEngine._admit and crash
    with IndexError on toks[-1]; it must be rejected at submit, without
    consuming in-flight quota."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    gw = ServingGateway(hv, model, params, n_slots=2, max_len=64)
    gw.open_session("t", slots=1)
    with pytest.raises(AdmissionError, match="empty prompt"):
        gw.submit("t", [], max_new_tokens=4)
    assert hv.admission.usage("t")["inflight"] == 0
    gw.submit("t", _prompt(cfg), max_new_tokens=4)    # normal traffic fine
    gw.run_until_idle()
    assert gw.session("t").served == 1
    gw.close()


def test_request_exceeding_engine_max_len_rejected(served_model):
    """A request that cannot fit the KV cache is rejected at admission
    instead of silently corrupting a slot."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    gw = ServingGateway(hv, model, params, n_slots=2, max_len=32)
    gw.open_session("t", slots=1)
    with pytest.raises(AdmissionError, match="max_len"):
        gw.submit("t", _prompt(cfg, n=30), max_new_tokens=8)
    assert hv.admission.usage("t")["inflight"] == 0
    gw.close()


def test_external_migration_rebinds_session(served_model):
    """migrate_stragglers called OUTSIDE the gateway (ops sweep) still
    rebinds the serving session via the migration listener."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=2, devices_per_node=1))
    gw = ServingGateway(hv, model, params, n_slots=2, max_len=64)
    hot = gw.open_session("hot", slots=1)
    cold = gw.open_session("cold", slots=1)
    old = hot.slice_id
    for _ in range(8):
        hv.monitor.record_step(hot.slice_id, 400.0)
        hv.monitor.record_step(cold.slice_id, 100.0)
    hv.migrate_stragglers()                    # not gw.rebalance()
    assert hot.slice_id != old
    # serving continues against the new slice without KeyError
    gw.submit("hot", _prompt(cfg), max_new_tokens=3)
    gw.run_until_idle()
    assert gw.session("hot").served == 1
    gw.close()


def test_quota_usage_isolated_per_service_model():
    """Slots held under one service model must not count against another
    model's ceiling for the same tenant."""
    adm = AdmissionController()
    adm.admit_tenant("t", "raas", 2)           # raas quota is 2: at ceiling
    adm.admit_tenant("t", "baas", 2)           # independent baas ceiling
    with pytest.raises(AdmissionError):
        adm.admit_tenant("t", "baas", 1)
    adm.release_tenant("t", "raas", 2)
    assert adm.usage("t", "raas")["slots"] == 0
    assert adm.usage("t", "baas")["slots"] == 2
    assert adm.usage("t")["slots"] == 2        # aggregate view


def test_bad_slot_count_does_not_leak_quota(served_model):
    """If allocation fails for any reason (here: invalid slot count), the
    quota admitted beforehand must be returned."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    with pytest.raises(ValueError):
        hv.open_serving_session("t", slots=3, service_model="rsaas")
    assert hv.admission.usage("t")["slots"] == 0
    hv.open_serving_session("t", slots=2, service_model="rsaas")


def test_gateway_close_deregisters_migration_listener(served_model):
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    gw = ServingGateway(hv, model, params, n_slots=2, max_len=64)
    assert gw._on_migration in hv.migration_listeners
    gw.close()
    gw.close()                                  # idempotent
    assert gw._on_migration not in hv.migration_listeners


def test_submit_without_session_rejected(served_model):
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    gw = ServingGateway(hv, model, params, n_slots=2, max_len=64)
    with pytest.raises(KeyError):
        gw.submit("nobody", _prompt(cfg))


# ---------------------------------------------------------------------------
# Slice-aware scheduling in the engine
# ---------------------------------------------------------------------------

def test_tenant_share_caps_concurrent_slots(served_model):
    """A 1-slot tenant may never occupy more than one engine slot, even
    with a deep backlog and free capacity."""
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    gw = ServingGateway(hv, model, params, n_slots=4, max_len=64)
    gw.open_session("small", slots=1)
    for i in range(4):
        gw.submit("small", _prompt(cfg, seed=i), max_new_tokens=3)
    while gw.step():
        assert gw.engine.active_by_tenant().get("small", 0) <= 1
    assert gw.session("small").served == 4
    gw.close()


def test_round_robin_admission_across_tenants(served_model):
    """With two backlogged tenants and two slots, admission interleaves
    tenants instead of draining one queue first."""
    cfg, model, params = served_model
    engine = BatchingEngine(model, params, n_slots=2, max_len=64)
    for i in range(2):
        engine.submit(_prompt(cfg, seed=i), max_new_tokens=3, tenant="a")
    for i in range(2):
        engine.submit(_prompt(cfg, seed=10 + i), max_new_tokens=3,
                      tenant="b")
    engine.step()
    assert engine.active_by_tenant() == {"a": 1, "b": 1}
    engine.run_until_idle()
    # drained queues are pruned: tenant churn must not leave ghost keys in
    # the round-robin rotation
    assert engine.queued_by_tenant() == {}


# ---------------------------------------------------------------------------
# Straggler telemetry -> migration -> session rebind
# ---------------------------------------------------------------------------

def test_hot_tenant_migrates_and_session_rebinds(served_model):
    cfg, model, params = served_model
    hv = Hypervisor(ClusterSpec(n_nodes=2, devices_per_node=1))
    gw = ServingGateway(hv, model, params, n_slots=4, max_len=64)
    hot = gw.open_session("hot", slots=1)
    cold = gw.open_session("cold", slots=1)
    old_slice, old_dev = hot.slice_id, hv.db.find_slice(hot.slice_id).device_id

    # simulate telemetry: the hot tenant consistently dominates step time
    for _ in range(8):
        gw._on_step({"hot": 1}, 400.0)
        gw._on_step({"cold": 1}, 100.0)
    moved = gw.rebalance()
    assert moved and moved[0][0] == old_slice
    assert hot.slice_id != old_slice
    new_vs = hv.db.find_slice(hot.slice_id)
    assert new_vs.device_id != old_dev
    assert new_vs.owner == "hot"
    assert new_vs.program == gw.program_fingerprint   # program carried over
    # telemetry after the move lands on the new slice
    gw._on_step({"hot": 1}, 50.0)
    assert hot.slice_id in hv.monitor._step_times
    gw.close()
