"""Auto-tuner tests: design-space legality, roofline cost-model pruning
and per-class divergence, tuned-config persistence in the ProgramCache,
the GeometryConfig/registry default pin, and the bit-exactness matrix —
every tuner-emitted geometry must serve the same greedy token streams as
the default, dense and paged, lockstep and event-driven."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import GeometryConfig
from repro.core import ClusterSpec, Hypervisor
from repro.kernels import registry as kreg
from repro.models import get_model
from repro.runtime import EventLoop, GatewayFleet
from repro.tuning import (TunedConfig, candidate_cost, device_class,
                          enumerate_candidates, legal_reason,
                          model_fingerprint, profile_for_speed,
                          prune_reason, resolve_tuned, tune)
from repro.tuning.cost_model import DeviceProfile


@pytest.fixture(scope="module")
def served_model():
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# Defaults pin: configs/base.py stays jax-free, so its GeometryConfig
# literals duplicate kernels/registry.py — this test is the sync contract
# ---------------------------------------------------------------------------

def test_geometry_defaults_pinned_to_registry():
    g = GeometryConfig()
    assert g.decode_block_k == kreg.DECODE_BLOCK_DEFAULT
    assert g.flash_block_q == g.flash_block_k == kreg.FLASH_BLOCK_DEFAULT
    assert g.mm_block_m == g.mm_block_n == g.mm_block_k \
        == kreg.MM_BLOCK_DEFAULT
    t = TunedConfig()
    assert t.decode_block_k == kreg.DECODE_BLOCK_DEFAULT
    assert t.flash_block_q == t.flash_block_k == kreg.FLASH_BLOCK_DEFAULT
    assert t.mm_block_m == kreg.MM_BLOCK_DEFAULT
    assert t.page_size == kreg.PAGE_SIZE_DEFAULT
    assert t.n_slots == kreg.SLOTS_DEFAULT
    assert t.prefill_chunk == kreg.PREFILL_CHUNK_DEFAULT


# ---------------------------------------------------------------------------
# Design space
# ---------------------------------------------------------------------------

def test_enumerated_candidates_are_legal():
    """Every candidate the sweep yields satisfies the registry's
    divisibility rules; the shipped default is in the space (the tuner
    can never do worse than it)."""
    for paged in (False, True):
        cands = list(enumerate_candidates(max_len=2048, head_dim=64,
                                          paged=paged))
        assert cands
        for c in cands:
            assert legal_reason(c, max_len=2048, head_dim=64,
                                paged=paged) is None
        assert TunedConfig() in cands   # the default is always reachable


def test_illegal_geometry_is_rejected():
    assert legal_reason(TunedConfig(decode_block_k=384), max_len=2048,
                        head_dim=64, paged=False) is not None
    assert legal_reason(TunedConfig(page_size=48), max_len=2048,
                        head_dim=64, paged=True) is not None
    assert legal_reason(TunedConfig(), max_len=2048, head_dim=60,
                        paged=False) is not None   # lane misalignment


# ---------------------------------------------------------------------------
# Cost model: hard pruning + per-class divergence
# ---------------------------------------------------------------------------

def test_prune_on_vmem_and_hbm():
    cfg = get_config("smollm-135m")
    tiny_vmem = DeviceProfile("tiny-vmem", 1.0, 1e12, 1e11,
                              vmem_bytes=1024, hbm_bytes=16 * 2 ** 30)
    r = prune_reason(TunedConfig(), cfg, tiny_vmem, max_len=2048,
                     paged=False)
    assert r is not None and r.startswith("VMEM")
    tiny_hbm = DeviceProfile("tiny-hbm", 1.0, 1e12, 1e11,
                             vmem_bytes=16 * 2 ** 20, hbm_bytes=1024)
    r = prune_reason(TunedConfig(), cfg, tiny_hbm, max_len=2048,
                     paged=False)
    assert r is not None and r.startswith("HBM")
    ok = profile_for_speed(1.0)
    assert prune_reason(TunedConfig(), cfg, ok, max_len=2048,
                        paged=False) is None
    pruned = candidate_cost(TunedConfig(), cfg, tiny_vmem, max_len=2048,
                            paged=False)
    assert pruned.pruned is not None \
        and pruned.us_per_token == float("inf")


def test_small_class_gets_half_memory():
    assert profile_for_speed(0.25).vmem_bytes \
        == profile_for_speed(1.0).vmem_bytes // 2
    assert profile_for_speed(0.25).hbm_bytes \
        == profile_for_speed(1.0).hbm_bytes // 2


def test_tuner_beats_default_and_classes_diverge():
    """The tentpole claim: the sweep finds geometry strictly better than
    the hand-picked default on BOTH device classes, and the two classes
    get DIFFERENT geometry (engines on fast vs 0.25x parts should not
    run the same blocks)."""
    cfg = get_config("gemma3-1b")
    fast = tune(cfg, profile_for_speed(1.0), max_len=2048, paged=False)
    slow = tune(cfg, profile_for_speed(0.25), max_len=2048, paged=False)
    assert fast.win > 1.0 and slow.win > 1.0
    assert fast.best != slow.best
    assert fast.best.decode_block_k >= slow.best.decode_block_k


def test_tune_is_deterministic():
    cfg = get_config("smollm-135m")
    a = tune(cfg, profile_for_speed(0.25), max_len=2048, paged=True)
    b = tune(cfg, profile_for_speed(0.25), max_len=2048, paged=True)
    assert a.best == b.best
    assert [c.geometry_key() for c, _ in a.table] \
        == [c.geometry_key() for c, _ in b.table]


# ---------------------------------------------------------------------------
# Persistence: ProgramCache tuned-config store
# ---------------------------------------------------------------------------

def test_tuned_store_roundtrip(tmp_path):
    from repro.core import ProgramCache
    pc = ProgramCache()
    cfg = TunedConfig(decode_block_k=1024, n_slots=8)
    pc.put_tuned("fp0", "c1.00x", cfg.to_dict())
    pc.put_tuned("fp0", "c0.25x", TunedConfig(decode_block_k=256).to_dict())
    assert TunedConfig.from_dict(pc.get_tuned("fp0", "c1.00x")) == cfg
    path = str(tmp_path / "tuned.json")
    pc.save_tuned(path)
    pc2 = ProgramCache()
    assert pc2.load_tuned(path) == 2
    assert pc2.tuned_configs() == pc.tuned_configs()
    assert pc2.get_tuned("fp0", "c9.99x") is None


def test_resolve_tuned_prefers_persisted_winner():
    """resolve_tuned is a store lookup first — a pre-seeded (restored)
    winner is honored verbatim, no re-sweep."""
    from repro.core import ProgramCache
    cfg = get_config("smollm-135m")
    pc = ProgramCache()
    fp = model_fingerprint(cfg, 2048, False)
    seeded = TunedConfig(decode_block_k=128, n_slots=2)
    pc.put_tuned(fp, device_class(1.0), seeded.to_dict())
    assert resolve_tuned(pc, cfg, 1.0, max_len=2048, paged=False) == seeded
    # an unseen class tunes once, then hits the store
    first = resolve_tuned(pc, cfg, 0.25, max_len=2048, paged=False)
    assert pc.get_tuned(fp, device_class(0.25)) == first.to_dict()
    assert resolve_tuned(pc, cfg, 0.25, max_len=2048, paged=False) == first


# ---------------------------------------------------------------------------
# Bit-exactness matrix (the tuner changes WHERE bytes move, never WHAT
# is computed): every geometry the tuner emits across the benchmark's
# class matrix serves identical greedy token streams
# ---------------------------------------------------------------------------

def _tuner_winner_geometries():
    """Distinct winners across (class, mode) for the served arch."""
    cfg = get_config("smollm-135m")
    geoms = {}
    for paged in (False, True):
        for speed in (1.0, 0.25):
            best = tune(cfg, profile_for_speed(speed), max_len=2048,
                        paged=paged).best
            geoms[best.geometry_key()] = best
    return sorted(geoms.items())


def _serve(model, params, cfg, tuned, paged, loop):
    """Serve three tenants on a two-class fleet with the given geometry
    (None = shipped default); returns per-tenant token logs."""
    from repro.models.api import Model
    if tuned is None:
        m, n_slots, page_size = model, 4, 8
    else:
        geom = GeometryConfig(decode_block_k=tuned.decode_block_k,
                              flash_block_q=tuned.flash_block_q,
                              flash_block_k=tuned.flash_block_k,
                              mm_block_m=tuned.mm_block_m,
                              mm_block_n=tuned.mm_block_n,
                              mm_block_k=tuned.mm_block_k)
        m = Model(cfg.replace(geometry=geom))
        n_slots, page_size = tuned.n_slots, min(tuned.page_size, 64)
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2,
                                device_speeds=(1.0, 0.25)))
    fleet = GatewayFleet(hv, m, params, n_slots=n_slots, max_len=64,
                         paged=paged, page_size=page_size)
    ev = EventLoop(fleet) if loop == "event" else None
    try:
        rng = np.random.default_rng(0)
        reqs = {}
        for t in ("a", "b", "c"):
            fleet.open_session(t, slots=1)
            prompt = rng.integers(0, cfg.vocab_size, size=6).tolist()
            reqs[t] = fleet.submit(t, prompt, max_new_tokens=8)
        for _ in range(400):
            fleet.step() if ev is None else ev.run_ticks(1)
            if all(r.done.is_set() for r in reqs.values()):
                break
        assert all(r.done.is_set() for r in reqs.values())
        fleet.verify_invariants()
        return {t: list(r.out_tokens) for t, r in reqs.items()}
    finally:
        fleet.close()


@pytest.mark.parametrize("loop", ["lockstep", "event"])
@pytest.mark.parametrize("paged", [False, True],
                         ids=["dense", "paged"])
@pytest.mark.parametrize(("gkey", "tuned"), _tuner_winner_geometries(),
                         ids=lambda v: v if isinstance(v, str) else "")
def test_tuned_geometry_is_bit_exact(served_model, gkey, tuned, paged,
                                     loop):
    cfg, model, params = served_model
    base = _serve(model, params, cfg, None, paged, loop)
    got = _serve(model, params, cfg, tuned, paged, loop)
    assert got == base, f"geometry {gkey} diverged under {loop}"
