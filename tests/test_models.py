"""Per-architecture smoke tests (reduced configs) + decode consistency.

Every assigned arch: instantiate the reduced same-family config, run one
forward and one train step on CPU, assert output shapes and finiteness;
then check the serving path (prefill + one decode token) agrees with the
teacher-forced forward logits.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.runtime import TrainOpts, init_train_state, make_train_step

B, S = 2, 32


def _cfg(name):
    return reduced(get_config(name)).replace(dtype="float32")


def _batch(cfg, key, seq=S, with_labels=False):
    toks = jax.random.randint(key, (B, seq), 0, cfg.vocab_size)
    if cfg.family == "audio":
        batch = {"frames": jax.random.normal(key, (B, 48, cfg.d_model)) * 0.1,
                 "tokens": toks[:, :16]}
        if with_labels:
            batch["labels"] = toks[:, 1:17]
        return batch
    batch = {"tokens": toks}
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model)) * 0.1
    if with_labels:
        batch["labels"] = jnp.roll(toks, -1, axis=1)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch):
    cfg = _cfg(arch)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    h, aux = m.forward(params, batch)
    exp_s = 16 if cfg.family == "audio" else S + cfg.n_patches
    assert h.shape == (B, exp_s, cfg.d_model)
    assert bool(jnp.isfinite(h).all())
    logits = m.logits(params, h)
    assert logits.shape == (B, exp_s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_nothing_nan(arch):
    cfg = _cfg(arch)
    m = get_model(cfg)
    opts = TrainOpts(opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20),
                     loss_chunk=16)
    state = init_train_state(m, jax.random.PRNGKey(0), opts)
    step = jax.jit(make_train_step(m, opts))
    batch = _batch(cfg, jax.random.PRNGKey(1), with_labels=True)
    if cfg.family == "audio":
        batch["labels"] = batch["tokens"]
    losses = []
    for i in range(3):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(jnp.isfinite(jnp.asarray(losses))), losses
    assert losses[-1] < losses[0], losses  # same batch: must overfit


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = _cfg(arch)
    m = get_model(cfg)
    key = jax.random.PRNGKey(1)
    params = m.init(key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.family == "audio":
        frames = jax.random.normal(key, (B, 48, cfg.d_model)) * 0.1
        h, _ = m.forward(params, {"frames": frames, "tokens": toks[:, :16]})
        full_logits = m.logits(params, h)[:, 15 - 1]
        _, caches = m.prefill(
            params, {"frames": frames, "tokens": toks[:, :15]}, 0)
        d, _ = m.decode(params, caches, toks[:, 15:16],
                        jnp.full((B,), 15, jnp.int32))
        err = float(jnp.abs(d[:, 0] - m.logits(params, h)[:, 15]).max())
    else:
        batch = {"tokens": toks}
        patches = None
        if cfg.n_patches:
            patches = jax.random.normal(
                key, (B, cfg.n_patches, cfg.d_model)) * 0.1
            batch["patches"] = patches
        h, _ = m.forward(params, batch)
        full_logits = m.logits(params, h)[:, -1]
        pre = {"tokens": toks[:, :S - 1]}
        if patches is not None:
            pre["patches"] = patches
        _, caches = m.prefill(params, pre, S + cfg.n_patches + 8)
        pos = jnp.full((B,), S - 1 + cfg.n_patches, jnp.int32)
        d, _ = m.decode(params, caches, toks[:, S - 1:S], pos)
        err = float(jnp.abs(d[:, 0] - full_logits).max())
    assert err < 2e-4, f"{arch}: decode/forward mismatch {err}"


def test_multi_token_greedy_decode_consistency():
    """Greedy decode 6 tokens == teacher-forced argmax chain (smollm)."""
    cfg = _cfg("smollm-135m")
    m = get_model(cfg)
    key = jax.random.PRNGKey(3)
    params = m.init(key)
    prompt = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    _, caches = m.prefill(params, {"tokens": prompt[:, :-1]}, 32)
    tok = prompt[:, -1:]
    pos = jnp.array([7], jnp.int32)
    out = []
    for _ in range(6):
        logits, caches = m.decode(params, caches, tok, pos)
        tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
        pos = pos + 1
    # teacher-forced check: feed prompt+generated and compare argmax chain
    full = jnp.concatenate([prompt, jnp.asarray([out], jnp.int32)], axis=1)
    h, _ = m.forward(params, {"tokens": full})
    logits = m.logits(params, h)
    for i, t in enumerate(out):
        pred = int(jnp.argmax(logits[0, 7 + i]))
        assert pred == t, (i, pred, t)


def test_pattern_stage_plan_structures():
    """Stage planner: gemma3 (5L+1G)*4+2L, gemma2 pairs, zamba2 shared."""
    from repro.models.stages import plan_stages
    g3 = plan_stages(get_config("gemma3-1b"))
    assert [s.kind for s in g3] == ["pattern", "run"]
    assert g3[0].repeats == 4 and len(g3[0].sites) == 6
    assert g3[1].repeats == 2
    g2 = plan_stages(get_config("gemma2-9b"))
    assert g2[0].kind == "pattern" and g2[0].repeats == 21
    z = plan_stages(get_config("zamba2-7b"))
    assert z[0].kind == "pattern" and z[0].repeats == 13
    assert z[1].kind == "run" and z[1].repeats == 3
    ds = plan_stages(get_config("deepseek-v2-lite-16b"))
    assert ds[0].repeats == 1 and ds[1].repeats == 26
    assert sum(s.repeats * len(s.sites) for s in ds) == 27


from hypothesis import given, settings, strategies as st
from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, MIXER_SHARED_ATTN,
                                MIXER_SSM, ModelConfig, SSMConfig)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.sampled_from([ATTN_GLOBAL, ATTN_LOCAL, MIXER_SSM]),
                min_size=1, max_size=4),
       st.integers(1, 40))
def test_stage_plan_covers_all_layers(pattern, n_layers):
    """Property: any pattern × depth plans to exactly n_layers sites, in
    order, with pattern tiling preserved."""
    from repro.models.stages import plan_stages
    cfg = ModelConfig(n_layers=n_layers, pattern=tuple(pattern),
                      ssm=SSMConfig())
    stages = plan_stages(cfg)
    # reconstruct the per-layer mixer sequence from the plan
    seq = []
    for stg in stages:
        for _ in range(stg.repeats):
            seq.extend(s.mixer for s in stg.sites)
    expected = [pattern[i % len(pattern)] for i in range(n_layers)]
    assert seq == expected


def test_int8_kv_cache_decode_close_to_fp():
    """kv_quant=True: decode matches the fp cache path within quantization
    tolerance, and the cache state is genuinely int8."""
    cfg = _cfg("smollm-135m")
    m = get_model(cfg)
    key = jax.random.PRNGKey(5)
    params = m.init(key)
    toks = jax.random.randint(key, (B, 16), 0, cfg.vocab_size)

    def run(quant):
        c = cfg.replace(kv_quant=quant)
        mq = get_model(c)
        _, caches = mq.prefill(params, {"tokens": toks[:, :15]}, 32)
        if quant:
            leaves = jax.tree.leaves(caches)
            assert any(l.dtype == jnp.int8 for l in leaves)
        logits, _ = mq.decode(params, caches, toks[:, 15:16],
                              jnp.full((B,), 15, jnp.int32))
        return logits

    lq = run(True)
    lf = run(False)
    # greedy tokens agree and logits are close (int8 row quantization)
    assert jnp.array_equal(jnp.argmax(lq, -1), jnp.argmax(lf, -1))
    assert float(jnp.abs(lq - lf).max()) < 0.15
