"""RC2F dataplane tests: FIFOs (order/loss properties), shell co-residency,
config spaces, link contention model vs the paper's published numbers."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.rc2f import (PCIE_LINK_BYTES_S, ConfigSpace, CoreSpec, FusedShell,
                        OutputFIFO, SharedLink, StreamFIFO, StreamSpec,
                        core_throughput, make_gcs, make_ucs)


# ---------------------------------------------------------------------------
# FIFOs
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 1000), min_size=1, max_size=50),
       st.integers(1, 4))
def test_fifo_preserves_order_and_count(items, depth):
    fifo = StreamFIFO(depth=depth)
    arrays = [np.full((4,), v, np.int32) for v in items]
    fifo.feed(iter(arrays))
    out = [int(np.asarray(x)[0]) for x in fifo]
    assert out == items
    assert fifo.items_in == len(items)


def test_output_fifo_roundtrip():
    out = OutputFIFO(depth=4)
    out.put({"y": np.arange(6.0)})
    got = out.get()
    np.testing.assert_array_equal(got["y"], np.arange(6.0))
    assert out.bytes_out == 48


# ---------------------------------------------------------------------------
# Config spaces
# ---------------------------------------------------------------------------

def test_gcs_defaults_and_rw():
    gcs = make_gcs()
    assert gcs.read("magic") == 0x5C3E
    assert gcs.read("n_slots") == 4
    gcs.write("step_counter", 7)
    assert gcs.read("step_counter") == 7
    with pytest.raises(KeyError):
        gcs.write("nonexistent", 1)


# ---------------------------------------------------------------------------
# Shell
# ---------------------------------------------------------------------------

SPEC = CoreSpec("t", (StreamSpec((8, 8)), StreamSpec((8, 8))),
                (StreamSpec((8, 8)),))


def test_fused_shell_isolated_cores():
    shell = FusedShell(4)
    shell.load(0, lambda a, b: a @ b, SPEC, "alice")
    shell.load(3, lambda a, b: a + b, SPEC, "bob")
    assert shell.active_slots() == [0, 3]
    assert shell.gcs.read("active_mask") == 0b1001
    eye = np.eye(8, dtype=np.float32)
    ones = np.ones((8, 8), np.float32)
    outs = shell.run_cycle({0: (eye, ones), 3: (ones, ones)})
    assert np.allclose(outs[0], ones)
    assert np.allclose(outs[3], 2 * ones)


def test_fused_shell_partial_reconfig_keeps_others():
    """PR of slot 0 must not disturb slot 1 (paper's PR region isolation)."""
    shell = FusedShell(2)
    shell.load(0, lambda a, b: a @ b, SPEC)
    shell.load(1, lambda a, b: a - b, SPEC)
    ones = np.ones((8, 8), np.float32)
    o1 = shell.run_cycle({0: (ones, ones), 1: (ones, ones)})
    shell.load(0, lambda a, b: a * 3 + b * 0, SPEC)   # swap slot 0 only
    o2 = shell.run_cycle({0: (ones, ones), 1: (ones, ones)})
    assert np.allclose(o2[1], o1[1])                  # slot 1 unchanged
    assert np.allclose(o2[0], 3 * ones)


def test_shell_park_on_empty():
    shell = FusedShell(2)
    shell.load(0, lambda a, b: a, SPEC)
    assert shell.gcs.read("clock_enable") == 1
    shell.unload(0)
    assert shell.gcs.read("clock_enable") == 0        # energy policy
    assert shell.gcs.read("active_mask") == 0


def test_shell_rejects_wrong_slots():
    shell = FusedShell(2)
    shell.load(0, lambda a, b: a, SPEC)
    with pytest.raises(ValueError):
        shell.run_cycle({1: (np.ones((8, 8), np.float32),) * 2})


# ---------------------------------------------------------------------------
# Link contention model vs paper Table II/III
# ---------------------------------------------------------------------------

def test_link_contention_matches_paper_table2():
    """Table II: FIFO throughput 798 -> 397 -> 196 MB/s for 1/2/4 vFPGAs."""
    link = SharedLink(bandwidth_bytes_s=798e6)
    assert abs(link.per_stream_throughput(1) / 1e6 - 798) < 1
    assert abs(link.per_stream_throughput(2) / 1e6 - 399) < 3
    assert abs(link.per_stream_throughput(4) / 1e6 - 199.5) < 4


def test_core_throughput_matches_paper_table3():
    """Table III 16x16: one core compute-bound at 509 MB/s; 2 cores
    link-bound at ~398; 4 cores ~198. 32x32: compute-bound at 279 even
    with 2 cores (277 measured)."""
    link = SharedLink(bandwidth_bytes_s=800e6)
    c16 = 509e6      # single-core compute rate implied by the paper
    assert core_throughput(c16, link, 1) == pytest.approx(509e6)
    assert core_throughput(c16, link, 2) == pytest.approx(400e6, rel=0.01)
    assert core_throughput(c16, link, 4) == pytest.approx(200e6, rel=0.02)
    c32 = 279e6
    assert core_throughput(c32, link, 1) == pytest.approx(279e6)
    assert core_throughput(c32, link, 2) == pytest.approx(279e6)  # still compute-bound


@settings(max_examples=40, deadline=None)
@given(st.floats(1e6, 1e10), st.integers(1, 4), st.integers(1, 4))
def test_throughput_monotone_in_contention(rate, n1, n2):
    link = SharedLink()
    t1 = core_throughput(rate, link, min(n1, n2))
    t2 = core_throughput(rate, link, max(n1, n2))
    assert t1 >= t2                    # more tenants never increases per-core
    assert t2 <= rate                  # never exceeds compute bound
