"""Layer-level properties: RoPE, norms, SSD chunk-invariance, MLA absorbed
decode == expanded form, local attention == masked full attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.layers import (AttnOpts, MLAOpts, SSMOpts, apply_rope,
                          attn_forward, init_attention, init_mla, init_ssm,
                          mla_forward, rms_norm, softcap, ssm_forward)
from repro.configs.base import MLAConfig, SSMConfig
from repro.layers.ssm import ssd_scan


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 64))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))

    def score(i, j):
        qi = apply_rope(q, jnp.array([[i]]), 10000.0)
        kj = apply_rope(k, jnp.array([[j]]), 10000.0)
        return float(jnp.sum(qi * kj))

    assert abs(score(5, 3) - score(105, 103)) < 1e-3
    assert abs(score(7, 0) - score(1007, 1000)) < 1e-3


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_rms_norm_unit_rms(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 32)) * 7
    y = rms_norm(x, jnp.zeros(32))
    rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = softcap(x, 30.0)
    assert float(jnp.abs(y).max()) <= 30.0
    np.testing.assert_allclose(np.asarray(softcap(x, 0.0)), np.asarray(x))


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunk_invariance(chunk):
    """SSD output must not depend on the chunk size."""
    B, S, H, P, G, N = 2, 64, 4, 8, 2, 16
    key = jax.random.PRNGKey(0)
    xs = jax.random.normal(key, (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)))
    Bm = jax.random.normal(jax.random.PRNGKey(3), (B, S, G, N)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(4), (B, S, G, N)) * 0.3
    D = jnp.ones((H,))
    y_ref, s_ref = ssd_scan(xs, dt, A, Bm, Cm, D, chunk=S)
    y, s = ssd_scan(xs, dt, A, Bm, Cm, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=1e-4, rtol=1e-3)


def test_ssd_state_carry_equals_concat():
    """scan(x1) then scan(x2 | state) == scan([x1;x2])."""
    B, S, H, P, G, N = 1, 32, 2, 8, 1, 8
    key = jax.random.PRNGKey(5)
    xs = jax.random.normal(key, (B, 2 * S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(6),
                                           (B, 2 * S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(7), (H,)))
    Bm = jax.random.normal(jax.random.PRNGKey(8), (B, 2 * S, G, N)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(9), (B, 2 * S, G, N)) * 0.3
    D = jnp.zeros((H,))
    y_full, s_full = ssd_scan(xs, dt, A, Bm, Cm, D, chunk=16)
    y1, s1 = ssd_scan(xs[:, :S], dt[:, :S], A, Bm[:, :S], Cm[:, :S], D, 16)
    y2, s2 = ssd_scan(xs[:, S:], dt[:, S:], A, Bm[:, S:], Cm[:, S:], D, 16,
                      init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=1e-4, rtol=1e-3)


def test_local_attention_equals_masked_full():
    """Sliding-window path (key slicing) == full attention with window mask."""
    opts_local = AttnOpts(n_heads=4, n_kv_heads=2, head_dim=16, window=8,
                          q_chunk=8)
    opts_ref = AttnOpts(n_heads=4, n_kv_heads=2, head_dim=16, window=8,
                        q_chunk=0)
    p = init_attention(jax.random.PRNGKey(0), 32, opts_local)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    y1, _ = attn_forward(p, x, pos, opts_local)
    y2, _ = attn_forward(p, x, pos, opts_ref)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-5, rtol=1e-4)


def test_mla_absorbed_decode_equals_expanded():
    """The compressed-cache absorbed decode must equal the expanded form."""
    from repro.layers.mla import fill_mla_cache, init_mla_cache, mla_decode
    mcfg = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                     qk_rope_head_dim=8, v_head_dim=16)
    opts = MLAOpts(n_heads=4, cfg=mcfg)
    p = init_mla(jax.random.PRNGKey(0), 64, opts)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, 64))
    pos = jnp.broadcast_to(jnp.arange(9)[None], (2, 9))
    y_full, (c_kv, k_rope) = mla_forward(p, x, pos, opts)
    # prefill 8, decode the 9th
    cache = init_mla_cache(2, 16, opts, x.dtype)
    cache = fill_mla_cache(cache, c_kv[:, :8], k_rope[:, :8], pos[:, :8])
    y_dec, _ = mla_decode(p, x[:, 8:9], pos[:, 8:9], cache, opts)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, 8]),
                               atol=1e-4, rtol=1e-3)


def test_data_pipeline_determinism_and_host_sharding():
    from repro.data import DataConfig, DataPipeline
    g = DataPipeline(DataConfig(vocab_size=64, seq_len=16, batch_size=8))
    b1 = g.batch_at(3)
    b2 = g.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host-sharded pipelines tile the same global batch
    h0 = DataPipeline(DataConfig(vocab_size=64, seq_len=16, batch_size=8,
                                 n_hosts=2, host_index=0)).batch_at(3)
    h1 = DataPipeline(DataConfig(vocab_size=64, seq_len=16, batch_size=8,
                                 n_hosts=2, host_index=1)).batch_at(3)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
