"""Property-based trace-generator tests (``repro.runtime.loadgen``).

Two drivers, mirroring ``test_pool_properties``: hypothesis ``@given``
sweeps over spec space (skipped via the conftest stub when hypothesis is
not installed) and fixed seeded sweeps that always run. Properties:

  * arrival steps are non-decreasing and inside ``[0, horizon)``;
  * every prompt length / output budget is >= 1 and clamped to its max;
  * identical ``TraceSpec`` + seed => bit-identical trace; different
    seeds diverge;
  * observed tenant shares track the spec's Zipf weights (hot-first);
  * ``TraceSpec`` round-trips through ``dataclasses.asdict``.
"""
import dataclasses

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.faults import seeded_rng
from repro.runtime.loadgen import (Arrival, TraceSpec, _poisson, percentile,
                                   synthesize, tenant_shares)


def _spec(**kw):
    base = dict(name="prop", horizon=32, base_rate=1.0,
                burst_rate_mult=3.0, burst_on_mean=4.0, burst_off_mean=8.0,
                diurnal_period=16, diurnal_amp=0.5, tenants=4, zipf_s=1.1,
                prompt_len_max=12, out_tokens_max=12)
    base.update(kw)
    return TraceSpec(**base)


def _check_wellformed(spec, arrivals):
    last = 0
    for a in arrivals:
        assert isinstance(a, Arrival)
        assert 0 <= a.step < spec.horizon
        assert a.step >= last, "arrival steps must be non-decreasing"
        last = a.step
        assert 1 <= a.prompt_len <= spec.prompt_len_max
        assert 1 <= a.max_new_tokens <= spec.out_tokens_max
        assert a.tenant in spec.tenant_ids()


# ---------------------------------------------------------------------------
# hypothesis sweep over spec space
# ---------------------------------------------------------------------------
@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
       horizon=st.integers(min_value=1, max_value=64),
       base_rate=st.floats(min_value=0.0, max_value=20.0),
       burst_mult=st.floats(min_value=1.0, max_value=8.0),
       diurnal_amp=st.floats(min_value=0.0, max_value=1.0),
       tenants=st.integers(min_value=1, max_value=12),
       zipf_s=st.floats(min_value=0.0, max_value=2.5))
@settings(max_examples=60, deadline=None)
def test_trace_wellformed_prop(seed, horizon, base_rate, burst_mult,
                               diurnal_amp, tenants, zipf_s):
    spec = _spec(horizon=horizon, base_rate=base_rate,
                 burst_rate_mult=burst_mult, diurnal_amp=diurnal_amp,
                 tenants=tenants, zipf_s=zipf_s)
    arrivals = synthesize(spec, seed)
    _check_wellformed(spec, arrivals)
    # bit-identical replay of the same (spec, seed)
    assert synthesize(spec, seed) == arrivals


@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_trace_seed_determinism_prop(seed):
    spec = _spec()
    a, b = synthesize(spec, seed), synthesize(spec, seed)
    assert a == b


# ---------------------------------------------------------------------------
# fixed seeded sweeps (always run)
# ---------------------------------------------------------------------------
def test_trace_wellformed_seeded():
    for seed in range(12):
        spec = _spec(tenants=1 + seed % 5, base_rate=0.2 * (1 + seed))
        _check_wellformed(spec, synthesize(spec, seed))


def test_trace_bit_identical_and_seed_sensitive():
    spec = _spec()
    assert synthesize(spec, 7) == synthesize(spec, 7)
    # a different seed must (overwhelmingly) produce a different trace
    assert synthesize(spec, 7) != synthesize(spec, 8)
    # so must a different spec under the same seed
    assert synthesize(spec, 7) != synthesize(
        dataclasses.replace(spec, base_rate=spec.base_rate * 2), 7)


def test_zipf_shares_within_tolerance():
    """Observed tenant shares track the spec's Zipf weights: a long,
    dense trace pins each share within +/-0.05 absolute of its expected
    weight, and the hot-first ordering holds."""
    spec = _spec(name="zipf", horizon=400, base_rate=8.0,
                 burst_rate_mult=1.0, diurnal_amp=0.0, tenants=5,
                 zipf_s=1.2)
    arrivals = synthesize(spec, 3)
    assert len(arrivals) > 2000
    shares = tenant_shares(arrivals)
    for t, w in zip(spec.tenant_ids(), spec.zipf_weights()):
        assert abs(shares.get(t, 0.0) - w) < 0.05, (t, shares.get(t), w)
    assert shares["t0"] > shares["t4"], "hot tenant must dominate the tail"


def test_poisson_mean_tracks_lambda():
    """The chunked Knuth sampler's mean tracks lambda, including rates
    far beyond a single exp(-lam) underflow chunk."""
    for lam in (0.5, 3.0, 25.0):
        rng = seeded_rng(11)
        n = 4000
        mean = sum(_poisson(rng, lam) for _ in range(n)) / n
        assert abs(mean - lam) < 0.1 * lam + 0.05, (lam, mean)
    assert _poisson(seeded_rng(0), 0.0) == 0


def test_diurnal_modulation_shifts_mass():
    """With a strong diurnal sinusoid, the first half-period (rate scaled
    up) must carry visibly more arrivals than the second (scaled down)."""
    spec = _spec(name="diurnal", horizon=64, base_rate=4.0,
                 burst_rate_mult=1.0, diurnal_period=64, diurnal_amp=0.9,
                 tenants=2)
    arrivals = synthesize(spec, 5)
    first = len([a for a in arrivals if a.step < 32])
    second = len(arrivals) - first
    assert first > 1.5 * second, (first, second)


def test_tracespec_asdict_roundtrip():
    spec = _spec(name="rt", tenants=3)
    d = dataclasses.asdict(spec)
    assert TraceSpec(**d) == spec


def test_percentile_nearest_rank():
    xs = list(range(1, 101))
    assert percentile(xs, 50) == 50.0
    assert percentile(xs, 95) == 95.0
    assert percentile(xs, 99) == 99.0
    assert percentile([], 95) is None
    assert percentile([7], 99) == 7.0


def test_lognormal_lengths_clamped():
    spec = _spec(name="fat", horizon=80, base_rate=4.0,
                 prompt_len_max=6, out_tokens_max=4)
    arrivals = synthesize(spec, 9)
    assert arrivals, "trace must not be empty at rate 4"
    assert max(a.prompt_len for a in arrivals) <= 6
    assert max(a.max_new_tokens for a in arrivals) <= 4
    assert min(a.prompt_len for a in arrivals) >= 1
    assert min(a.max_new_tokens for a in arrivals) >= 1
    # the clamp actually binds somewhere on a fat-tailed draw this long
    assert any(a.prompt_len == 6 for a in arrivals)


def test_large_trace_synthesis_scales():
    """The generator is used for million-session traces offline; keep a
    bounded-size canary in tier-1 — ~60k arrivals must stay well-formed
    and cheap (pure python, ~5 rng draws per arrival)."""
    spec = _spec(name="mega", horizon=2000, base_rate=30.0,
                 diurnal_period=500, tenants=100, zipf_s=1.1)
    arrivals = synthesize(spec, 1)
    assert len(arrivals) > 40_000
    _check_wellformed(spec, arrivals)
    shares = tenant_shares(arrivals)
    assert shares["t0"] == max(shares.values())
