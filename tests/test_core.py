"""RC3E control-plane tests: device DB invariants (hypothesis), scheduler,
PR cache, service models."""
import json

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (MAX_SLOTS, BAaaSSession, ClusterSpec, DeviceDB,
                        DeviceState, Hypervisor, JobState, NoCapacityError,
                        RAaaSSession, RSaaSSession, SliceState)


def make_db(nodes=2, devs=2):
    db = DeviceDB()
    for ni in range(nodes):
        db.add_node(f"n{ni}")
        for di in range(devs):
            db.add_device(f"d{ni}-{di}", f"n{ni}")
    return db


# ---------------------------------------------------------------------------
# Property: allocation never oversubscribes, release always frees
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.lists(st.one_of(
    st.tuples(st.just("alloc"), st.sampled_from([1, 2, 4])),
    st.tuples(st.just("release"), st.integers(0, 30)),
), min_size=1, max_size=40))
def test_device_db_slot_invariants(ops):
    db = make_db()
    live = []
    for op, arg in ops:
        if op == "alloc":
            try:
                vs = db.allocate_slice("u", arg, "raas")
                live.append(vs.slice_id)
            except NoCapacityError:
                # full: the DB must indeed have < arg free slots everywhere
                assert all(d.free_slots() < arg
                           for d in db.devices.values()
                           if d.state != DeviceState.EXCLUSIVE)
        else:
            if live:
                db.release(live.pop(arg % len(live)))
        # invariants after every op
        for d in db.devices.values():
            assert 0 <= d.used_slots() <= MAX_SLOTS
            if not d.slices:
                assert d.state in (DeviceState.PARKED, DeviceState.DEAD,
                                   DeviceState.EXCLUSIVE)


def test_pack_first_placement():
    """Energy policy: second 1-slot slice lands on the same device."""
    db = make_db()
    a = db.allocate_slice("u1", 1, "raas")
    b = db.allocate_slice("u2", 1, "raas")
    assert a.device_id == b.device_id
    # a 4-slot tenant must go elsewhere
    c = db.allocate_slice("u3", 4, "raas")
    assert c.device_id != a.device_id


def test_exclusive_excludes_vslices():
    db = make_db(nodes=1, devs=1)
    db.allocate_exclusive("owner")
    with pytest.raises(NoCapacityError):
        db.allocate_slice("other", 1, "raas")


def test_db_json_roundtrip():
    db = make_db()
    db.allocate_slice("u", 2, "raas")
    db2 = DeviceDB.from_json(db.to_json())
    assert db2.utilization() == db.utilization()
    assert set(db2.devices) == set(db.devices)


def test_node_failure_orphans_and_parks():
    db = make_db()
    vs = db.allocate_slice("u", 2, "raas")
    orphans = db.mark_node_dead(db.devices[vs.device_id].node_id)
    assert [o.slice_id for o in orphans] == [vs.slice_id]
    assert db.devices[vs.device_id].state == DeviceState.DEAD
    # capacity still available on the surviving node
    vs2 = db.allocate_slice("u", 2, "raas")
    assert db.devices[vs2.device_id].node_id != db.devices[vs.device_id].node_id


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

def test_scheduler_priority_and_capacity():
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    ran = []
    hv.scheduler.submit("a", 4, run=lambda s: ran.append("low"), priority=20)
    hv.scheduler.submit("b", 4, run=lambda s: ran.append("high"), priority=1)
    hv.scheduler.run_pending()   # only one fits at a time; high goes first
    assert ran[0] == "high"
    hv.scheduler.run_pending()
    assert ran == ["high", "low"]


def test_scheduler_smaller_job_backfills():
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    hv.db.allocate_slice("blocker", 2, "raas")   # 2 of 4 slots gone
    big = hv.scheduler.submit("a", 4, run=lambda s: "big")
    small = hv.scheduler.submit("b", 2, run=lambda s: "small")
    hv.scheduler.run_pending()
    assert small.state == JobState.DONE        # backfilled past the big job
    assert big.state in (JobState.QUEUED, JobState.REQUEUED)


def test_failed_job_requeues_then_fails():
    hv = Hypervisor(ClusterSpec())
    def boom(slice_id):
        raise RuntimeError("core dumped")
    job = hv.scheduler.submit("u", 1, run=boom)
    for _ in range(job.max_attempts):
        hv.scheduler.run_pending()
    assert job.state == JobState.FAILED
    assert job.attempts == job.max_attempts
    # slice released every time
    assert hv.db.utilization() == {d: 0.0 for d in hv.db.devices}


# ---------------------------------------------------------------------------
# Reconfiguration (PR cache) + service models
# ---------------------------------------------------------------------------

def _mm_core(a, b):
    return (a @ b,)


def test_pr_cache_hit_is_fast():
    import jax.numpy as jnp
    import numpy as np
    hv = Hypervisor(ClusterSpec())
    ex = (jnp.ones((16, 16)), jnp.ones((16, 16)))
    e1, t_full, hit1 = hv.reconfig.partial_reconfigure(_mm_core, ex)
    e2, t_pr, hit2 = hv.reconfig.partial_reconfigure(_mm_core, ex)
    assert not hit1 and hit2
    assert e2.fingerprint == e1.fingerprint
    assert t_pr < t_full  # paper Table I: PR ≪ full configuration


def test_cache_keys_geometry_variants_apart():
    """Tuned and default geometry of the SAME core + avals are distinct
    executables: they must coexist in the cache (no collision) and each
    geometry must hit only its own entry on re-bind."""
    import numpy as np
    from repro.core import ProgramCache, Reconfigurator
    rc = Reconfigurator(ProgramCache())
    ex = (np.ones((4, 4), np.float32),) * 2
    _, _, hit_def = rc.partial_reconfigure(_mm_core, ex)
    _, _, hit_tuned = rc.partial_reconfigure(_mm_core, ex,
                                             geometry="dk1024.s8")
    assert not hit_def and not hit_tuned     # no cross-geometry collision
    assert len(rc.cache) == 2
    assert rc.partial_reconfigure(_mm_core, ex)[2]
    assert rc.partial_reconfigure(_mm_core, ex, geometry="dk1024.s8")[2]
    assert not rc.partial_reconfigure(_mm_core, ex, geometry="dk256.s2")[2]


def test_mixed_geometry_eviction_repoints_fp_index():
    """A bounded cache holding several geometry variants of one
    fingerprint: LRU eviction drops exactly one variant, the public
    fingerprint index repoints at a survivor, and the evicted geometry
    misses (recompiles) while the survivors still hit."""
    import numpy as np
    from repro.core import ProgramCache, Reconfigurator
    rc = Reconfigurator(ProgramCache(max_entries=2))
    ex = (np.ones((4, 4), np.float32),) * 2
    e_def, _ = rc.configure(_mm_core, ex)
    e_g2, _ = rc.configure(_mm_core, ex, geometry="g2")
    e_g3, _ = rc.configure(_mm_core, ex, geometry="g3")  # evicts default
    assert len(rc.cache) == 2 and rc.cache.evictions == 1
    assert e_def.fingerprint == e_g2.fingerprint == e_g3.fingerprint
    # the fingerprint stayed resolvable through a surviving variant
    assert rc.cache.entry_for(e_def.fingerprint) in (e_g2, e_g3)
    assert rc.partial_reconfigure(_mm_core, ex, geometry="g2")[2]
    assert not rc.partial_reconfigure(_mm_core, ex)[2]   # default evicted


def test_rsaas_full_device_and_run():
    import numpy as np
    hv = Hypervisor(ClusterSpec())
    sess = RSaaSSession(hv, "alice")
    assert hv.db.device(sess.device.device_id).state == DeviceState.EXCLUSIVE
    sess.program(_mm_core, (np.eye(4, dtype=np.float32),
                            np.ones((4, 4), np.float32)))
    out = sess.run(np.eye(4, dtype=np.float32), np.ones((4, 4), np.float32))
    assert np.allclose(out[0], np.ones((4, 4)))
    sess.close()
    assert hv.db.device(sess.device.device_id).state == DeviceState.PARKED


def test_raas_admission_rejects_bad_core():
    import numpy as np
    from repro.rc2f.admission import AdmissionError
    hv = Hypervisor(ClusterSpec())
    sess = RAaaSSession(hv, "bob")

    import jax.numpy as jnp

    def bad_core(a):
        return (a @ jnp.ones((5,)),)          # shape error -> trace failure

    with pytest.raises(AdmissionError):
        sess.deploy_core(bad_core, (np.ones((4, 4), np.float32),))

    def amplifier(a):                         # 64 B in -> 16 MB out
        return (jnp.broadcast_to(a[0, 0], (2048, 2048)) * 1.0,)

    with pytest.raises(AdmissionError):
        sess.deploy_core(amplifier, (np.ones((4, 4), np.float32),))
    sess.close()


def test_program_cache_fingerprint_lookup():
    """entry_for is the public O(1) fingerprint index the hypervisor's
    execute path uses (no scan over private state)."""
    import numpy as np
    from repro.core import ProgramCache, Reconfigurator
    rc = Reconfigurator(ProgramCache())
    ex = (np.ones((4, 4), np.float32),) * 2
    entry, _ = rc.configure(_mm_core, ex)
    assert rc.cache.entry_for(entry.fingerprint) is entry
    with pytest.raises(KeyError):
        rc.cache.entry_for("deadbeef00000000")


def test_evicted_program_raises_on_execute():
    """A slice whose program was evicted from the cache must fail loudly:
    the hypervisor raises KeyError instead of silently recompiling."""
    import numpy as np
    hv = Hypervisor(ClusterSpec())
    vs = hv.allocate_vslice("u", 1)
    ex = (np.ones((4, 4), np.float32),) * 2
    entry = hv.program_slice(vs.slice_id, _mm_core, ex)
    hv.reconfig.cache.evict(entry.fingerprint)
    with pytest.raises(KeyError, match="evicted"):
        hv.execute(vs.slice_id, *ex)


def test_entry_for_counts_as_lru_use():
    """A program that keeps executing (entry_for lookups) must stay
    resident in a bounded cache; colder entries evict first."""
    from repro.core import ProgramCache, ProgramEntry
    pc = ProgramCache(max_entries=2)
    pc.put(("hot", "a"), ProgramEntry("hot", "exe-hot", None, 0.0))
    pc.put(("cold", "a"), ProgramEntry("cold", "exe-cold", None, 0.0))
    pc.entry_for("hot")                       # the execute path
    pc.put(("new", "a"), ProgramEntry("new", "exe-new", None, 0.0))
    assert pc.entry_for("hot").compiled == "exe-hot"
    with pytest.raises(KeyError):
        pc.entry_for("cold")                  # cold one was evicted


def test_cache_fp_index_repoints_on_variant_eviction():
    """Evicting one aval-variant of a fingerprint must repoint the public
    index at a surviving variant, never at the evicted executable."""
    from repro.core import ProgramCache, ProgramEntry
    pc = ProgramCache(max_entries=2)
    a = ProgramEntry("fp1", "exe-a", None, 0.0)
    b = ProgramEntry("fp1", "exe-b", None, 0.0)
    pc.put(("fp1", "avalA"), a)
    pc.put(("fp1", "avalB"), b)      # index points at b (latest)
    pc.get(("fp1", "avalA"))         # a becomes most-recently-used
    pc.put(("fp2", "avalC"), ProgramEntry("fp2", "exe-c", None, 0.0))
    # LRU evicted (fp1, avalB); the index must fall back to the live a
    assert pc.entry_for("fp1") is a


def test_program_cache_lru_bound():
    """max_entries bounds the bitfile library; LRU entries are evicted and
    their fingerprints drop out of the public index."""
    from repro.core import ProgramCache, Reconfigurator
    import numpy as np

    def make_core(i):
        def core(a):
            return (a * float(i),)
        core.__name__ = f"core_{i}"
        return core

    rc = Reconfigurator(ProgramCache(max_entries=2))
    ex = (np.ones((2, 2), np.float32),)
    entries = [rc.configure(make_core(i), ex, static_desc=str(i))[0]
               for i in range(3)]
    assert len(rc.cache) == 2
    assert rc.cache.evictions == 1
    with pytest.raises(KeyError):
        rc.cache.entry_for(entries[0].fingerprint)   # oldest evicted
    for e in entries[1:]:
        assert rc.cache.entry_for(e.fingerprint) is e


def test_baaas_hides_allocation():
    import numpy as np
    hv = Hypervisor(ClusterSpec())
    hv.register_service(
        "matmul16",
        lambda: (_mm_core, (np.ones((16, 16), np.float32),) * 2))
    sess = BAaaSSession(hv, "carol")
    assert sess.list_services() == ["matmul16"]
    out = sess.invoke("matmul16", np.eye(16, dtype=np.float32),
                      np.ones((16, 16), np.float32))
    assert np.allclose(out[0], np.ones((16, 16)))
    # allocation fully reclaimed afterwards
    assert all(u == 0.0 for u in hv.db.utilization().values())


def test_invoke_service_explicit_args_vs_example_inputs():
    """args=None runs the registered example inputs; an explicit tuple —
    INCLUDING the empty tuple for a zero-input core — is passed through
    verbatim (the old falsy check conflated () with "use the examples")."""
    import numpy as np
    hv = Hypervisor(ClusterSpec())
    hv.register_service("double", lambda: (
        lambda a: (a * 2,), (np.ones((4,), np.float32),)))
    hv.register_service("const7", lambda: (
        lambda: (np.full((3,), 7.0, np.float32),), ()))

    out = hv.invoke_service("double", "u")                 # example inputs
    np.testing.assert_allclose(out[0], np.full((4,), 2.0))
    out = hv.invoke_service("double", "u",
                            (np.arange(4, dtype=np.float32),))
    np.testing.assert_allclose(out[0], [0, 2, 4, 6])
    # zero-input core: explicit () must NOT be replaced by example inputs
    out = hv.invoke_service("const7", "u", ())
    np.testing.assert_allclose(out[0], np.full((3,), 7.0))
    out = hv.invoke_service("const7", "u")                 # None: examples
    np.testing.assert_allclose(out[0], np.full((3,), 7.0))
