"""BatchScheduler policy tests: strict priority ordering, FIFO tiebreak
within a priority class, and max_attempts exhaustion on repeated failure."""
import pytest

from repro.core import (BatchScheduler, ClusterSpec, DeviceDB, Hypervisor,
                        JobState)


def make_db(nodes=1, devs=4):
    db = DeviceDB()
    for ni in range(nodes):
        db.add_node(f"n{ni}")
        for di in range(devs):
            db.add_device(f"d{ni}-{di}", f"n{ni}")
    return db


def test_priority_ordering():
    """Lower priority value runs first regardless of submission order."""
    sched = BatchScheduler(make_db())
    ran = []
    sched.submit("u", 1, run=lambda s: ran.append("p20"), priority=20)
    sched.submit("u", 1, run=lambda s: ran.append("p1"), priority=1)
    sched.submit("u", 1, run=lambda s: ran.append("p10"), priority=10)
    started = sched.schedule_once()
    assert [j.priority for j in started] == [1, 10, 20]


def test_fifo_tiebreak_within_priority():
    """Same priority: jobs start in submission order."""
    sched = BatchScheduler(make_db())
    jobs = [sched.submit("u", 1, priority=5) for _ in range(4)]
    started = sched.schedule_once()
    assert [j.job_id for j in started] == [j.job_id for j in jobs]


def test_fifo_tiebreak_survives_requeue():
    """A requeued job re-enters the FIFO at requeue time with its original
    priority, so it still beats later submissions of the same priority."""
    sched = BatchScheduler(make_db(devs=1))   # 4 slots total
    first = sched.submit("u", 4, run=lambda s: (_ for _ in ()).throw(
        RuntimeError("boom")), priority=5)
    sched.run_pending()                       # fails -> requeued
    assert first.state == JobState.REQUEUED
    second = sched.submit("u", 4, run=lambda s: "ok", priority=5)
    started = sched.schedule_once()           # capacity for one at a time
    assert [j.job_id for j in started] == [first.job_id]


def test_max_attempts_exhaustion():
    sched = BatchScheduler(make_db())
    calls = []

    def boom(slice_id):
        calls.append(slice_id)
        raise RuntimeError("core dumped")

    job = sched.submit("u", 1, run=boom)
    job.max_attempts = 2
    for _ in range(5):                        # extra passes must be no-ops
        sched.run_pending()
    assert job.state == JobState.FAILED
    assert job.attempts == 2
    assert len(calls) == 2
    assert job.error == "core dumped"
    # every attempt's slice was released
    assert all(d.used_slots() == 0 for d in sched.db.devices.values())


def test_failed_terminal_job_not_rescheduled():
    sched = BatchScheduler(make_db())
    job = sched.submit("u", 1, run=lambda s: 1 / 0)
    job.max_attempts = 1
    sched.run_pending()
    assert job.state == JobState.FAILED
    assert sched.queued() == []
    assert sched.schedule_once() == []


def test_hypervisor_scheduler_integration():
    """The hypervisor's scheduler admits by priority under real capacity."""
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    order = []
    hv.scheduler.submit("a", 4, run=lambda s: order.append("low"),
                        priority=30)
    hv.scheduler.submit("b", 4, run=lambda s: order.append("high"),
                        priority=2)
    hv.scheduler.run_pending()
    hv.scheduler.run_pending()
    assert order == ["high", "low"]
