"""BatchScheduler policy tests: strict priority ordering, FIFO tiebreak
within a priority class, and max_attempts exhaustion on repeated failure."""
import pytest

from repro.core import (BatchScheduler, ClusterSpec, DeviceDB, Hypervisor,
                        JobState)


def make_db(nodes=1, devs=4):
    db = DeviceDB()
    for ni in range(nodes):
        db.add_node(f"n{ni}")
        for di in range(devs):
            db.add_device(f"d{ni}-{di}", f"n{ni}")
    return db


def test_priority_ordering():
    """Lower priority value runs first regardless of submission order."""
    sched = BatchScheduler(make_db())
    ran = []
    sched.submit("u", 1, run=lambda s: ran.append("p20"), priority=20)
    sched.submit("u", 1, run=lambda s: ran.append("p1"), priority=1)
    sched.submit("u", 1, run=lambda s: ran.append("p10"), priority=10)
    started = sched.schedule_once()
    assert [j.priority for j in started] == [1, 10, 20]


def test_fifo_tiebreak_within_priority():
    """Same priority: jobs start in submission order."""
    sched = BatchScheduler(make_db())
    jobs = [sched.submit("u", 1, priority=5) for _ in range(4)]
    started = sched.schedule_once()
    assert [j.job_id for j in started] == [j.job_id for j in jobs]


def test_fifo_tiebreak_survives_requeue():
    """A requeued job re-enters the FIFO at requeue time with its original
    priority, so it still beats later submissions of the same priority."""
    sched = BatchScheduler(make_db(devs=1))   # 4 slots total
    first = sched.submit("u", 4, run=lambda s: (_ for _ in ()).throw(
        RuntimeError("boom")), priority=5)
    sched.run_pending()                       # fails -> requeued
    assert first.state == JobState.REQUEUED
    second = sched.submit("u", 4, run=lambda s: "ok", priority=5)
    started = sched.schedule_once()           # capacity for one at a time
    assert [j.job_id for j in started] == [first.job_id]


def test_max_attempts_exhaustion():
    sched = BatchScheduler(make_db())
    calls = []

    def boom(slice_id):
        calls.append(slice_id)
        raise RuntimeError("core dumped")

    job = sched.submit("u", 1, run=boom)
    job.max_attempts = 2
    for _ in range(5):                        # extra passes must be no-ops
        sched.run_pending()
    assert job.state == JobState.FAILED
    assert job.attempts == 2
    assert len(calls) == 2
    assert job.error == "core dumped"
    # every attempt's slice was released
    assert all(d.used_slots() == 0 for d in sched.db.devices.values())


def test_failed_terminal_job_not_rescheduled():
    sched = BatchScheduler(make_db())
    job = sched.submit("u", 1, run=lambda s: 1 / 0)
    job.max_attempts = 1
    sched.run_pending()
    assert job.state == JobState.FAILED
    assert sched.queued() == []
    assert sched.schedule_once() == []


def test_large_job_not_starved_by_small_stream():
    """A 4-slot job repeatedly deferred by NoCapacityError must not be
    bypassed forever by a stream of 1-slot jobs behind it: after
    ``starvation_patience`` deferred passes the scheduler holds capacity
    back for it."""
    sched = BatchScheduler(make_db(devs=1), starvation_patience=3)
    blocker = sched.submit("u", 1, priority=5)
    assert sched.schedule_once() == [blocker]     # 1 of 4 slots busy
    big = sched.submit("big", 4, priority=5)
    admitted_after_holdback = []
    held = False
    for i in range(8):
        small = sched.submit("u", 1, priority=5)
        started = sched.schedule_once()
        assert big not in started                 # blocker still holds a slot
        if held:
            admitted_after_holdback += started
        for j in started:
            if j is not blocker:
                sched.complete(j.job_id)          # smalls come and go
        held = held or big.deferrals >= 3
    assert held, "big job never reached the hold-back threshold"
    # once held back, the small stream stops being admitted past it
    assert admitted_after_holdback == []
    assert any(h["kind"] == "holdback" and h["job"] == big.job_id
               for h in sched.history)
    # when the blocker finally frees its slot, the big job runs first
    sched.complete(blocker.job_id)
    started = sched.schedule_once()
    assert big in started and big.state == JobState.RUNNING
    assert big.deferrals == 0                     # aging reset on admission
    # the held-back smalls run afterwards
    sched.complete(big.job_id)
    assert len(sched.schedule_once()) == 4        # backlog drains again


def test_holdback_skipped_when_job_can_never_fit():
    """Escape hatch: if the capacity blocking a large job belongs to
    allocations the scheduler does not control (e.g. serving sessions),
    holding the queue back would starve everyone forever — backfill must
    continue."""
    db = make_db(devs=1)
    db.allocate_slice("serving-tenant", 2, "baas")   # outside the scheduler
    sched = BatchScheduler(db, starvation_patience=1)
    big = sched.submit("big", 4, priority=5)         # can never fit
    for _ in range(5):
        small = sched.submit("u", 1, priority=5)
        started = sched.schedule_once()
        assert small in started                      # backfill continues
        sched.complete(small.job_id)
    assert big.deferrals >= 5
    assert not any(h["kind"] == "holdback" for h in sched.history)


def test_holdback_does_not_block_higher_priority():
    """Hold-back stops BACKFILL behind the starved job; jobs of strictly
    higher priority still pop first and run."""
    sched = BatchScheduler(make_db(devs=1), starvation_patience=1)
    blocker = sched.submit("u", 1, priority=5)
    sched.schedule_once()
    big = sched.submit("big", 4, priority=5)
    sched.schedule_once()                         # big deferred -> held
    urgent = sched.submit("u", 1, priority=1)
    assert urgent in sched.schedule_once()


def test_hypervisor_scheduler_integration():
    """The hypervisor's scheduler admits by priority under real capacity."""
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    order = []
    hv.scheduler.submit("a", 4, run=lambda s: order.append("low"),
                        priority=30)
    hv.scheduler.submit("b", 4, run=lambda s: order.append("high"),
                        priority=2)
    hv.scheduler.run_pending()
    hv.scheduler.run_pending()
    assert order == ["high", "low"]


def test_migrate_slice_rebinds_running_batch_job():
    """A batch job whose slice is migrated (directed move / consolidate /
    straggler sweep) must follow it: complete() releases the NEW slice
    instead of crashing on the released old one and leaking the new."""
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    job = hv.scheduler.submit("u", 2)
    assert hv.scheduler.schedule_once() == [job]
    old = job.slice_id
    new = hv.migrate_slice(old, target_device="dev-0-1", reason="ops")
    assert new is not None
    assert job.slice_id == new.slice_id != old
    hv.scheduler.complete(job.job_id)           # no KeyError
    assert job.state == JobState.DONE
    assert all(u == 0.0 for u in hv.db.utilization().values())
