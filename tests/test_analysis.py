"""rc3e-check tests: each static pass against fixture modules planting
exactly one violation (with a clean counterpart), the pragma + baseline
machinery, the CLI exit-code contract, and the runtime lifecycle
sanitizer's transition tables.

Fixture files are written under ``tmp_path/repro/<subdir>/`` so the
workspace's canonical relative paths ("runtime/x.py") and the passes'
directory scoping behave exactly as they do on the real tree.
"""
import textwrap
from pathlib import Path

import pytest

from repro.analysis import LifecycleViolation, Sanitizer
from repro.analysis import determinism, hostsync, kernelpass, ownership
from repro.analysis.__main__ import main
from repro.analysis.common import Workspace
from repro.analysis.lifecycle import MACHINES

REPO = Path(__file__).resolve().parents[1]


def _ws(tmp_path, files):
    root = tmp_path / "repro"
    for rel, src in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return Workspace([root])


def _line(src, needle):
    """1-based line of the first fixture line containing ``needle``."""
    for i, ln in enumerate(textwrap.dedent(src).splitlines(), 1):
        if needle in ln:
            return i
    raise AssertionError(f"fixture needle not found: {needle}")


# ---------------------------------------------------------------------------
# ownership pass
# ---------------------------------------------------------------------------

OWNERSHIP_SRC = """
    class Pool:
        def _alloc_one(self, tenant):
            return 1

        def _decref(self, pid):
            pass

        def risky(self, tenant):
            pid = self._alloc_one(tenant)  # leak: validate below may raise
            self.validate(pid)
            return pid

        def careful(self, tenant):
            pid = self._alloc_one(tenant)  # guarded: handler rolls back
            try:
                self.validate(pid)
            except Exception:
                self._decref(pid)
                raise
            return pid

        def sloppy(self, tenant):
            self._alloc_one(tenant)  # dropped handle


    def _mark_cancelled(req):
        req.done = True


    class Fleet:
        def bad_evict(self, req):
            _mark_cancelled(req)  # journal entry never retired

        def good_evict(self, req):
            self.journal.pop(req.request_id, None)
            _mark_cancelled(req)
    """


def test_ownership_pass_exact_findings(tmp_path):
    ws = _ws(tmp_path, {"runtime/pool.py": OWNERSHIP_SRC})
    found = {(f.rule, f.symbol, f.line) for f in ownership.run(ws)}
    assert found == {
        ("unguarded-acquire", "Pool.risky",
         _line(OWNERSHIP_SRC, "# leak")),
        ("discarded-handle", "Pool.sloppy",
         _line(OWNERSHIP_SRC, "# dropped handle")),
        ("unretired-cancel", "Fleet.bad_evict",
         _line(OWNERSHIP_SRC, "# journal entry never retired")),
    }


UNSCRUBBED_SRC = """
    class Engine:
        def _flush_scrub(self):
            pass

        def good_admit(self, slot, tenant, toks):
            self._flush_scrub()
            return self.pool.admit(slot, tenant, toks)

        def good_drain(self, slot, tenant):
            for pid in self.pool.take_scrub():
                self.zero(pid)
            return self.pool.grow(slot, tenant)

        def bad_grow(self, slot, tenant):
            return self.pool.grow(slot, tenant)  # recycled page, no scrub

        def bad_cow(self, slot, b, tenant):
            src, dst = self.pool.cow(slot, b, tenant)  # no scrub either
            return dst

        def waived(self, slot, tenant, toks):
            return self.pool.admit(slot, tenant, toks)  # rc3e: allow-unscrubbed-free

        def not_a_pool(self, slot, tenant, toks):
            return self.queue.admit(slot, tenant, toks)
    """


def test_unscrubbed_free_exact_findings(tmp_path):
    """Page-recycle sites (pool.admit/grow/cow) must sit behind a scrub
    hook in the same function; receiver-matching keeps non-pool ``admit``
    calls (e.g. the admission controller) out of scope."""
    ws = _ws(tmp_path, {"runtime/engine.py": UNSCRUBBED_SRC})
    found = {(f.rule, f.symbol, f.line) for f in ownership.run(ws)
             if f.rule == "unscrubbed-free"}
    assert found == {
        ("unscrubbed-free", "Engine.bad_grow",
         _line(UNSCRUBBED_SRC, "# recycled page, no scrub")),
        ("unscrubbed-free", "Engine.bad_cow",
         _line(UNSCRUBBED_SRC, "# no scrub either")),
    }


# ---------------------------------------------------------------------------
# hostsync pass
# ---------------------------------------------------------------------------

HOTPATH_SRC = """
    import numpy as np
    import jax.numpy as jnp


    class BatchingEngine:
        def step(self):
            logits = self._decode(self._upload(self.tokens))
            return self._sample(logits)

        def _sample(self, logits):
            return int(np.argmax(np.asarray(logits)))  # per-token download

        def _upload(self, tokens):
            return jnp.asarray(tokens)  # rc3e: allow-host-sync (tiny input)

        def _cold_path(self, x):
            return np.asarray(x)
    """


def test_hostsync_flags_only_reachable_unpragmad_markers(tmp_path):
    ws = _ws(tmp_path, {"runtime/engine.py": HOTPATH_SRC})
    found = {(f.symbol, f.line) for f in hostsync.run(ws)}
    # _cold_path is not reachable from step; _upload carries the pragma
    assert found == {("BatchingEngine._sample",
                      _line(HOTPATH_SRC, "# per-token download"))}


# ---------------------------------------------------------------------------
# determinism pass
# ---------------------------------------------------------------------------

DETERMINISM_SRC = """
    import random
    import time


    def bad_clock():
        return time.time()  # wall clock

    def ok_clock():
        return time.monotonic()

    def bad_rng():
        return random.random()  # process-global rng

    def bad_ctor(seed):
        return random.Random(seed)  # bypasses the choke point

    def seeded_rng(seed):
        return random.Random(seed)

    def bad_for(xs):
        for x in set(xs):  # salted order
            yield x

    def ok_for(xs):
        for x in sorted(set(xs)):
            yield x
    """


def test_determinism_pass_exact_findings(tmp_path):
    ws = _ws(tmp_path, {"runtime/chaosy.py": DETERMINISM_SRC})
    found = {(f.rule, f.symbol, f.line) for f in determinism.run(ws)}
    assert ("time-time", "bad_clock",
            _line(DETERMINISM_SRC, "# wall clock")) in found
    assert ("unseeded-random", "bad_rng",
            _line(DETERMINISM_SRC, "# process-global rng")) in found
    # even a SEEDED Random() outside seeded_rng is flagged...
    assert ("unseeded-random", "bad_ctor",
            _line(DETERMINISM_SRC, "# bypasses the choke point")) in found
    assert ("set-iteration", "bad_for",
            _line(DETERMINISM_SRC, "# salted order")) in found
    # ...while the helper itself, monotonic() and sorted(set()) are clean
    symbols = {f.symbol for f in determinism.run(ws)}
    assert {"seeded_rng", "ok_clock", "ok_for"} & symbols == set()


def test_determinism_scoping_excludes_other_dirs(tmp_path):
    # time/set rules are scoped to runtime/ + core/; randomness is global
    ws = _ws(tmp_path, {"kernels/free.py": DETERMINISM_SRC})
    rules = {f.rule for f in determinism.run(ws)}
    assert rules == {"unseeded-random"}


ROUND_COUNTER_SRC = """
    class Loop:
        def bad_pace(self, fleet):
            return fleet.steps % 4  # round-counter read

        def ok_count(self, eng):
            eng.steps += 1          # an engine counting its own steps
            return self.ticks

        def waived(self, fleet):  # rc3e: allow-round-counter
            return fleet.steps
    """


def test_round_counter_flagged_in_event_loop(tmp_path):
    ws = _ws(tmp_path, {"runtime/events.py": ROUND_COUNTER_SRC})
    found = {(f.rule, f.symbol, f.line) for f in determinism.run(ws)}
    assert ("round-counter", "Loop.bad_pace",
            _line(ROUND_COUNTER_SRC, "# round-counter read")) in found
    # stores/augassigns and the loop's own ticks are not reads of the
    # fleet round counter; the pragma waives its whole function
    rc_symbols = {f.symbol for f in determinism.run(ws)
                  if f.rule == "round-counter"}
    assert rc_symbols == {"Loop.bad_pace"}


def test_round_counter_scoped_to_event_loop_module(tmp_path):
    # the rule targets runtime/events.py only: the lockstep fleet reads
    # its own round counter legitimately everywhere else
    ws = _ws(tmp_path, {"runtime/fleet.py": ROUND_COUNTER_SRC})
    assert not [f for f in determinism.run(ws)
                if f.rule == "round-counter"]


# ---------------------------------------------------------------------------
# kernel pass
# ---------------------------------------------------------------------------

KERNEL_SRC = """
    def bad_kernel(x_ref, o_ref):
        v = x_ref[0]
        if v > 0:  # traced branch
            o_ref[0] = v

    def good_kernel(x_ref, o_ref, *, bias_ref=None):
        if bias_ref is None:
            o_ref[0] = x_ref[0]

    def bad_launch(M, bm):
        grid = (M // bm,)  # unproven divisibility
        return grid

    def good_launch(M, bm):
        assert M % bm == 0
        grid = (M // bm,)
        return grid

    def padded_launch(M, bm):
        Mp = -(-M // bm) * bm
        grid = (Mp // bm,)
        return grid
    """


def test_kernel_pass_exact_findings(tmp_path):
    ws = _ws(tmp_path, {"kernels/toy.py": KERNEL_SRC})
    found = {(f.rule, f.symbol, f.line) for f in kernelpass.run(ws)
             if f.rule != "registry-shapes"}
    assert found == {
        ("traced-branch", "bad_kernel",
         _line(KERNEL_SRC, "# traced branch")),
        ("grid-divisibility", "bad_launch",
         _line(KERNEL_SRC, "# unproven divisibility")),
    }


def test_registry_shapes_clean_on_real_registry():
    # executed check: every registered arch (full AND reduced) tiles
    # cleanly against the decode block / page size / lane constants
    assert kernelpass.check_registry_shapes() == []


# ---------------------------------------------------------------------------
# CLI + baseline machinery
# ---------------------------------------------------------------------------

def test_cli_baseline_roundtrip(tmp_path, capsys):
    root = tmp_path / "repro" / "runtime"
    root.mkdir(parents=True)
    (root / "bad.py").write_text(textwrap.dedent(OWNERSHIP_SRC))
    baseline = tmp_path / "baseline.json"
    args = [str(tmp_path / "repro"), "--baseline", str(baseline)]
    # fresh findings fail the build...
    assert main(args) == 1
    # ...grandfathering them (exit 0) makes the same tree pass...
    assert main(args + ["--write-baseline"]) == 0
    assert main(args) == 0
    # ...and a NEW violation still fails against the old baseline
    (root / "new.py").write_text(textwrap.dedent(HOTPATH_SRC))
    assert main(args) == 1
    capsys.readouterr()


def test_merged_tree_is_clean():
    """Acceptance: `python -m repro.analysis src/` exits 0 on this tree
    (every remaining marker is pragma-justified; baseline is empty)."""
    assert main([str(REPO / "src"), "--baseline",
                 str(REPO / "analysis_baseline.json")]) == 0


# ---------------------------------------------------------------------------
# lifecycle sanitizer
# ---------------------------------------------------------------------------

def _fresh():
    s = Sanitizer()
    s.enable()
    return s


def test_machine_tables_are_closed():
    # every transition's source and target are states the table knows
    # (initial, a transition target, or terminal) — no typo'd states
    for name, m in MACHINES.items():
        states = {m.initial} | set(m.transitions.values()) | set(m.terminal)
        for (src, _), dst in m.transitions.items():
            assert src in states, f"{name}: unknown source {src!r}"
            assert dst in states, f"{name}: unknown target {dst!r}"


def test_slot_occupy_release_alternate():
    s = _fresh()
    s.emit("slot", (1, 0), "occupy")
    s.emit("slot", (1, 0), "release")
    s.emit("slot", (1, 0), "occupy")
    with pytest.raises(LifecycleViolation, match="illegal event 'occupy'"):
        s.emit("slot", (1, 0), "occupy")        # double-occupy
    s.emit("slot", (1, 0), "release")
    with pytest.raises(LifecycleViolation, match="illegal event 'release'"):
        s.emit("slot", (1, 0), "release")       # double-release


def test_page_double_free_and_share_of_free_page():
    s = _fresh()
    s.emit("page", (1, 7), "alloc")
    s.emit("page", (1, 7), "share")
    s.emit("page", (1, 7), "unshare")
    s.emit("page", (1, 7), "free")
    with pytest.raises(LifecycleViolation):
        s.emit("page", (1, 7), "free")          # double-free
    with pytest.raises(LifecycleViolation):
        s.emit("page", (1, 8), "share")         # incref of never-alloc'd


def test_request_terminal_pops_and_stays_dead():
    s = _fresh()
    s.emit("request", 42, "submit")
    s.emit("request", 42, "admit")
    s.emit("request", 42, "chunk")              # event loop: chunked prefill
    s.emit("request", 42, "ready")
    s.emit("request", 42, "preempt")            # back to queue
    s.emit("request", 42, "admit")
    s.emit("request", 42, "ready")              # lockstep: one breath
    s.emit("request", 42, "finish")
    assert s.live("request") == 0               # DONE popped: bounded memory
    # decode-after-settle: the key resolves against NEW again, where
    # 'admit' is still illegal — the bug class survives the pop
    with pytest.raises(LifecycleViolation):
        s.emit("request", 42, "admit")


def test_request_handoff_and_orphan_paths():
    s = _fresh()
    s.emit("request", 1, "submit")
    s.emit("request", 1, "admit")
    s.emit("request", 1, "drain")               # live hand-off
    s.emit("request", 1, "adopt")               # page-copied to target
    s.emit("request", 1, "orphan")              # its device died
    s.emit("request", 1, "requeue")             # journal replay
    s.emit("request", 1, "cancel")
    with pytest.raises(LifecycleViolation):
        s.emit("request", 1, "cancel")          # already settled


def test_journal_replay_after_retire_raises():
    s = _fresh()
    s.emit("journal", (1, 5), "append")
    s.emit("journal", (1, 5), "replay")
    s.emit("journal", (1, 5), "retire")
    with pytest.raises(LifecycleViolation):
        s.emit("journal", (1, 5), "replay")     # settled request replayed
    # a re-append after retire starts a NEW entry — legal by design: the
    # fleet's shared itertools.count never reuses a request id, so the
    # popped key can only mean a genuinely new journal entry
    s.emit("journal", (1, 5), "append")


def test_device_dead_is_sticky():
    s = _fresh()
    s.emit("device", (1, "dev-0"), "activate")
    s.emit("device", (1, "dev-0"), "kill")
    assert s.state("device", (1, "dev-0")) == "DEAD"
    # sticky terminal: post-mortem events violate instead of restarting
    with pytest.raises(LifecycleViolation, match="terminal"):
        s.emit("device", (1, "dev-0"), "activate")
    s.emit("device", (1, "dev-1"), "park")      # idempotent park from PARKED


def test_disabled_sanitizer_is_inert():
    s = Sanitizer()
    s.disable()
    s.emit("slot", 0, "release")                # illegal — but unchecked
    s.emit("nonexistent-machine", 0, "x")       # not even resolved
    assert s.stats() == {}


def test_scope_tokens_never_repeat():
    s = _fresh()
    toks = [s.scope() for _ in range(100)]
    assert len(set(toks)) == 100
    assert toks == sorted(toks)
