"""Quickstart: the paper's workflow end to end on one box.

1. Stand up the RC3E hypervisor over a simulated 2-node inventory.
2. RAaaS: allocate a vSlice, deploy a streaming matmul core (the paper's §V
   example) through admission + "HLS" (jit), stream data through it.
3. Swap the core via partial reconfiguration (cache hit) and show the
   latency gap vs the cold configuration.
4. BAaaS: invoke a provider-registered service without seeing any device.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.core import BAaaSSession, ClusterSpec, Hypervisor, RAaaSSession
from repro.rc2f import CoreSpec, SharedLink, StreamSpec, core_throughput


def main():
    hv = Hypervisor(ClusterSpec(n_nodes=2, devices_per_node=2))
    print("== RC3E inventory ==")
    for dev, util in hv.status()["utilization"].items():
        print(f"  {dev}: {util:.0%} used")

    # ---- RAaaS: user core on a vSlice ----
    sess = RAaaSSession(hv, "alice", slots=1)
    print(f"\nallocated {sess.vslice.slice_id} on {sess.vslice.device_id}")

    def mm_core(a, b):
        return (a @ b,)

    g = 64
    spec = CoreSpec("mm16", (StreamSpec((g, 16, 16)), StreamSpec((g, 16, 16))),
                    (StreamSpec((g, 16, 16)),))

    def mm_stream_core(a, b):
        import jax.numpy as jnp
        return (jnp.einsum("gij,gjk->gik", a, b),)

    t0 = time.perf_counter()
    sess.deploy_core(mm_stream_core, spec.example_inputs(), "mm16")
    t_cold = time.perf_counter() - t0
    a = np.random.rand(g, 16, 16).astype(np.float32)
    out = sess.run(a, a)
    print(f"deployed + ran streaming matmul core: out {out[0].shape}, "
          f"cold configure {t_cold * 1e3:.1f} ms")

    t0 = time.perf_counter()
    sess.deploy_core(mm_stream_core, spec.example_inputs(), "mm16")
    t_pr = time.perf_counter() - t0
    print(f"partial reconfiguration (cache hit): {t_pr * 1e3:.2f} ms "
          f"({t_cold / max(t_pr, 1e-9):.0f}x faster — paper Table I: 29.5 s "
          "vs 0.9 s)")

    # ---- paper Table III contention forecast for this core ----
    link = SharedLink()
    print("\nper-core MB/s if co-resident (paper Table III):",
          [round(core_throughput(509e6, link, n) / 1e6) for n in (1, 2, 4)])

    # ---- BAaaS ----
    hv.register_service("vector-double", lambda: (
        lambda v: (v * 2,), (np.ones((8,), np.float32),)))
    ba = BAaaSSession(hv, "bob")
    print("\nBAaaS services visible to bob:", ba.list_services())
    print("invoke:", ba.invoke("vector-double",
                               np.arange(8, dtype=np.float32))[0])

    sess.close()
    print("\nfinal utilization:", hv.status()["utilization"])


if __name__ == "__main__":
    main()
