"""End-to-end training driver: train a smollm-family model through the full
stack — RC3E allocation, StreamFIFO-fed synthetic data, AdamW, periodic
checkpointing with restart support.

Default runs a width-reduced smollm (~10M params) for 300 steps on CPU and
prints the loss trajectory (which must fall under the unigram entropy).
``--full`` selects the real 135M config (same code path; hours on CPU).

Run:  PYTHONPATH=src python examples/train_smollm.py [--steps 300] [--full]
"""
import argparse
import time

import jax
import numpy as np

from repro.ckpt import latest_step, restore, save
from repro.configs import get_config
from repro.core import ClusterSpec, Hypervisor
from repro.data import DataConfig, DataPipeline
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.rc2f import StreamFIFO
from repro.runtime import TrainOpts, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="use the real smollm-135m config")
    ap.add_argument("--ckpt-dir", default="results/train_smollm")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("smollm-135m").replace(dtype="float32")
    if not args.full:
        cfg = cfg.replace(n_layers=6, d_model=256, n_heads=8, n_kv_heads=4,
                          head_dim=32, d_ff=768, vocab_size=2048)
    model = get_model(cfg)
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.1f}M params, "
          f"{'full' if args.full else 'reduced'})")

    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    vs = hv.allocate_vslice("trainer", slots=4)
    print(f"RC3E: training on {vs.slice_id} ({vs.device_id})")

    opts = TrainOpts(opt=AdamWConfig(lr=3e-3, warmup_steps=20,
                                     total_steps=args.steps),
                     loss_chunk=64)
    step_fn = jax.jit(make_train_step(model, opts))

    like = jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(0), opts))
    try:
        state, start = restore(args.ckpt_dir, like)
        print(f"restored checkpoint at step {start}")
    except FileNotFoundError:
        state, start = init_train_state(model, jax.random.PRNGKey(0), opts), 0

    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                   seq_len=args.seq, batch_size=args.batch))
    print(f"unigram entropy (loss floor for context-free): "
          f"{data.unigram_entropy_nats():.3f} nats")

    fifo = StreamFIFO(depth=2).feed(
        data.batch_at(i) for i in range(start, args.steps))
    t0 = time.time()
    losses = []
    for i, batch in zip(range(start, args.steps), fifo):
        state, metrics = step_fn(state, batch)
        hv.monitor.record_step(vs.slice_id,
                               (time.time() - t0) * 1e3 / (i - start + 1))
        losses.append(float(metrics["loss"]))
        if (i + 1) % 50 == 0:
            save(state, args.ckpt_dir, step=i + 1, keep=2)
            tput = args.batch * args.seq * (i - start + 1) / (time.time() - t0)
            print(f"step {i + 1:4d}  loss {losses[-1]:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  {tput:,.0f} tok/s")
    print(f"\nloss: first5 {np.round(losses[:5], 3)} -> "
          f"last5 {np.round(losses[-5:], 3)}")
    assert losses[-1] < losses[0]
    hv.release(vs.slice_id)
    print("done; slice released, device parked.")


if __name__ == "__main__":
    main()
