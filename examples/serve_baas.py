"""BAaaS serving: a provider-prebuilt LM served behind the hypervisor with
continuous batching over a PAGED KV-cache pool — users submit prompts,
never see devices (paper §III-C); device memory is virtualized into pages
exactly as compute is virtualized into vSlices.

Run:  PYTHONPATH=src python examples/serve_baas.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import ClusterSpec, Hypervisor
from repro.models import get_model
from repro.runtime import BatchingEngine


def main():
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))

    # provider prepares the service: model + weights ("prebuilt bitfile")
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    vs = hv.allocate_vslice("provider:lm-service", slots=2, service_model="baas")
    # 8 slots share a page pool holding only 4 dense rows' worth of cache:
    # short requests take 1-2 pages instead of a whole max_len row
    engine = BatchingEngine(model, params, n_slots=8, max_len=96,
                            paged=True, page_size=16,
                            cache_pages=4 * (96 // 16) + 1)
    print(f"lm-service up on {vs.slice_id} ({vs.device_id}), 8 decode "
          f"slots over a {engine.pool.total_pages}-page KV pool")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (5, 3, 8, 4, 6, 2, 7, 5)]
    # two of a kind: identical prompts admitted together share prefix
    # pages copy-on-write
    prompts[1] = list(prompts[0])
    t0 = time.monotonic()
    reqs = [engine.submit(p, max_new_tokens=12) for p in prompts]
    drained = engine.run_until_idle()
    assert drained, "engine stalled with work still queued"

    total_new = sum(len(r.out_tokens) for r in reqs)
    wall = time.monotonic() - t0
    for r in reqs:
        ttft = (r.first_token_at - r.submitted_at) * 1e3
        print(f"req {r.request_id}: prompt {len(r.prompt)} tok -> "
              f"{len(r.out_tokens)} new ({r.finish_reason}), "
              f"TTFT {ttft:.0f} ms, tokens {r.out_tokens[:6]}...")
    print(f"\n{len(reqs)} requests, {total_new} tokens in {wall:.2f}s "
          f"({total_new / wall:.1f} tok/s aggregate, {engine.steps} engine "
          "steps — continuous batching shares every step across slots)")
    print(f"page pool: {engine.page_stats()}")
    hv.release(vs.slice_id)


if __name__ == "__main__":
    main()
