"""Multi-tenant demo — the paper's headline scenario (§V, Table III) at two
levels:

Part 1 (RC2F shell): four tenants' cores co-resident on ONE physical device,
throughput per core degrading as they share bandwidth while total utilization
rises; then one tenant is hot-swapped (partial reconfiguration) without
disturbing others.

Part 2 (serving gateway): three tenants' LM inference traffic routed through
the RC3E hypervisor — quota-checked sessions on vSlices, requests batched
across tenants on the shared device, every request logged against its slice.

Part 3 (serving fleet): one engine per physical device; a hot tenant is
flagged by the straggler monitor mid-stream and its session — queued AND
in-flight requests, generated tokens included — is handed off LIVE to a
second device's engine (the paper's outlook: "migration of user designs
between vFPGAs and physical FPGAs").

Run:  PYTHONPATH=src python examples/multi_tenant.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.rc2f import CoreSpec, FusedShell, SharedLink, StreamSpec, core_throughput

G, N_BLOCKS = 64, 12
SPEC = CoreSpec("mm16", (StreamSpec((G, 16, 16)), StreamSpec((G, 16, 16))),
                (StreamSpec((G, 16, 16)),))


def mm_core(a, b):
    return jnp.einsum("gij,gjk->gik", a, b)


def axpy_core(a, b):
    return a * 2.0 + b


def measure(shell, slots, blocks):
    inputs = {s: blocks for s in slots}
    shell.run_cycle(inputs)      # warm
    t0 = time.perf_counter()
    for _ in range(N_BLOCKS):
        out = shell.run_cycle(inputs)
    jax.block_until_ready(list(out.values())[0])
    dt = time.perf_counter() - t0
    per_core = N_BLOCKS * 2 * blocks[0].nbytes / dt / 1e6
    return per_core, per_core * len(slots)


def main():
    a = np.random.rand(G, 16, 16).astype(np.float32)
    link = SharedLink()
    print("paper Table III model (16x16, MB/s/core):",
          [round(core_throughput(509e6, link, n) / 1e6) for n in (1, 2, 4)])

    print("\nmeasured on this host (one physical device, fused shell):")
    shell = FusedShell(4)
    history = []
    for n in (1, 2, 4):
        for s in range(n):
            shell.load(s, mm_core, SPEC, f"tenant{s}")
        per, total = measure(shell, list(range(n)), (a, a))
        history.append((n, per, total))
        print(f"  {n} tenant(s): {per:7.1f} MB/s/core, {total:7.1f} MB/s total")
    base = history[0][2]
    print(f"  -> total throughput with 4 tenants = "
          f"{history[-1][2] / base:.2f}x of 1 tenant "
          "(paper: utilization maximized despite per-core loss)")

    # hot swap tenant 2's core (PR) and verify tenant 0 output unchanged
    before = shell.run_cycle({s: (a, a) for s in range(4)})
    shell.load(2, axpy_core, SPEC, "tenant2-v2")
    after = shell.run_cycle({s: (a, a) for s in range(4)})
    ok = np.allclose(np.asarray(before[0]), np.asarray(after[0]))
    print(f"\npartial reconfiguration of slot 2: tenant 0 output unchanged: {ok}")
    print("slot 2 now computes 2a+b:",
          np.allclose(np.asarray(after[2]), 2 * a + a))

    serving_gateway_demo()
    fleet_migration_demo()


def serving_gateway_demo():
    """Part 2: multi-tenant LM serving through the hypervisor."""
    from repro.configs import get_config, reduced
    from repro.core import ClusterSpec, Hypervisor
    from repro.models import get_model
    from repro.rc2f import AdmissionError
    from repro.runtime import ServingGateway

    print("\n--- serving gateway: 3 tenants, one device, one hypervisor ---")
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    gw = ServingGateway(hv, model, params, n_slots=4, max_len=96)
    for tenant, slots in (("alice", 2), ("bob", 1), ("carol", 1)):
        s = gw.open_session(tenant, slots=slots)
        print(f"  {tenant}: {slots}-slot vSlice {s.slice_id}")

    # quotas are enforced before any allocation happens
    try:
        gw.open_session("alice-2nd-core", slots=4)   # baas quota is 2 slots
    except AdmissionError as e:
        print(f"  quota rejection works: {e}")

    rng = np.random.default_rng(1)
    for i in range(9):
        tenant = ("alice", "bob", "carol")[i % 3]
        gw.submit(tenant, rng.integers(0, cfg.vocab_size, size=5).tolist(),
                  max_new_tokens=8)
    t0 = time.monotonic()
    gw.run_until_idle()
    wall = time.monotonic() - t0

    for tenant, s in sorted(gw.stats().items()):
        print(f"  {tenant}: {s['served']} requests, {s['tokens_out']} tokens "
              f"on {s['slice']}")
    served = [e for e in hv.log if e["kind"] == "serve"]
    print(f"  {len(served)} requests audited in Hypervisor.log, "
          f"{gw.engine.steps} shared decode steps, {wall:.2f}s "
          f"(cross-tenant continuous batching)")
    gw.close()


def fleet_migration_demo():
    """Part 3: live migration of a serving tenant between devices."""
    from repro.configs import get_config, reduced
    from repro.core import ClusterSpec, Hypervisor
    from repro.models import get_model
    from repro.runtime import GatewayFleet

    print("\n--- serving fleet: live session hand-off between devices ---")
    cfg = reduced(get_config("smollm-135m")).replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=2))
    fleet = GatewayFleet(hv, model, params, n_slots=4, max_len=96)
    hot = fleet.open_session("hot", slots=2)
    cold = fleet.open_session("cold", slots=1)
    print(f"  hot:  {hot.slice_id} on {fleet.device_of('hot')}  "
          f"cold: {cold.slice_id} on {fleet.device_of('cold')}")

    rng = np.random.default_rng(2)
    reqs = [fleet.submit("hot", rng.integers(0, cfg.vocab_size,
                                             size=6).tolist(),
                         max_new_tokens=12) for _ in range(4)]
    fleet.submit("cold", rng.integers(0, cfg.vocab_size, size=6).tolist(),
                 max_new_tokens=12)
    for _ in range(4):            # decoding is under way...
        fleet.step()
    mid = [len(r.out_tokens) for r in reqs]

    # ...when the monitor flags the hot tenant as a straggler
    for _ in range(8):
        hv.monitor.record_step(hot.slice_id, 400.0)
        hv.monitor.record_step(cold.slice_id, 100.0)
    fleet.rebalance()
    h = fleet.handoffs[-1]
    print(f"  straggler sweep: {h['tenant']} moved "
          f"{h['old_device']} -> {h['new_device']} with "
          f"{h['moved_requests']} request(s) in flight "
          f"(tokens generated so far: {mid})")

    fleet.run_until_idle()
    assert all(len(r.out_tokens) == 12 for r in reqs)
    served = [e for e in hv.log if e["kind"] == "serve"]
    print(f"  all {len(served)} requests completed; hot finished on "
          f"{fleet.device_of('hot')} "
          f"({fleet.engine_for('hot').steps} steps there)")
    fleet.close()
    print(f"  engines drained and parked; devices: "
          f"{ {d.device_id: d.state.value for d in hv.db.devices.values()} }")


if __name__ == "__main__":
    main()
