"""zamba2-7b [hybrid]: 81L d_model=3584, Mamba2 backbone + one SHARED
attention+MLP block applied every 6th site, d_ff=14336, vocab=32000,
ssm_state=64. [arXiv:2411.15242]"""
from repro.configs.base import (MIXER_SHARED_ATTN, MIXER_SSM, ModelConfig,
                                SSMConfig)


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
        d_ff=14336, vocab_size=32000,
        pattern=(MIXER_SSM,) * 5 + (MIXER_SHARED_ATTN,),
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=256),
        rope_theta=10_000.0,
        tie_embeddings=True, max_seq_len=1_048_576,
    )
