"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4), 128 experts
top-8 d_expert=768, vocab=151936. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=768, vocab_size=151936,
        pattern=(ATTN_GLOBAL,),
        moe=MoEConfig(n_experts=128, top_k=8, d_expert=768, n_shared=0,
                      norm_topk=True),
        qk_norm=True, rope_theta=1_000_000.0,
        tie_embeddings=False, max_seq_len=40960,
    )
