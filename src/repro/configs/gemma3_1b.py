"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.
5:1 local:global attention, 128k context. [hf:google/gemma-3-1b-pt]"""
from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
        d_ff=6912, vocab_size=262144,
        pattern=(ATTN_LOCAL,) * 5 + (ATTN_GLOBAL,),
        window=512,
        rope_theta=1_000_000.0, rope_local_theta=10_000.0,
        qk_norm=True, post_norm=True, embed_scale=True,
        act="gelu", tie_embeddings=True, max_seq_len=131072,
    )
