"""mamba2-370m [ssm]: 48L d_model=1024, attention-free SSD, vocab=50280,
ssm_state=128. [arXiv:2405.21060]"""
from repro.configs.base import MIXER_SSM, ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1, head_dim=64,
        d_ff=0, vocab_size=50280,
        pattern=(MIXER_SSM,),
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=256),
        tie_embeddings=True, max_seq_len=1_048_576,
    )
