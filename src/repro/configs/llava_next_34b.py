"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000; anyres vision tower is a stub (precomputed patch embeddings
prepended to the token stream). [hf:llava-hf/llava-v1.6]"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
        d_ff=20480, vocab_size=64000,
        pattern=(ATTN_GLOBAL,),
        n_patches=576,
        rope_theta=5_000_000.0,
        tie_embeddings=False, max_seq_len=4096,
    )
