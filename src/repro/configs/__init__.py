from repro.configs.base import (LONG_CONTEXT_ARCHS, SHAPES, EncoderConfig,
                                MLAConfig, ModelConfig, MoEConfig, ShapeCell,
                                SSMConfig)
from repro.configs.registry import ARCH_IDS, all_configs, get_config, reduced
