"""smollm-135m [dense]: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
llama-arch small. [hf:HuggingFaceTB/SmolLM-135M]"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m", family="dense",
        n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, head_dim=64,
        d_ff=1536, vocab_size=49152,
        pattern=(ATTN_GLOBAL,),
        rope_theta=10_000.0,
        tie_embeddings=True, max_seq_len=2048,
    )
