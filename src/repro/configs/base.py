"""Model / system configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; the stage planner
(`repro.models.stages`) turns the per-layer pattern into grouped ``lax.scan`` stages so
deep models lower to small HLO (fast SPMD compiles at 256/512 devices).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 = full-rank q projection (v2-lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 64
    top_k: int = 6
    d_expert: int = 1408          # per-expert ffn hidden size
    n_shared: int = 0             # shared experts always active
    first_k_dense: int = 0        # first k layers use a dense mlp instead
    dense_d_ff: int = 0           # hidden size of those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    norm_topk: bool = True
    # data-parallel shard count the dispatch is local to (set by the
    # launcher from the mesh): tokens reshape to (dp_shards, T_local) so the
    # position-in-expert cumsum never crosses shards
    dp_shards: int = 1


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64            # SSD head dim (P)
    n_groups: int = 1
    chunk: int = 256              # SSD chunk length
    a_init_range: Tuple[float, float] = (1.0, 16.0)


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper). Frontend is a stub:
    inputs are precomputed frame embeddings (batch, frames, d_model)."""

    n_layers: int = 4
    max_frames: int = 1500


@dataclass(frozen=True)
class GeometryConfig:
    """Kernel geometry for the serving dataplane — set by the auto-tuner
    (``repro.tuning``) per device class. Defaults are literal copies of the
    hand-picked constants in ``kernels/registry.py`` (this module stays
    jax-free, so it cannot import them; a test pins the two in sync).

    ``kernel_force`` overrides the Pallas-vs-reference dispatch in the
    attention layers ("kernel" | "interpret" | "ref"; "" = by backend).
    Serving-only: the Pallas paths define no VJP."""

    decode_block_k: int = 512
    flash_block_q: int = 256
    flash_block_k: int = 256
    mm_block_m: int = 128
    mm_block_n: int = 128
    mm_block_k: int = 128
    kernel_force: str = ""


# ---------------------------------------------------------------------------
# Main config
# ---------------------------------------------------------------------------

ATTN_GLOBAL = "global"
ATTN_LOCAL = "local"
MIXER_SSM = "ssm"
MIXER_SHARED_ATTN = "shared_attn"   # zamba2: one weight set reused at every site


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense | ssm | moe | hybrid | audio | vlm

    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0               # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 32000

    # Per-layer mixer pattern. ``pattern`` has length ``pattern_period`` and is
    # tiled across n_layers (remainder = prefix of the pattern). Entries are
    # ATTN_GLOBAL / ATTN_LOCAL / MIXER_SSM / MIXER_SHARED_ATTN.
    pattern: Tuple[str, ...] = (ATTN_GLOBAL,)

    window: int = 4096              # sliding window for ATTN_LOCAL layers
    attn_softcap: float = 0.0       # gemma2 logit soft-capping (0 = off)
    final_softcap: float = 0.0
    qk_norm: bool = False           # qwen3-style RMSNorm on q/k heads
    causal: bool = True             # False for encoder stacks
    use_rope: bool = True           # False for sinusoidal-posemb stacks
    embed_scale: bool = False       # gemma: embeddings scaled by sqrt(d)
    rope_theta: float = 10000.0
    rope_local_theta: float = 0.0   # gemma3: different theta for local layers (0=same)
    query_scale: float = 0.0        # 0 -> head_dim ** -0.5
    attn_tp: str = "heads"          # set to "seq" by the launcher when
                                    # n_kv_heads doesn't divide the TP axis
    tp_mode: str = "tp"             # "tp" | "pure_dp" | "fsdp"
    kv_quant: bool = False          # int8 KV cache (+fp32 row scales):
                                    # halves decode cache bytes per device
    max_seq_len: int = 131072

    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None

    # VLM stub: number of prepended patch-embedding positions.
    n_patches: int = 0

    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    act: str = "silu"               # silu | gelu
    post_norm: bool = False         # gemma2/3 use post-block norms as well

    dtype: str = "bfloat16"         # activation/compute dtype
    param_dtype: str = "float32"

    # serving kernel geometry (auto-tuner output; defaults = hand-picked)
    geometry: GeometryConfig = GeometryConfig()

    # ---------------- derived helpers ----------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def layer_kinds(self) -> Tuple[str, ...]:
        """Mixer kind per layer, tiling the pattern."""
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        if self.mla is not None:
            m = self.mla
            qd = (m.qk_nope_head_dim + m.qk_rope_head_dim) * self.n_heads
            p = d * qd                                      # q proj (full rank)
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)  # kv down + rope k
            p += m.kv_lora_rank * self.n_heads * (
                m.qk_nope_head_dim + m.v_head_dim)          # kv up
            p += self.n_heads * m.v_head_dim * d            # o proj
            return p
        return (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                + self.n_heads * hd * d)

    def _mlp_params(self, layer_idx: int) -> int:
        d = self.d_model
        if self.moe is not None:
            mo = self.moe
            if layer_idx < mo.first_k_dense:
                return 3 * d * (mo.dense_d_ff or self.d_ff)
            return (3 * d * mo.d_expert * (mo.n_experts + mo.n_shared)
                    + d * mo.n_experts)
        return 3 * d * self.d_ff

    def _ssm_params(self) -> int:
        d, s = self.d_model, self.ssm
        d_in = s.expand * d
        n_heads_ssm = d_in // s.head_dim
        p = d * (2 * d_in + 2 * s.n_groups * s.d_state + n_heads_ssm)  # in_proj
        p += s.d_conv * (d_in + 2 * s.n_groups * s.d_state)            # conv
        p += 2 * n_heads_ssm                                           # A, D
        p += d_in * d                                                  # out proj
        return p

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, v = self.d_model, self.vocab_size
        total = v * d
        if not self.tie_embeddings:
            total += v * d
        shared_counted = False
        for i, kind in enumerate(self.layer_kinds()):
            if kind == MIXER_SSM:
                total += self._ssm_params()
            elif kind == MIXER_SHARED_ATTN:
                if not shared_counted:   # zamba2: one weight set reused
                    total += self._attn_params() + 3 * d * self.d_ff
                    shared_counted = True
            else:  # global/local attention layer + its mlp
                total += self._attn_params() + self._mlp_params(i)
        if self.encoder is not None:
            enc_per = self._attn_params() + 3 * d * self.d_ff
            total += self.encoder.n_layers * enc_per
            # decoder cross-attention adds one more attn block per layer
            total += self.n_layers * self._attn_params()
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        d = self.d_model
        total_moe = 3 * d * mo.d_expert * (mo.n_experts + mo.n_shared)
        active_moe = 3 * d * mo.d_expert * (mo.top_k + mo.n_shared)
        n_moe_layers = self.n_layers - mo.first_k_dense
        return self.param_count() - n_moe_layers * (total_moe - active_moe)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Shape cells (assigned input shapes)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic cache growth). See DESIGN.md §4.
LONG_CONTEXT_ARCHS = ("mamba2-370m", "zamba2-7b", "gemma3-1b", "gemma2-9b")
