"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H, MLA kv_lora=512,
64 routed experts top-6 + 2 shared, d_expert=1408, first layer dense,
vocab=102400. [arXiv:2405.04434]"""
from repro.configs.base import ATTN_GLOBAL, MLAConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=10944, vocab_size=102400,
        pattern=(ATTN_GLOBAL,),
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                      first_k_dense=1, dense_d_ff=10944, norm_topk=False),
        rope_theta=10_000.0,
        tie_embeddings=False, max_seq_len=32768,
    )
