"""Architecture registry: ``get_config(name)`` and ``reduced(cfg)`` for smoke
tests. One module per assigned architecture lives alongside this file."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.configs.base import ModelConfig

ARCH_IDS = (
    "gemma3-1b", "gemma2-9b", "phi3-mini-3.8b", "smollm-135m",
    "mamba2-370m", "deepseek-v2-lite-16b", "qwen3-moe-30b-a3b",
    "zamba2-7b", "whisper-tiny", "llava-next-34b",
)

_MODULES = {
    "gemma3-1b": "gemma3_1b",
    "gemma2-9b": "gemma2_9b",
    "phi3-mini-3.8b": "phi3_mini",
    "smollm-135m": "smollm_135m",
    "mamba2-370m": "mamba2_370m",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "qwen3-moe-30b-a3b": "qwen3_moe",
    "zamba2-7b": "zamba2_7b",
    "whisper-tiny": "whisper_tiny",
    "llava-next-34b": "llava_next_34b",
}

_cache: Dict[str, ModelConfig] = {}


def get_config(name: str) -> ModelConfig:
    if name not in _cache:
        if name not in _MODULES:
            raise KeyError(f"unknown arch {name!r}; know {sorted(_MODULES)}")
        mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
        _cache[name] = mod.config()
    return _cache[name]


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_IDS}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests: keeps the layer pattern,
    mixer kinds and MoE/MLA/SSM structure; shrinks every dimension."""
    p = len(cfg.pattern)
    n_layers = 2 * p + 1 if p > 1 else 3
    n_kv = 1 if cfg.n_kv_heads == 1 else 2
    kw = dict(
        n_layers=n_layers, d_model=128, n_heads=4, n_kv_heads=n_kv,
        head_dim=32, d_ff=256, vocab_size=512, window=min(cfg.window, 32),
        max_seq_len=128, n_patches=min(cfg.n_patches, 16) if cfg.n_patches else 0,
    )
    if cfg.mla is not None:
        kw["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=64, qk_nope_head_dim=32,
            qk_rope_head_dim=16, v_head_dim=32)
    if cfg.moe is not None:
        # capacity_factor 8 -> no token drops at smoke scale, so decode and
        # full-forward outputs are exactly consistent in tests
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=2, d_expert=64, capacity_factor=8.0,
            dense_d_ff=256 if cfg.moe.first_k_dense else 0)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk=16)
    if cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(cfg.encoder, n_layers=2)
    return cfg.replace(**kw)
