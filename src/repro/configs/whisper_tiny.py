"""whisper-tiny [audio]: 4L enc + 4L dec, d_model=384 6H d_ff=1536
vocab=51865; conv frontend is a stub (precomputed frame embeddings).
[arXiv:2212.04356]"""
from repro.configs.base import ATTN_GLOBAL, EncoderConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="audio",
        n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
        d_ff=1536, vocab_size=51865,
        pattern=(ATTN_GLOBAL,),
        encoder=EncoderConfig(n_layers=4, max_frames=1500),
        use_rope=False,
        tie_embeddings=True, max_seq_len=448,
    )
