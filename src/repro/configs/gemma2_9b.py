"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.
Local+global alternating, logit softcaps. [arXiv:2408.00118]"""
from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b", family="dense",
        n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
        d_ff=14336, vocab_size=256000,
        pattern=(ATTN_LOCAL, ATTN_GLOBAL),
        window=4096,
        attn_softcap=50.0, final_softcap=30.0,
        query_scale=(3584 // 16) ** -0.5,   # query_pre_attn_scalar = d/heads
        post_norm=True, embed_scale=True,
        act="gelu", tie_embeddings=True, max_seq_len=8192,
    )
