"""Gradient compression for the data-parallel all-reduce: int8 quantization
with error feedback (distributed-optimization trick for 1000+ node scale,
where gradient bytes dominate the DP axis).

Used inside a ``shard_map`` over the DP axes: each shard quantizes its local
gradient to int8 (per-tensor scale), psums the int8 payload (16-32x fewer
bytes on the wire than fp32), dequantizes, and keeps the quantization
residual locally, adding it back the next step (error feedback preserves
convergence; Seide et al. 2014, Karimireddy et al. 2019).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, residuals, axis_name):
    """int8 all-reduce with error feedback. Per-shard call (inside shard_map).

    The wire payload is the int8 tensor + one fp32 scale per tensor,
    exchanged with ``all_gather`` (int8 on every hop — an int8 *psum* would
    overflow and XLA would upcast it silently); each shard dequantizes and
    averages locally. Ring cost: size×(N-1)/N bytes vs 8× that for fp32
    all-reduce. Returns (mean_grads, new_residuals).
    """

    def one(g, r):
        g32 = g.astype(jnp.float32) + r                 # add error feedback
        q, scale = quantize_int8(g32)
        new_r = g32 - dequantize_int8(q, scale)          # local residual
        qs = jax.lax.all_gather(q, axis_name)            # (N, ...) int8 wire
        scales = jax.lax.all_gather(scale, axis_name)    # (N,) fp32
        deq = qs.astype(jnp.float32) * scales.reshape(
            (-1,) + (1,) * q.ndim)
        return jnp.mean(deq, axis=0).astype(g.dtype), new_r

    flat_g, td = jax.tree.flatten(grads)
    flat_r = td.flatten_up_to(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return td.unflatten([o[0] for o in outs]), \
        td.unflatten([o[1] for o in outs])


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def wire_bytes_fp32(grads) -> int:
    return sum(x.size * 4 for x in jax.tree.leaves(grads))


def wire_bytes_int8(grads) -> int:
    return sum(x.size * 1 + 4 for x in jax.tree.leaves(grads))
