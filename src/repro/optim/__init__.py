from repro.optim.adamw import (AdamWConfig, adamw_update, clip_by_global_norm,
                               global_norm, init_opt_state, schedule)
from repro.optim.compress import (compressed_psum, dequantize_int8,
                                  init_residuals, quantize_int8,
                                  wire_bytes_fp32, wire_bytes_int8)
