"""AdamW + schedules + global-norm clipping (self-contained, no optax)."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = opt_state["count"] + 1
    lr = schedule(cfg, count)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, mu, nu, p):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * jnp.square(g32)
        mu_hat = mu / (1 - b1 ** count.astype(jnp.float32))
        nu_hat = nu / (1 - b2 ** count.astype(jnp.float32))
        step = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (step + cfg.weight_decay * p32)
        return p32.astype(p.dtype), mu, nu

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, n, p)
           for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
