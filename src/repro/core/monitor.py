"""Monitoring: heartbeats, failure detection, straggler mitigation.

The paper's RC3E monitors device status via the gcs registers; at pod scale
this grows into (a) node heartbeats with a miss deadline -> DEAD -> slice
re-placement, and (b) per-slice step-time tracking: a slice whose recent
step times exceed ``straggler_factor`` × fleet median for ``patience``
consecutive steps is flagged for migration.

A injectable ``clock`` makes every policy deterministic in tests.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.device_db import DeviceDB, VSlice


@dataclass
class MonitorConfig:
    heartbeat_interval_s: float = 5.0
    heartbeat_deadline_s: float = 15.0
    straggler_factor: float = 1.5
    straggler_patience: int = 3
    step_window: int = 16
    # fleet-wide traffic trend window: arrival / completion counts pushed
    # by the serving fleet each round feed the SLO-projection autoscaler
    # (scale out on *projected* p95 breach, not just backlog). The window
    # bounds BOTH the sample count and the event-time span in seconds —
    # under the event-driven loop samples arrive on the queue's clock, so
    # a burst of closely spaced rounds must not stretch the trend's
    # horizon, and a long quiet gap must age old samples out
    traffic_window: int = 32


class Monitor:
    def __init__(self, db: DeviceDB, cfg: Optional[MonitorConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.db = db
        self.cfg = cfg if cfg is not None else MonitorConfig()
        self.clock = clock
        self._step_times: Dict[str, List[float]] = {}
        self._straggler_strikes: Dict[str, int] = {}
        self._pages: Dict[str, Tuple[int, int]] = {}   # dev -> (used, total)
        self._scrub: Dict[str, Tuple[int, float]] = {}  # dev -> (pages, ms)
        # (t, arrivals, completions, active_devices) per fleet round, t on
        # the injected clock (event time under the event-driven loop)
        self._traffic: List[Tuple[float, int, int, int]] = []
        # per-device completion samples (t, n) — cleared when the device
        # dies or parks, pruned to the same window otherwise
        self._dev_traffic: Dict[str, List[Tuple[float, int]]] = {}
        self.events: List[dict] = []

    # ---------------- heartbeats ----------------
    def heartbeat(self, node_id: str):
        self.db.nodes[node_id].last_heartbeat = self.clock()

    def check_heartbeats(self) -> List[VSlice]:
        """Mark nodes past deadline DEAD; return orphaned slices. A dead
        node's telemetry dies with it: its slices' step windows (they must
        not keep feeding the fleet median / straggler policy) and its
        devices' page-occupancy entries (a dead pool is not "pressured" —
        it would otherwise trip page-pressure scale-out forever)."""
        now = self.clock()
        orphans: List[VSlice] = []
        for node in list(self.db.nodes.values()):
            if not node.alive:
                continue
            if now - node.last_heartbeat > self.cfg.heartbeat_deadline_s:
                dead = self.db.mark_node_dead(node.node_id)
                for s in dead:
                    self.clear_slice(s.slice_id)
                for did in node.devices:
                    self.clear_pages(did)
                    self.clear_traffic(did)
                orphans.extend(dead)
                self.events.append({"t": now, "kind": "node_dead",
                                    "node": node.node_id,
                                    "orphans": [s.slice_id for s in dead]})
        return orphans

    # ---------------- stragglers ----------------
    def record_step(self, slice_id: str, step_ms: float):
        w = self._step_times.setdefault(slice_id, [])
        w.append(step_ms)
        if len(w) > self.cfg.step_window:
            del w[0]

    def median_step_ms(self) -> Optional[float]:
        all_recent = [t for w in self._step_times.values() for t in w]
        return statistics.median(all_recent) if all_recent else None

    def find_stragglers(self) -> List[str]:
        """Slices whose recent steps are consistently slow vs fleet median."""
        med = self.median_step_ms()
        if med is None:
            return []
        flagged = []
        for sid, w in self._step_times.items():
            recent = w[-self.cfg.straggler_patience:]
            if (len(recent) >= self.cfg.straggler_patience
                    and all(t > self.cfg.straggler_factor * med
                            for t in recent)):
                strikes = self._straggler_strikes.get(sid, 0) + 1
                self._straggler_strikes[sid] = strikes
                flagged.append(sid)
                self.events.append({"t": self.clock(), "kind": "straggler",
                                    "slice": sid, "median_ms": med,
                                    "recent_ms": recent})
            else:
                self._straggler_strikes.pop(sid, None)
        return flagged

    def clear_slice(self, slice_id: str):
        self._step_times.pop(slice_id, None)
        self._straggler_strikes.pop(slice_id, None)

    # ---------------- traffic trend (SLO projection input) ----------------
    def record_traffic(self, arrivals: int, completions: int,
                       active_devices: int,
                       by_device: Optional[Dict[str, int]] = None):
        """One fleet round's open-loop traffic sample: how many requests
        ARRIVED (were submitted), how many COMPLETED, and how many devices
        were serving. Samples are stamped with the injected clock (EVENT
        time under the event-driven loop — rounds are no longer equally
        spaced, so rates must divide by elapsed time, not sample count).
        ``by_device`` attributes completions to the device that served
        them; a dead device's samples are dropped by ``clear_traffic`` in
        the failure sweeps, so churn can never grow these windows."""
        t = float(self.clock())
        self._traffic.append((t, int(arrivals), int(completions),
                              int(active_devices)))
        self._prune_traffic(self._traffic, t)
        for dev, n in (by_device or {}).items():
            w = self._dev_traffic.setdefault(dev, [])
            w.append((t, int(n)))
            self._prune_traffic(w, t)

    def _prune_traffic(self, window: list, now: float) -> None:
        """Window discipline: cap the sample count AND age out samples
        older than ``traffic_window`` seconds of (event) time."""
        cap = self.cfg.traffic_window
        if len(window) > cap:
            del window[:len(window) - cap]
        cut = now - cap
        drop = 0
        while drop < len(window) - 1 and window[drop][0] < cut:
            drop += 1
        if drop:
            del window[:drop]

    def _traffic_span(self) -> float:
        """Elapsed time the window covers. Rounds recorded within one
        clock reading (lockstep tests with a wall clock) degenerate to
        per-sample rates: span == sample count, preserving the old
        rate-per-round semantics."""
        dt = self._traffic[-1][0] - self._traffic[0][0]
        return dt if dt > 0 else float(len(self._traffic))

    def arrival_rate(self) -> Optional[float]:
        """Arrivals per unit event-time over the traffic window (None
        until the first sample lands)."""
        if not self._traffic:
            return None
        return sum(a for _, a, _, _ in self._traffic) / self._traffic_span()

    def service_rate_per_device(self) -> Optional[float]:
        """Completions per device per unit event-time over the window —
        the μ the projection multiplies by the active-device count. The
        denominator is device-time: mean serving devices × window span.
        None until at least one sample saw a serving device."""
        if not self._traffic:
            return None
        mean_active = sum(n for _, _, _, n in self._traffic) \
            / len(self._traffic)
        dev_time = mean_active * self._traffic_span()
        if dev_time <= 0:
            return None
        return sum(c for _, _, c, _ in self._traffic) / dev_time

    def clear_traffic(self, device_id: str):
        """Drop a device's completion samples — called from the dead-device
        sweeps alongside step telemetry and page occupancy, so a device
        dying mid-window cannot leave its deque growing (or its stale
        completions flattering the fleet's service rate) forever."""
        self._dev_traffic.pop(device_id, None)

    def device_completion_rate(self, device_id: str) -> Optional[float]:
        """One device's completions per unit event-time (None: no samples)."""
        w = self._dev_traffic.get(device_id)
        if not w:
            return None
        dt = w[-1][0] - w[0][0]
        span = dt if dt > 0 else float(len(w))
        return sum(n for _, n in w) / span

    def traffic_stats(self) -> dict:
        return {"window": len(self._traffic),
                "span": self._traffic_span() if self._traffic else 0.0,
                "arrival_rate": self.arrival_rate(),
                "service_rate_per_device": self.service_rate_per_device()}

    # ---------------- KV page occupancy ----------------
    def record_pages(self, device_id: str, used: int, total: int):
        """Live KV page-pool occupancy for one device's dataplane (pushed
        by the serving gateway/fleet each step). ``find_page_pressure``
        and ``status()`` read it; clearing happens when an engine parks."""
        self._pages[device_id] = (int(used), int(total))

    def record_scrub(self, device_id: str, pages: int, ms: float):
        """Cumulative zero-on-free cost for one device's pool (pushed
        alongside ``record_pages``): how many freed pages were scrubbed
        and how many milliseconds the batched scrub dispatches cost. The
        operator's view of what the isolation policy is buying/costing."""
        self._scrub[device_id] = (int(pages), float(ms))

    def clear_pages(self, device_id: str):
        self._pages.pop(device_id, None)
        self._scrub.pop(device_id, None)

    def page_occupancy(self) -> Dict[str, float]:
        return {dev: used / max(1, total)
                for dev, (used, total) in self._pages.items()}

    def find_page_pressure(self, threshold: float = 0.85) -> List[str]:
        """Devices whose page pools run hot — the memory-side scale-out
        signal (ordered hottest first)."""
        occ = self.page_occupancy()
        hot = [dev for dev, o in occ.items() if o >= threshold]
        return sorted(hot, key=lambda dev: -occ[dev])

    # ---------------- status (gcs analogue) ----------------
    def status(self) -> dict:
        """FULL fleet view — operator/fleet paths only. Gateway-facing
        (tenant-callable) paths must use ``tenant_status``: this view
        names every tenant's slices, page grants and occupancy, which is
        exactly the cross-tenant observability the isolation threat model
        forbids handing to a co-tenant."""
        return {
            "devices": {d.device_id: {
                "state": d.state.value,
                "slots_used": d.used_slots(),
                "slices": {s.slice_id: s.state.value
                           for s in d.slices.values()},
            } for d in self.db.devices.values()},
            "utilization": self.db.utilization(),
            "pages": {dev: {"used": used, "total": total,
                            "occupancy": round(used / max(1, total), 4)}
                      for dev, (used, total) in self._pages.items()},
            "scrub": {dev: {"pages": pages, "ms": round(ms, 3)}
                      for dev, (pages, ms) in self._scrub.items()},
            "page_grants": self.db.page_grants(),
            "median_step_ms": self.median_step_ms(),
            "traffic": self.traffic_stats(),
        }

    def tenant_status(self, tenant: str) -> dict:
        """Tenant-scoped slice of ``status()``: ONLY what ``tenant`` owns
        — its slices (state + page grant) and the state of the devices
        hosting them. No co-tenant names, no shared-pool occupancy, no
        fleet medians or traffic rates: each of those is a channel a
        hostile tenant could poll to infer a co-resident's load."""
        slices = {}
        devices = {}
        for d in self.db.devices.values():
            own = {s.slice_id: {"state": s.state.value,
                                "cache_pages": s.cache_pages}
                   for s in d.slices.values() if s.owner == tenant}
            if own:
                slices.update(own)
                devices[d.device_id] = {"state": d.state.value}
        return {"tenant": tenant, "slices": slices, "devices": devices}
