"""Monitoring: heartbeats, failure detection, straggler mitigation.

The paper's RC3E monitors device status via the gcs registers; at pod scale
this grows into (a) node heartbeats with a miss deadline -> DEAD -> slice
re-placement, and (b) per-slice step-time tracking: a slice whose recent
step times exceed ``straggler_factor`` × fleet median for ``patience``
consecutive steps is flagged for migration.

A injectable ``clock`` makes every policy deterministic in tests.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.device_db import DeviceDB, VSlice


@dataclass
class MonitorConfig:
    heartbeat_interval_s: float = 5.0
    heartbeat_deadline_s: float = 15.0
    straggler_factor: float = 1.5
    straggler_patience: int = 3
    step_window: int = 16
    # fleet-wide traffic trend window (steps): arrival / completion counts
    # pushed by the serving fleet each step feed the SLO-projection
    # autoscaler (scale out on *projected* p95 breach, not just backlog)
    traffic_window: int = 32


class Monitor:
    def __init__(self, db: DeviceDB, cfg: Optional[MonitorConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.db = db
        self.cfg = cfg if cfg is not None else MonitorConfig()
        self.clock = clock
        self._step_times: Dict[str, List[float]] = {}
        self._straggler_strikes: Dict[str, int] = {}
        self._pages: Dict[str, Tuple[int, int]] = {}   # dev -> (used, total)
        # (arrivals, completions, active_devices) per fleet step
        self._traffic: List[Tuple[int, int, int]] = []
        self.events: List[dict] = []

    # ---------------- heartbeats ----------------
    def heartbeat(self, node_id: str):
        self.db.nodes[node_id].last_heartbeat = self.clock()

    def check_heartbeats(self) -> List[VSlice]:
        """Mark nodes past deadline DEAD; return orphaned slices. A dead
        node's telemetry dies with it: its slices' step windows (they must
        not keep feeding the fleet median / straggler policy) and its
        devices' page-occupancy entries (a dead pool is not "pressured" —
        it would otherwise trip page-pressure scale-out forever)."""
        now = self.clock()
        orphans: List[VSlice] = []
        for node in list(self.db.nodes.values()):
            if not node.alive:
                continue
            if now - node.last_heartbeat > self.cfg.heartbeat_deadline_s:
                dead = self.db.mark_node_dead(node.node_id)
                for s in dead:
                    self.clear_slice(s.slice_id)
                for did in node.devices:
                    self.clear_pages(did)
                orphans.extend(dead)
                self.events.append({"t": now, "kind": "node_dead",
                                    "node": node.node_id,
                                    "orphans": [s.slice_id for s in dead]})
        return orphans

    # ---------------- stragglers ----------------
    def record_step(self, slice_id: str, step_ms: float):
        w = self._step_times.setdefault(slice_id, [])
        w.append(step_ms)
        if len(w) > self.cfg.step_window:
            del w[0]

    def median_step_ms(self) -> Optional[float]:
        all_recent = [t for w in self._step_times.values() for t in w]
        return statistics.median(all_recent) if all_recent else None

    def find_stragglers(self) -> List[str]:
        """Slices whose recent steps are consistently slow vs fleet median."""
        med = self.median_step_ms()
        if med is None:
            return []
        flagged = []
        for sid, w in self._step_times.items():
            recent = w[-self.cfg.straggler_patience:]
            if (len(recent) >= self.cfg.straggler_patience
                    and all(t > self.cfg.straggler_factor * med
                            for t in recent)):
                strikes = self._straggler_strikes.get(sid, 0) + 1
                self._straggler_strikes[sid] = strikes
                flagged.append(sid)
                self.events.append({"t": self.clock(), "kind": "straggler",
                                    "slice": sid, "median_ms": med,
                                    "recent_ms": recent})
            else:
                self._straggler_strikes.pop(sid, None)
        return flagged

    def clear_slice(self, slice_id: str):
        self._step_times.pop(slice_id, None)
        self._straggler_strikes.pop(slice_id, None)

    # ---------------- traffic trend (SLO projection input) ----------------
    def record_traffic(self, arrivals: int, completions: int,
                       active_devices: int):
        """One fleet step's open-loop traffic sample: how many requests
        ARRIVED (were submitted), how many COMPLETED, and how many devices
        were serving. The windowed rates below are the arrival-rate /
        service-rate trend the SLO autoscaler projects from."""
        self._traffic.append((int(arrivals), int(completions),
                              int(active_devices)))
        if len(self._traffic) > self.cfg.traffic_window:
            del self._traffic[0]

    def arrival_rate(self) -> Optional[float]:
        """Mean arrivals per step over the traffic window (None until the
        first sample lands)."""
        if not self._traffic:
            return None
        return sum(a for a, _, _ in self._traffic) / len(self._traffic)

    def service_rate_per_device(self) -> Optional[float]:
        """Mean request completions per device-step over the window — the
        μ the projection multiplies by the active-device count. None until
        at least one sample saw a serving device."""
        dev_steps = sum(n for _, _, n in self._traffic)
        if dev_steps <= 0:
            return None
        return sum(c for _, c, _ in self._traffic) / dev_steps

    def traffic_stats(self) -> dict:
        return {"window": len(self._traffic),
                "arrival_rate": self.arrival_rate(),
                "service_rate_per_device": self.service_rate_per_device()}

    # ---------------- KV page occupancy ----------------
    def record_pages(self, device_id: str, used: int, total: int):
        """Live KV page-pool occupancy for one device's dataplane (pushed
        by the serving gateway/fleet each step). ``find_page_pressure``
        and ``status()`` read it; clearing happens when an engine parks."""
        self._pages[device_id] = (int(used), int(total))

    def clear_pages(self, device_id: str):
        self._pages.pop(device_id, None)

    def page_occupancy(self) -> Dict[str, float]:
        return {dev: used / max(1, total)
                for dev, (used, total) in self._pages.items()}

    def find_page_pressure(self, threshold: float = 0.85) -> List[str]:
        """Devices whose page pools run hot — the memory-side scale-out
        signal (ordered hottest first)."""
        occ = self.page_occupancy()
        hot = [dev for dev, o in occ.items() if o >= threshold]
        return sorted(hot, key=lambda dev: -occ[dev])

    # ---------------- status (gcs analogue) ----------------
    def status(self) -> dict:
        return {
            "devices": {d.device_id: {
                "state": d.state.value,
                "slots_used": d.used_slots(),
                "slices": {s.slice_id: s.state.value
                           for s in d.slices.values()},
            } for d in self.db.devices.values()},
            "utilization": self.db.utilization(),
            "pages": {dev: {"used": used, "total": total,
                            "occupancy": round(used / max(1, total), 4)}
                      for dev, (used, total) in self._pages.items()},
            "page_grants": self.db.page_grants(),
            "median_step_ms": self.median_step_ms(),
            "traffic": self.traffic_stats(),
        }
