"""RC3E core: the paper's primary contribution (hypervisor + vFPGA
virtualization + service models) as a JAX-cluster control plane."""
from repro.core.device_db import (MAX_SLOTS, DeviceDB, DeviceState,
                                  NoCapacityError, PhysicalDevice, SliceState,
                                  VSlice)
from repro.core.elastic import ElasticController
from repro.core.hypervisor import ClusterSpec, Hypervisor
from repro.core.monitor import Monitor, MonitorConfig
from repro.core.reconfig import (ProgramCache, ProgramEntry, Reconfigurator,
                                 fingerprint)
from repro.core.scheduler import BatchScheduler, Job, JobState
from repro.core.service_models import (BAaaSSession, RAaaSSession,
                                       RSaaSSession)
