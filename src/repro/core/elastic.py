"""Elastic scaling: grow/shrink a tenant's slice set and re-place work.

The paper's outlook ("migration of user designs between vFPGAs and physical
FPGAs is also intended") is implemented here as a first-class operation:
``resize`` reallocates a tenant to a new slot count, carrying the program
fingerprint so the PR cache makes re-programming cheap, and the training
runtime pairs this with ``repro.ckpt.reshard`` to move optimizer/model state
onto the new data-parallel extent.
"""
from __future__ import annotations

from typing import Callable, List, Optional

from repro.core.device_db import (DeviceState, NoCapacityError, SliceState,
                                  VSlice)
from repro.core.hypervisor import Hypervisor


class ElasticController:
    def __init__(self, hv: Hypervisor):
        self.hv = hv

    def resize(self, owner: str, new_slots: int,
               service_model: str = "raas") -> List[VSlice]:
        """Replace the tenant's slices with one allocation of ``new_slots``.

        Allocate-before-release so a failed grow leaves the tenant intact.
        """
        old = self.hv.db.slices_of(owner)
        program = old[0].program if old else None
        new = self.hv.db.allocate_slice(owner, new_slots, service_model)
        for s in old:
            self.hv.release(s.slice_id)
        if program:
            new.program = program
            new.state = SliceState.CONFIGURED
        self.hv._log("elastic_resize", owner=owner, slots=new_slots,
                     slice=new.slice_id)
        return [new]

    # ------------------------------------------------------------------
    # Fleet-level scaling (DeviceDB energy policy, inverted on demand)
    # ------------------------------------------------------------------
    def pick_scale_out_device(self) -> Optional[str]:
        """A PARKED, alive, empty physical device to wake when serving
        demand outgrows the active fleet — the deliberate inversion of the
        pack-first energy policy. Returns its id, or None when every
        device is already active (or dead)."""
        cands = self.hv.db.idle_devices()
        return cands[0].device_id if cands else None

    def scale_out(self, slice_id: str) -> Optional[VSlice]:
        """Wake a PARKED device and move the given (hot / deepest-queued)
        slice onto it via a directed migration. The hypervisor's migration
        listeners carry the dataplane along (the serving fleet spins up an
        engine there and hands the tenant's traffic off live). Returns the
        new slice, or None when no parked capacity exists."""
        dev = self.pick_scale_out_device()
        if dev is None:
            return None
        new = self.hv.migrate_slice(slice_id, target_device=dev,
                                    reason="scale_out")
        if new is not None:
            self.hv._log("elastic_scale_out", slice=new.slice_id, device=dev)
        return new

    # ------------------------------------------------------------------
    # SLO-projection scaling (open-loop traffic: act on the trend, not
    # the backlog — by the time queue depth trips, the p95 is already
    # blown through a burst wave)
    # ------------------------------------------------------------------
    def _active_serving_devices(self) -> int:
        return len([d for d in self.hv.db.alive_devices()
                    if d.state in (DeviceState.ACTIVE,
                                   DeviceState.EXCLUSIVE)])

    def projected_p95_steps(self, backlog: int,
                            horizon: int = 16) -> Optional[float]:
        """Projected p95 request sojourn (in fleet steps) one ``horizon``
        from now, from the monitor's arrival-rate/service-rate trend.

        Fluid queueing estimate: a request arriving at the end of the
        horizon waits behind today's backlog plus the horizon's expected
        arrivals, all draining through the active fleet's measured service
        capacity — ``(backlog + λ·horizon) / (μ_dev · n_active)``. When
        λ exceeds capacity the estimate grows linearly in the horizon,
        which is exactly the divergence the autoscaler must act on.
        Returns None until the monitor has a usable trend (no samples yet,
        or nothing served so far)."""
        lam = self.hv.monitor.arrival_rate()
        mu_dev = self.hv.monitor.service_rate_per_device()
        if lam is None or mu_dev is None or mu_dev <= 0.0:
            return None
        mu_total = mu_dev * max(1, self._active_serving_devices())
        return (backlog + lam * horizon) / mu_total

    def scale_out_on_slo(self, slice_id: str, slo_p95_steps: float,
                         backlog: int, horizon: int = 16
                         ) -> Optional[VSlice]:
        """Wake a PARKED device when the *projected* p95 breaches the SLO
        — queue depth and page pressure are lagging signals; the trend
        fires while the burst is still arriving. ``slice_id`` is the slice
        worth moving (the fleet passes its deepest-queued tenant's).
        Returns the new slice, or None when the projection is under SLO
        (or unavailable) or no parked capacity exists."""
        projected = self.projected_p95_steps(backlog, horizon)
        if projected is None or projected <= slo_p95_steps:
            return None
        new = self.scale_out(slice_id)
        if new is not None:
            self.hv._log("elastic_slo_scale_out", slice=slice_id,
                         new_slice=new.slice_id, projected_p95=projected,
                         slo_p95=slo_p95_steps, backlog=backlog)
        return new

    def scale_out_on_page_pressure(self, hottest_slice_of: dict,
                                   threshold: float = 0.85
                                   ) -> Optional[VSlice]:
        """Memory-side elastic scaling: when a device's KV page pool runs
        hot (occupancy pushed into the monitor by the serving dataplane),
        move its hottest tenant's slice onto a woken PARKED device — queue
        depth says nothing about long-context tenants whose *pages* are
        the bottleneck. ``hottest_slice_of`` maps device_id -> slice_id of
        the tenant best worth moving (the fleet computes it from per-slot
        page counts). Returns the new slice, or None when no device is
        pressured or no parked capacity exists."""
        for dev in self.hv.monitor.find_page_pressure(threshold):
            sid = hottest_slice_of.get(dev)
            if sid is None:
                continue
            new = self.scale_out(sid)
            if new is not None:
                self.hv._log("elastic_page_pressure", device=dev,
                             slice=sid, new_slice=new.slice_id)
                return new
        return None

    def consolidate(self, device_id: str) -> bool:
        """Drain a device for parking (scale-in): migrate every slice it
        hosts onto the remaining fleet (pack-first). Returns True when the
        device emptied — ``DeviceDB.release`` then parks it, completing the
        energy policy's "minimize active devices" half.

        The placement is dry-run first (largest slice first against each
        other device's free slots), so an infeasible drain returns False
        WITHOUT migrating anything — no tenant pays a live hand-off for a
        device that cannot actually empty.
        """
        if not self.drain_feasible(device_id):
            return False
        dev = self.hv.db.device(device_id)
        slices = sorted(dev.slices.values(), key=lambda s: -s.slots)
        for s in slices:
            if self.hv.migrate_slice(s.slice_id, reason="scale_in") is None:
                return False    # capacity changed under us mid-drain
        self.hv._log("elastic_scale_in", device=device_id)
        return True

    def drain_feasible(self, device_id: str) -> bool:
        """Dry-run the ``consolidate`` placement: can every slice this
        device hosts fit onto the rest of the alive fleet (largest first,
        mirroring the allocator's pack-first order, honoring page grants
        on metered clusters)? No state is touched."""
        dev = self.hv.db.device(device_id)
        slices = sorted(dev.slices.values(), key=lambda s: -s.slots)
        others = [d for d in self.hv.db.alive_devices()
                  if d.device_id != device_id
                  and d.state != DeviceState.EXCLUSIVE]
        free = {d.device_id: d.free_slots() for d in others}
        free_pages = {d.device_id:
                      (d.cache_pages - d.granted_cache_pages()
                       if d.cache_pages else None) for d in others}
        for s in slices:
            # mirror the allocator's pack-first order (fewest free first)
            fits = sorted((k for k, v in free.items()
                           if v >= s.slots
                           and (not s.cache_pages or free_pages[k] is None
                                or free_pages[k] >= s.cache_pages)),
                          key=lambda k: (free[k], k))
            if not fits:
                return False
            free[fits[0]] -= s.slots
            if s.cache_pages and free_pages[fits[0]] is not None:
                free_pages[fits[0]] -= s.cache_pages
        return True

    def pick_scale_in_device(self, min_active: int = 1) -> Optional[str]:
        """The device to drain when the fleet is over-provisioned: among
        ACTIVE slice-hosting devices, the highest-draw one whose slices
        can actually be re-packed elsewhere (dry-run) — the power-hungry
        device classes park first, completing the energy policy under a
        diurnal down-ramp. Keeps at least ``min_active`` serving devices.
        Returns the device id, or None when nothing can (or should)
        drain."""
        active = [d for d in self.hv.db.alive_devices()
                  if d.state == DeviceState.ACTIVE and d.slices]
        if len(active) <= min_active:
            return None
        for d in sorted(active, key=lambda d: (-d.draw, d.device_id)):
            if self.drain_feasible(d.device_id):
                return d.device_id
        return None

    def place_failover(self, owner: str, slots: int,
                       service_model: str = "baas",
                       cache_pages_of: Optional[Callable[[int], int]] = None
                       ) -> Optional[VSlice]:
        """Re-place a dead device's tenant on surviving capacity. Tries the
        tenant's full slot count first; when the survivors cannot fit it,
        degrades 4 -> 2 -> 1 (elastic degrade — a smaller slice now beats a
        lost session). PARKED devices count as survivors: the allocator
        waking one IS the scale-out half of failover.

        ``cache_pages_of`` maps a slot count to that placement's page
        grant (the fleet passes its per-session grant formula). It is
        re-evaluated at every degrade step: on a page-metered cluster a
        smaller slice must ask for its OWN smaller grant, or a placement
        that fits in slots would keep failing on pages — and a degraded
        slice would over-reserve the full-size grant forever.

        Returns the new slice (``slots`` may be smaller than requested),
        or None when not even a 1-slot slice fits anywhere."""
        s = slots
        while s >= 1:
            try:
                vs = self.hv.db.allocate_slice(
                    owner, s, service_model,
                    cache_pages=cache_pages_of(s) if cache_pages_of else 0)
            except NoCapacityError:
                s //= 2
                continue
            self.hv._log("failover_place", owner=owner, slice=vs.slice_id,
                         device=vs.device_id, slots=s, requested=slots,
                         degraded=s != slots)
            return vs
        return None

    def shrink_to_survivors(self, owner: str) -> Optional[VSlice]:
        """After a node failure: re-place the tenant on surviving capacity at
        the largest slot count that fits (elastic degrade). Returns the new
        slice, or None if the cluster is full."""
        vs = self.place_failover(owner, 4, "raas")
        if vs is not None:
            self.hv._log("elastic_degrade", owner=owner, slots=vs.slots,
                         slice=vs.slice_id)
        return vs
