"""Elastic scaling: grow/shrink a tenant's slice set and re-place work.

The paper's outlook ("migration of user designs between vFPGAs and physical
FPGAs is also intended") is implemented here as a first-class operation:
``resize`` reallocates a tenant to a new slot count, carrying the program
fingerprint so the PR cache makes re-programming cheap, and the training
runtime pairs this with ``repro.ckpt.reshard`` to move optimizer/model state
onto the new data-parallel extent.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.device_db import NoCapacityError, SliceState, VSlice
from repro.core.hypervisor import Hypervisor


class ElasticController:
    def __init__(self, hv: Hypervisor):
        self.hv = hv

    def resize(self, owner: str, new_slots: int,
               service_model: str = "raas") -> List[VSlice]:
        """Replace the tenant's slices with one allocation of ``new_slots``.

        Allocate-before-release so a failed grow leaves the tenant intact.
        """
        old = self.hv.db.slices_of(owner)
        program = old[0].program if old else None
        new = self.hv.db.allocate_slice(owner, new_slots, service_model)
        for s in old:
            self.hv.release(s.slice_id)
        if program:
            new.program = program
            new.state = SliceState.CONFIGURED
        self.hv._log("elastic_resize", owner=owner, slots=new_slots,
                     slice=new.slice_id)
        return [new]

    def shrink_to_survivors(self, owner: str) -> Optional[VSlice]:
        """After a node failure: re-place the tenant on surviving capacity at
        the largest slot count that fits (elastic degrade). Returns the new
        slice, or None if the cluster is full."""
        for slots in (4, 2, 1):
            try:
                vs = self.hv.db.allocate_slice(owner, slots, "raas")
                self.hv._log("elastic_degrade", owner=owner, slots=slots,
                             slice=vs.slice_id)
                return vs
            except NoCapacityError:
                continue
        return None
