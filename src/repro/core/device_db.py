"""RC3E device database (paper §IV-B).

Tracks nodes, physical accelerator meshes and vSlices with allocation state,
exactly as the paper's hypervisor database tracks nodes / FPGAs / vFPGAs.
Pure control plane: no jax imports, fully unit-testable, persistable to JSON.

Energy policy (paper: "minimize the number of active vFPGAs and maximize the
utilization of physical FPGAs"): physical devices with no allocated slices are
PARKED (clock-gated in the paper); the allocator packs new slices onto already
ACTIVE devices first.
"""
from __future__ import annotations

import dataclasses
import enum
import json
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.analysis.lifecycle import sanitizer

MAX_SLOTS = 4  # paper: up to four vFPGAs per physical device


class DeviceState(str, enum.Enum):
    PARKED = "parked"          # no tenants; clocks gated (paper energy policy)
    ACTIVE = "active"          # >=1 vSlice allocated
    EXCLUSIVE = "exclusive"    # RSaaS: whole device allocated to one user
    DRAINING = "draining"      # being vacated (maintenance / elastic shrink)
    DEAD = "dead"              # failed node


class SliceState(str, enum.Enum):
    FREE = "free"
    ALLOCATED = "allocated"    # owned by a tenant, no program loaded
    CONFIGURED = "configured"  # program (executable) loaded
    RUNNING = "running"
    MIGRATING = "migrating"


@dataclass
class VSlice:
    slice_id: str
    device_id: str
    slots: int                         # 1, 2 or 4 of the device's 4 slots
    state: SliceState = SliceState.FREE
    owner: Optional[str] = None
    service_model: Optional[str] = None   # rsaas | raas | baas
    program: Optional[str] = None         # executable fingerprint
    step_times_ms: List[float] = field(default_factory=list)
    # device-memory dimension: KV-cache pool pages granted to this slice
    # (0 = unmetered/dense). Compute (slots) and memory (pages) are
    # virtualized separately, so a small-compute long-context tenant is
    # expressible — and accountable.
    cache_pages: int = 0


@dataclass
class PhysicalDevice:
    device_id: str
    node_id: str
    chips: int                         # e.g. 64 chips per vSlice-slot group
    state: DeviceState = DeviceState.PARKED
    slices: Dict[str, VSlice] = field(default_factory=dict)
    cache_pages: int = 0               # pool pages this device's HBM holds
    # relative power draw while un-parked (PARKED = clock-gated = free).
    # Heterogeneous fleets give device classes different draws; the energy
    # metric (device-steps x draw) and the scale-in policy ("park the
    # power-hungry devices first") both read it.
    draw: float = 1.0
    # relative dataplane speed: the event-driven loop steps this device's
    # engine every ``tick_s / speed`` event-seconds, so a slow device
    # class (speed < 1) decodes on its own cadence instead of gating the
    # whole fleet behind a lockstep barrier. The lockstep loop ignores it
    # (every engine steps once per round, the round costs the slowest
    # member's period).
    speed: float = 1.0

    def used_slots(self) -> int:
        return sum(s.slots for s in self.slices.values()
                   if s.state != SliceState.FREE)

    def free_slots(self) -> int:
        return MAX_SLOTS - self.used_slots()

    def granted_cache_pages(self) -> int:
        return sum(s.cache_pages for s in self.slices.values()
                   if s.state != SliceState.FREE)


@dataclass
class Node:
    node_id: str
    devices: List[str] = field(default_factory=list)
    alive: bool = True
    last_heartbeat: float = 0.0


class DeviceDB:
    """Thread-safe in-memory DB with JSON persistence."""

    def __init__(self):
        self._lock = threading.RLock()
        self.nodes: Dict[str, Node] = {}
        self.devices: Dict[str, PhysicalDevice] = {}
        self._slice_counter = 0
        self._san = sanitizer.scope()    # device-machine key namespace

    # ---------------- topology ----------------
    def add_node(self, node_id: str) -> Node:
        with self._lock:
            if node_id in self.nodes:
                raise ValueError(f"node {node_id} exists")
            n = Node(node_id)
            self.nodes[node_id] = n
            return n

    def add_device(self, device_id: str, node_id: str, chips: int = 256,
                   cache_pages: int = 0, draw: float = 1.0,
                   speed: float = 1.0):
        with self._lock:
            if device_id in self.devices:
                raise ValueError(f"device {device_id} exists")
            if node_id not in self.nodes:
                raise KeyError(f"no node {node_id}")
            d = PhysicalDevice(device_id, node_id, chips,
                               cache_pages=cache_pages, draw=draw,
                               speed=speed)
            self.devices[device_id] = d
            self.nodes[node_id].devices.append(device_id)
            return d

    # ---------------- queries ----------------
    def device(self, device_id: str) -> PhysicalDevice:
        return self.devices[device_id]

    def find_slice(self, slice_id: str) -> VSlice:
        for d in self.devices.values():
            if slice_id in d.slices:
                return d.slices[slice_id]
        raise KeyError(f"no slice {slice_id}")

    def slices_of(self, owner: str) -> List[VSlice]:
        return [s for d in self.devices.values() for s in d.slices.values()
                if s.owner == owner]

    def utilization(self) -> Dict[str, float]:
        """Fraction of slots in use per device (paper's monitoring view)."""
        return {d.device_id: d.used_slots() / MAX_SLOTS
                for d in self.devices.values()}

    def page_grants(self) -> Dict[str, float]:
        """Fraction of each metered device's page pool granted to slices
        (the memory-dimension twin of ``utilization``)."""
        return {d.device_id: d.granted_cache_pages() / d.cache_pages
                for d in self.devices.values() if d.cache_pages}

    def active_draw(self) -> float:
        """Aggregate power draw of every un-parked, alive device this
        instant. PARKED devices are clock-gated (paper's energy policy)
        and DEAD ones draw nothing; everything else — ACTIVE, EXCLUSIVE,
        DRAINING — burns its class draw. The scale harness integrates this
        over fleet steps into the energy metric (device-steps x draw)."""
        with self._lock:
            return sum(d.draw for d in self.devices.values()
                       if d.state not in (DeviceState.PARKED,
                                          DeviceState.DEAD)
                       and self.nodes[d.node_id].alive)

    # ---------------- allocation ----------------
    def _alive_devices(self):
        return [d for d in self.devices.values()
                if d.state not in (DeviceState.DEAD, DeviceState.DRAINING)
                and self.nodes[d.node_id].alive]

    def alive_devices(self) -> List[PhysicalDevice]:
        """Schedulable devices (not DEAD/DRAINING, node alive) — the public
        view for policy code (elastic controller, batch scheduler)."""
        with self._lock:
            return self._alive_devices()

    def idle_devices(self) -> List[PhysicalDevice]:
        """PARKED, empty, alive devices (by id): wake candidates for
        elastic scale-out and the RSaaS exclusive allocator."""
        with self._lock:
            return sorted((d for d in self._alive_devices()
                           if d.state == DeviceState.PARKED and not d.slices),
                          key=lambda d: d.device_id)

    def allocate_slice(self, owner: str, slots: int, service_model: str,
                       device_id: Optional[str] = None,
                       exclude_device: Optional[str] = None,
                       cache_pages: int = 0) -> VSlice:
        """Pack-first placement (energy policy): prefer ACTIVE devices with
        the least free slots that still fit, park-wake only if needed.
        ``exclude_device`` supports straggler migration (must move away).
        ``cache_pages`` grants the slice a share of the device's KV page
        pool; a device whose pool is fully granted no longer fits
        page-bearing slices even when it has free compute slots."""
        if slots not in (1, 2, 4):
            raise ValueError("slots must be 1, 2 or 4")
        with self._lock:
            cands = self._alive_devices()
            if device_id is not None:
                cands = [d for d in cands if d.device_id == device_id]
            if exclude_device is not None:
                cands = [d for d in cands if d.device_id != exclude_device]
            cands = [d for d in cands
                     if d.state != DeviceState.EXCLUSIVE
                     and d.free_slots() >= slots
                     and (not cache_pages or not d.cache_pages
                          or d.granted_cache_pages() + cache_pages
                          <= d.cache_pages)]
            if not cands:
                raise NoCapacityError(
                    f"no device with {slots} free slots"
                    + (f" and {cache_pages} free cache pages"
                       if cache_pages else ""))
            # pack-first: fewest free slots among ACTIVE, then PARKED
            cands.sort(key=lambda d: (d.state != DeviceState.ACTIVE,
                                      d.free_slots(), d.device_id))
            dev = cands[0]
            self._slice_counter += 1
            vs = VSlice(f"vs-{self._slice_counter:05d}", dev.device_id, slots,
                        SliceState.ALLOCATED, owner, service_model,
                        cache_pages=cache_pages)
            dev.slices[vs.slice_id] = vs
            sanitizer.emit("device", (self._san, dev.device_id), "activate")
            dev.state = DeviceState.ACTIVE
            return vs

    def allocate_exclusive(self, owner: str,
                           device_id: Optional[str] = None) -> PhysicalDevice:
        """RSaaS: whole physical device (marked separately, paper §IV-B)."""
        with self._lock:
            cands = self.idle_devices()
            if device_id is not None:
                cands = [d for d in cands if d.device_id == device_id]
            if not cands:
                raise NoCapacityError("no idle physical device")
            dev = cands[0]
            sanitizer.emit("device", (self._san, dev.device_id), "exclusive")
            dev.state = DeviceState.EXCLUSIVE
            self._slice_counter += 1
            vs = VSlice(f"vs-{self._slice_counter:05d}", dev.device_id,
                        MAX_SLOTS, SliceState.ALLOCATED, owner, "rsaas")
            dev.slices[vs.slice_id] = vs
            return dev

    def release(self, slice_id: str):
        with self._lock:
            vs = self.find_slice(slice_id)
            dev = self.devices[vs.device_id]
            del dev.slices[slice_id]
            if not dev.slices:
                sanitizer.emit("device", (self._san, dev.device_id), "park")
                dev.state = DeviceState.PARKED   # energy policy: gate clocks

    def set_slice_state(self, slice_id: str, state: SliceState,
                        program: Optional[str] = None):
        with self._lock:
            vs = self.find_slice(slice_id)
            vs.state = state
            if program is not None:
                vs.program = program

    # ---------------- failure handling ----------------
    def mark_node_dead(self, node_id: str) -> List[VSlice]:
        """Returns the orphaned slices that need re-placement."""
        with self._lock:
            node = self.nodes[node_id]
            node.alive = False
            orphans = []
            for did in node.devices:
                orphans.extend(self._kill_device(self.devices[did]))
            return orphans

    def mark_device_dead(self, device_id: str) -> List[VSlice]:
        """Device-granular failure (the node survives): one accelerator
        dropped off the bus / failed its status read. Returns the orphaned
        slices that need re-placement."""
        with self._lock:
            return self._kill_device(self.devices[device_id])

    def _kill_device(self, dev: PhysicalDevice) -> List[VSlice]:
        if dev.state != DeviceState.DEAD:
            # guard: a node kill sweeps every device on the node, some of
            # which may already be individually dead — DEAD is sticky and
            # re-killing a dead device is not a lifecycle event
            sanitizer.emit("device", (self._san, dev.device_id), "kill")
        dev.state = DeviceState.DEAD
        orphans = list(dev.slices.values())
        dev.slices = {}
        return orphans

    # ---------------- persistence ----------------
    def to_json(self) -> str:
        with self._lock:
            def enc(o):
                if isinstance(o, enum.Enum):
                    return o.value
                if dataclasses.is_dataclass(o):
                    return dataclasses.asdict(o)
                raise TypeError(type(o))
            return json.dumps({
                "nodes": {k: dataclasses.asdict(v)
                          for k, v in self.nodes.items()},
                "devices": {k: dataclasses.asdict(v)
                            for k, v in self.devices.items()},
                "slice_counter": self._slice_counter,
            }, default=enc, indent=1)

    @classmethod
    def from_json(cls, text: str) -> "DeviceDB":
        raw = json.loads(text)
        db = cls()
        for k, v in raw["nodes"].items():
            db.nodes[k] = Node(**v)
        for k, v in raw["devices"].items():
            slices = {sk: VSlice(**{**sv, "state": SliceState(sv["state"])})
                      for sk, sv in v.pop("slices").items()}
            d = PhysicalDevice(**{**v, "state": DeviceState(v["state"]),
                                  "slices": slices})
            db.devices[k] = d
        db._slice_counter = raw["slice_counter"]
        return db


class NoCapacityError(RuntimeError):
    pass
