"""RC3E hypervisor (paper §IV): the control plane tying together the device
database, program cache / partial reconfiguration, batch scheduler and
monitor, and exposing the three cloud service models:

  RSaaS  - allocate a full physical mesh, run arbitrary jitted programs
  RAaaS  - allocate a vSlice, plug a user core into the RC2F shell
  BAaaS  - invoke a provider-prebuilt service (model zoo), allocation hidden

Serving traffic enters through the *tenant session* API
(``open_serving_session`` / ``record_served_request`` /
``close_serving_session``): the serving gateway in
``repro.runtime.gateway`` binds every tenant to a hypervisor-allocated
vSlice, and per-step telemetry flows into the straggler monitor so hot
tenants get migrated like any other workload.

On this CPU container the "physical device" is a simulated inventory; the
dataplane executes on the host jax device. On a real cluster the same control
plane drives per-slice jax meshes (launch/mesh.py builds them).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.device_db import (DeviceDB, DeviceState, NoCapacityError,
                                  SliceState, VSlice)
from repro.core.monitor import Monitor, MonitorConfig
from repro.core.reconfig import ProgramCache, ProgramEntry, Reconfigurator
from repro.core.scheduler import BatchScheduler, JobState
from repro.rc2f.admission import AdmissionController, AdmissionError


@dataclass
class ClusterSpec:
    """Inventory description, e.g. 2 nodes × 2 devices × 256 chips.
    ``cache_pages_per_device`` meters each device's KV page pool (0 =
    unmetered): page-bearing vSlice grants are then packed against it.
    ``device_draws`` assigns per-device power draws (cycled over the
    fleet-wide device index) for heterogeneous energy accounting; empty
    means a homogeneous fleet of draw 1.0. ``device_speeds`` does the
    same for relative dataplane speed: the event-driven serving loop
    steps each engine every ``tick_s / speed`` event-seconds, so mixed
    device classes decode on their own cadence."""
    n_nodes: int = 2
    devices_per_node: int = 2
    chips_per_device: int = 256
    cache_pages_per_device: int = 0
    device_draws: Tuple[float, ...] = ()
    device_speeds: Tuple[float, ...] = ()


class Hypervisor:
    def __init__(self, spec: Optional[ClusterSpec] = None,
                 monitor_cfg: Optional[MonitorConfig] = None,
                 clock: Callable[[], float] = time.monotonic,
                 admission: Optional[AdmissionController] = None):
        spec = spec if spec is not None else ClusterSpec()
        self.db = DeviceDB()
        for ni in range(spec.n_nodes):
            node = self.db.add_node(f"node-{ni}")
            node.last_heartbeat = clock()
            for di in range(spec.devices_per_node):
                idx = ni * spec.devices_per_node + di
                draw = spec.device_draws[idx % len(spec.device_draws)] \
                    if spec.device_draws else 1.0
                speed = spec.device_speeds[idx % len(spec.device_speeds)] \
                    if spec.device_speeds else 1.0
                self.db.add_device(f"dev-{ni}-{di}", node.node_id,
                                   spec.chips_per_device,
                                   cache_pages=spec.cache_pages_per_device,
                                   draw=draw, speed=speed)
        self.reconfig = Reconfigurator(ProgramCache())
        self.scheduler = BatchScheduler(self.db, clock)
        self.monitor = Monitor(self.db,
                               monitor_cfg if monitor_cfg is not None
                               else MonitorConfig(), clock)
        # the controller's rate-limit buckets refill on the hypervisor's
        # clock — a FakeClock-driven harness rate-limits in event time
        self.admission = admission if admission is not None \
            else AdmissionController(clock=clock)
        self.clock = clock
        self.services: Dict[str, Callable[[], Any]] = {}
        self.log: List[dict] = []
        self.last_migrations: List[Tuple[str, str]] = []
        # called with (old_slice_id, new_slice_id) on every migration, so
        # components holding slice handles (serving gateway) rebind at the
        # source instead of polling
        self.migration_listeners: List[Callable[[str, str], None]] = []

    # ------------------------------------------------------------------
    # Middleware entry points (paper §IV-C)
    # ------------------------------------------------------------------
    def status(self) -> dict:
        """RC2F status call analogue (Table I row 1)."""
        return self.monitor.status()

    # ---------------- RSaaS ----------------
    def allocate_physical(self, owner: str,
                          device_id: Optional[str] = None):
        dev = self.db.allocate_exclusive(owner, device_id)
        self._log("rsaas_alloc", owner=owner, device=dev.device_id)
        return dev

    # ---------------- RAaaS ----------------
    def allocate_vslice(self, owner: str, slots: int = 1,
                        service_model: str = "raas",
                        cache_pages: int = 0) -> VSlice:
        vs = self.db.allocate_slice(owner, slots, service_model,
                                    cache_pages=cache_pages)
        self._log("vslice_alloc", owner=owner, slice=vs.slice_id,
                  device=vs.device_id, slots=slots, cache_pages=cache_pages)
        return vs

    def release(self, slice_id: str):
        self.db.release(slice_id)
        self.monitor.clear_slice(slice_id)
        self._log("release", slice=slice_id)

    def program_slice(self, slice_id: str, fn: Callable, example_inputs,
                      static_desc: str = "",
                      geometry: str = "") -> ProgramEntry:
        """Configure a vSlice with a user core (full config or PR swap).
        ``geometry`` keys tuned-kernel variants of one core apart."""
        entry, dt, hit = self.reconfig.partial_reconfigure(
            fn, example_inputs, static_desc=static_desc, geometry=geometry)
        self.db.set_slice_state(slice_id, SliceState.CONFIGURED,
                                program=entry.fingerprint)
        self._log("program", slice=slice_id, fingerprint=entry.fingerprint,
                  seconds=dt, cache_hit=hit)
        return entry

    def execute(self, slice_id: str, *args):
        """Run the slice's configured executable; records step time for the
        straggler monitor."""
        vs = self.db.find_slice(slice_id)
        if vs.program is None:
            raise RuntimeError(f"slice {slice_id} not configured")
        entry = self._entry_for(vs.program)
        self.db.set_slice_state(slice_id, SliceState.RUNNING)
        t0 = self.clock()
        out = entry.compiled(*args)
        self.monitor.record_step(slice_id, (self.clock() - t0) * 1e3)
        self.db.set_slice_state(slice_id, SliceState.CONFIGURED)
        return out

    def _entry_for(self, fingerprint: str) -> ProgramEntry:
        return self.reconfig.cache.entry_for(fingerprint)

    # ---------------- BAaaS ----------------
    def register_service(self, name: str, builder: Callable[[], Any]):
        """Provider-prebuilt service (bitfile + host app in the paper)."""
        self.services[name] = builder

    def invoke_service(self, name: str, owner: str,
                       args: Optional[tuple] = None, *, slots: int = 1):
        """BAaaS: allocation + configuration happen invisibly.

        ``args`` is the explicit input tuple, or None to run the service on
        its registered example inputs. An empty tuple is respected as "call
        with no inputs" (zero-input cores) — it must NOT fall back to the
        example inputs the way a falsy check would.
        """
        if name not in self.services:
            raise KeyError(f"no service {name!r}")
        vs = self.allocate_vslice(owner, slots, service_model="baas")
        try:
            fn, example_inputs = self.services[name]()
            self.program_slice(vs.slice_id, fn, example_inputs,
                               static_desc=name)
            call_args = example_inputs if args is None else tuple(args)
            return self.execute(vs.slice_id, *call_args)
        finally:
            self.release(vs.slice_id)

    # ------------------------------------------------------------------
    # Serving gateway tenant sessions (shared-device inference traffic)
    # ------------------------------------------------------------------
    def open_serving_session(self, tenant: str, slots: int = 1,
                             service_model: str = "baas",
                             cache_pages: int = 0) -> VSlice:
        """Admit a tenant (quota check) and bind it to a vSlice. Every
        serving request is attributed to this slice in ``log`` and the
        monitor, so stragglers among serving tenants migrate exactly like
        batch workloads. ``cache_pages`` grants the slice a share of the
        device's KV page pool, clamped to the service model's
        ``max_cache_pages_per_tenant`` quota (the memory dimension of the
        vSlice)."""
        quota = self.admission.quota_for(service_model)
        if quota.max_cache_pages_per_tenant and cache_pages:
            cache_pages = min(cache_pages,
                              quota.max_cache_pages_per_tenant)
        self.admission.admit_tenant(tenant, service_model, slots)
        try:
            vs = self.allocate_vslice(tenant, slots, service_model,
                                      cache_pages=cache_pages)
        except Exception:   # NoCapacityError, bad slot count, ...
            self.admission.release_tenant(tenant, service_model, slots)
            raise
        self._log("session_open", tenant=tenant, slice=vs.slice_id,
                  device=vs.device_id, slots=slots,
                  service_model=service_model, cache_pages=cache_pages)
        return vs

    def close_serving_session(self, slice_id: str):
        vs = self.db.find_slice(slice_id)
        tenant, model, slots = vs.owner, vs.service_model, vs.slots
        self.release(slice_id)
        self.admission.release_tenant(tenant or "", model or "baas", slots)
        self._log("session_close", tenant=tenant, slice=slice_id)

    def admit_serving_request(self, slice_id: str, prompt_tokens: int,
                              new_tokens: int):
        """Per-request admission against the session's service-model quota."""
        vs = self.db.find_slice(slice_id)
        self.admission.admit_request(vs.owner or "", vs.service_model or
                                     "baas", prompt_tokens, new_tokens)

    def record_serving_step(self, slice_id: str, step_ms: float):
        """Attribute one shared decode step to a tenant's slice. Feeds the
        same straggler policy as ``execute``."""
        self.db.set_slice_state(slice_id, SliceState.RUNNING)
        self.monitor.record_step(slice_id, step_ms)

    def record_served_request(self, slice_id: str, tenant: str,
                              request_id: int, prompt_tokens: int,
                              new_tokens: int, latency_ms: float):
        """Log a completed request against its vSlice (audit trail: every
        served request is traceable to a hypervisor allocation)."""
        vs = self.db.find_slice(slice_id)
        self.admission.finish_request(tenant, vs.service_model or "baas")
        self._log("serve", tenant=tenant, slice=slice_id,
                  request=request_id, prompt_tokens=prompt_tokens,
                  new_tokens=new_tokens, latency_ms=round(latency_ms, 3))

    # ------------------------------------------------------------------
    # Failure handling / elasticity
    # ------------------------------------------------------------------
    def handle_failures(self) -> List[str]:
        """Heartbeat sweep -> mark dead nodes -> requeue orphaned batch jobs.
        Returns orphaned slice ids."""
        orphans = self.monitor.check_heartbeats()
        ids = [s.slice_id for s in orphans]
        if ids:
            self.scheduler.requeue_orphans(ids)
            self._log("failover", orphans=ids)
        return ids

    def mark_device_failed(self, device_id: str,
                           reason: str = "status_error") -> List[str]:
        """Device-granular failure: one accelerator failed its status read
        (the gcs analogue) while its node stayed up. Marks the device DEAD,
        clears its telemetry (step windows + page occupancy — a dead pool
        must not keep feeding the straggler / page-pressure policies),
        requeues orphaned batch jobs, and returns the orphaned slice ids.
        Serving sessions are re-placed by the fleet's recovery sweep, which
        watches for DEAD devices holding engines."""
        orphans = self.db.mark_device_dead(device_id)
        ids = [s.slice_id for s in orphans]
        for sid in ids:
            self.monitor.clear_slice(sid)
        self.monitor.clear_pages(device_id)
        self.monitor.clear_traffic(device_id)
        self.monitor.events.append({"t": self.clock(), "kind": "device_dead",
                                    "device": device_id, "orphans": ids})
        if ids:
            self.scheduler.requeue_orphans(ids)
        self._log("device_failed", device=device_id, reason=reason,
                  orphans=ids)
        return ids

    def migrate_slice(self, slice_id: str,
                      target_device: Optional[str] = None,
                      reason: str = "straggler") -> Optional[VSlice]:
        """Re-place ONE slice on another device, carrying its program
        fingerprint (PR makes re-programming cheap on the target).

        Directed when ``target_device`` is given (elastic scale-out wakes a
        PARKED device this way); otherwise the allocator packs it anywhere
        except its current device. Fires ``migration_listeners`` with
        (old, new) slice ids — the serving fleet's listener performs the
        live dataplane hand-off. Returns the new slice, or None when the
        move is impossible (unknown slice, no capacity, target == source).
        """
        try:
            vs = self.db.find_slice(slice_id)
        except KeyError:
            return None
        old_dev = vs.device_id
        if target_device == old_dev:
            return None
        prev_state = vs.state
        self.db.set_slice_state(slice_id, SliceState.MIGRATING)
        try:
            new = self.db.allocate_slice(vs.owner, vs.slots,
                                         vs.service_model or "raas",
                                         device_id=target_device,
                                         exclude_device=old_dev,
                                         cache_pages=vs.cache_pages)
        except NoCapacityError:
            # nowhere better to go; keep the original placement AND state
            # (a directed move may target a never-executed slice)
            self.db.set_slice_state(slice_id, prev_state)
            return None
        new.program = vs.program
        new.state = SliceState.CONFIGURED if vs.program \
            else SliceState.ALLOCATED
        self.db.release(slice_id)
        self.monitor.clear_slice(slice_id)
        # batch jobs running on the old slice follow it, like serving
        # sessions do via the listeners below — otherwise their eventual
        # complete()/fail() hits a released slice and the new one leaks
        for job in self.scheduler.jobs.values():
            if job.slice_id == slice_id and job.state == JobState.RUNNING:
                job.slice_id = new.slice_id
        self._log("migrate", old=slice_id, new=new.slice_id,
                  old_device=old_dev, new_device=new.device_id,
                  reason=reason)
        for listener in self.migration_listeners:
            listener(slice_id, new.slice_id)
        return new

    def migrate_stragglers(self) -> List[str]:
        """Re-place slices flagged by the straggler policy (paper's load
        distribution role). Returns new slice ids; ``last_migrations`` holds
        the (old, new) pairs so callers holding slice handles (e.g. the
        serving gateway) can rebind."""
        moved = []
        self.last_migrations = []
        for sid in self.monitor.find_stragglers():
            new = self.migrate_slice(sid, reason="straggler")
            if new is not None:
                moved.append(new.slice_id)
                self.last_migrations.append((sid, new.slice_id))
        return moved

    # ------------------------------------------------------------------
    def _log(self, kind: str, **kw):
        self.log.append({"t": self.clock(), "kind": kind, **kw})
