"""Batch system (paper §IV-C: "integrated batch system for long-running
applications without direct user interaction").

Jobs specify slice size, service model and a run callable. The scheduler
admits jobs FIFO-within-priority when capacity exists, tracks running jobs,
and re-queues jobs orphaned by node failures or straggler migration.
"""
from __future__ import annotations

import enum
import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.device_db import (DeviceDB, DeviceState, NoCapacityError,
                                  SliceState)


class JobState(str, enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    REQUEUED = "requeued"


@dataclass(order=True)
class _QEntry:
    priority: int
    seq: int
    job_id: str = field(compare=False)


@dataclass
class Job:
    job_id: str
    owner: str
    slots: int                    # vSlice size (1/2/4)
    service_model: str            # raas | baas
    run: Optional[Callable[..., Any]] = None   # called with (slice_id)
    priority: int = 10            # lower = sooner
    state: JobState = JobState.QUEUED
    slice_id: Optional[str] = None
    result: Any = None
    error: Optional[str] = None
    submitted_at: float = 0.0
    attempts: int = 0
    max_attempts: int = 3
    deferrals: int = 0            # consecutive NoCapacity passes (aging)


class BatchScheduler:
    def __init__(self, db: DeviceDB,
                 clock: Callable[[], float] = time.monotonic,
                 starvation_patience: int = 3):
        self.db = db
        self.clock = clock
        self.starvation_patience = starvation_patience
        self.jobs: Dict[str, Job] = {}
        self._heap: List[_QEntry] = []
        self._seq = itertools.count()        # job ids
        self._hseq = itertools.count()       # FIFO tiebreak within priority
        self.history: List[dict] = []
        # per-owner weighted fair share (deficit credit): owners with
        # queued work accrue weight each pass and pay ``slots`` per start,
        # so within a priority band a flood of one owner's jobs cannot
        # starve a co-tenant — the same DRR policy the serving engine
        # applies to decode slots, here over batch vSlice allocations
        self._owner_weight: Dict[str, float] = {}
        self._owner_credit: Dict[str, float] = {}

    # ---------------- submission ----------------
    def submit(self, owner: str, slots: int, service_model: str = "raas",
               run: Optional[Callable] = None, priority: int = 10) -> Job:
        job_id = f"job-{next(self._seq):05d}"
        job = Job(job_id, owner, slots, service_model, run, priority,
                  submitted_at=self.clock())
        self.jobs[job_id] = job
        heapq.heappush(self._heap, _QEntry(priority, next(self._hseq), job_id))
        return job

    def set_owner_weight(self, owner: str,
                         weight: Optional[float] = None) -> None:
        """Fair-share weight for ``owner`` (None resets to 1.0)."""
        if weight is None:
            self._owner_weight.pop(owner, None)
        else:
            self._owner_weight[owner] = max(1e-3, float(weight))

    # ---------------- scheduling loop ----------------
    def _fair_order(self, entries: List[_QEntry]) -> List[_QEntry]:
        """Order queued entries by (priority, owner fair-share credit,
        submission order). Owners with queued work accrue credit each
        pass; a start debits ``slots``. With one owner — or balanced,
        equally-weighted owners — this degenerates to plain
        priority-FIFO, so fairness costs nothing until tenants actually
        contend. Credit is pruned only when an owner has neither queued
        nor running jobs (erasing debt mid-flight would reward a
        one-job-at-a-time flood)."""
        queued_owners = {self.jobs[e.job_id].owner for e in entries}
        running_owners = {j.owner for j in self.jobs.values()
                          if j.state == JobState.RUNNING}
        for o in list(self._owner_credit):
            if o not in queued_owners and o not in running_owners:
                del self._owner_credit[o]
        for o in sorted(queued_owners):
            self._owner_credit[o] = self._owner_credit.get(o, 0.0) + \
                self._owner_weight.get(o, 1.0)
        return sorted(entries, key=lambda e: (
            e.priority,
            -self._owner_credit.get(self.jobs[e.job_id].owner, 0.0),
            e.seq))

    def schedule_once(self) -> List[Job]:
        """Admit as many queued jobs as capacity allows (priority order,
        owner-fair within a priority band — see ``_fair_order``).
        Returns the jobs started this pass.

        Backfill with aging: a job deferred by ``NoCapacityError`` normally
        lets smaller jobs behind it run (backfill), but after
        ``starvation_patience`` consecutive deferred passes the pass stops
        at it (hold-back reservation) — freed capacity then accumulates for
        the large job instead of being nibbled away by a stream of small
        ones behind it.
        """
        started: List[Job] = []
        deferred: List[_QEntry] = []
        live: List[_QEntry] = []
        while self._heap:
            entry = heapq.heappop(self._heap)
            if self.jobs[entry.job_id].state in (JobState.QUEUED,
                                                 JobState.REQUEUED):
                live.append(entry)
        pending = self._fair_order(live)
        for idx, entry in enumerate(pending):
            job = self.jobs[entry.job_id]
            try:
                vs = self.db.allocate_slice(job.owner, job.slots,
                                            job.service_model)
            except NoCapacityError:
                deferred.append(entry)
                job.deferrals += 1
                if job.deferrals >= self.starvation_patience \
                        and self._reservation_feasible(job):
                    self.history.append(
                        {"t": self.clock(), "kind": "holdback",
                         "job": job.job_id, "deferrals": job.deferrals})
                    deferred.extend(pending[idx + 1:])
                    break
                # keep draining the queue: a smaller job behind may still fit
                continue
            job.slice_id = vs.slice_id
            job.state = JobState.RUNNING
            job.attempts += 1
            job.deferrals = 0
            self._owner_credit[job.owner] = \
                self._owner_credit.get(job.owner, 0.0) - job.slots
            self.db.set_slice_state(vs.slice_id, SliceState.RUNNING)
            self.history.append({"t": self.clock(), "kind": "start",
                                 "job": job.job_id, "slice": vs.slice_id})
            started.append(job)
        for e in deferred:
            heapq.heappush(self._heap, e)
        return started

    def _reservation_feasible(self, job: Job) -> bool:
        """Escape hatch for the hold-back: only reserve capacity for a job
        that completing the currently-RUNNING batch jobs could ever make
        fit. If the blocking slots belong to allocations the scheduler
        does not control (serving sessions, RSaaS tenants), holding the
        queue would starve everyone behind the job forever — keep
        backfilling instead."""
        running_by_dev: Dict[str, int] = {}
        for j in self.jobs.values():
            if j.state == JobState.RUNNING and j.slice_id:
                try:
                    vs = self.db.find_slice(j.slice_id)
                except KeyError:
                    continue
                running_by_dev[vs.device_id] = \
                    running_by_dev.get(vs.device_id, 0) + vs.slots
        return any(
            d.free_slots() + running_by_dev.get(d.device_id, 0) >= job.slots
            for d in self.db.alive_devices()
            if d.state != DeviceState.EXCLUSIVE)

    def run_pending(self) -> List[Job]:
        """Admit + synchronously execute (test/CPU mode)."""
        started = self.schedule_once()
        for job in started:
            try:
                if job.run is not None:
                    job.result = job.run(job.slice_id)
                self.complete(job.job_id)
            except Exception as e:  # noqa: BLE001 - job isolation
                self.fail(job.job_id, str(e))
        return started

    # ---------------- lifecycle ----------------
    def complete(self, job_id: str):
        job = self.jobs[job_id]
        job.state = JobState.DONE
        if job.slice_id:
            self.db.release(job.slice_id)
            job.slice_id = None
        self.history.append({"t": self.clock(), "kind": "done", "job": job_id})

    def fail(self, job_id: str, error: str):
        job = self.jobs[job_id]
        job.error = error
        if job.slice_id:
            try:
                self.db.release(job.slice_id)
            except KeyError:
                pass   # slice died with its node
            job.slice_id = None
        if job.attempts < job.max_attempts:
            job.state = JobState.REQUEUED
            heapq.heappush(self._heap,
                           _QEntry(job.priority, next(self._hseq), job_id))
        else:
            job.state = JobState.FAILED
        self.history.append({"t": self.clock(), "kind": "fail", "job": job_id,
                             "error": error, "attempts": job.attempts})

    def requeue_orphans(self, orphan_slice_ids: List[str]):
        """Called by the hypervisor after a node failure."""
        for job in self.jobs.values():
            if job.state == JobState.RUNNING and job.slice_id in orphan_slice_ids:
                job.slice_id = None
                self.fail(job.job_id, "node failure")

    def queued(self) -> List[Job]:
        return [j for j in self.jobs.values()
                if j.state in (JobState.QUEUED, JobState.REQUEUED)]
