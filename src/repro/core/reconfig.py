"""Configuration & partial reconfiguration (paper §IV-C, Table I).

FPGA mapping:
  full configuration  (bitstream, ~29 s)  -> cold jit lower+compile
  partial reconfig    (PR region, ~0.9 s) -> hot swap of a cached executable
                                             into a vSlice while co-tenants run

The ``ProgramCache`` is the "bitfile library": keyed by (core fingerprint,
input avals, kernel geometry). ``configure`` populates it (slow path);
``partial_reconfigure`` swaps a cached executable into a slice (fast path).
Latencies of both paths are what benchmarks/table1_overhead.py measures.

The cache also persists auto-tuner winners: a side store maps
(model fingerprint, device class) -> TunedConfig dict, JSON round-trippable
via ``save_tuned``/``load_tuned``, so a provider's tuned library survives
restarts the way a bitfile store would.
"""
from __future__ import annotations

import hashlib
import json
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax


def fingerprint(fn: Callable, static_desc: str = "") -> str:
    """Stable fingerprint of a user core (the 'bitfile hash')."""
    src = getattr(fn, "__name__", repr(fn)) + static_desc
    try:
        import inspect
        src += inspect.getsource(fn)
    except (OSError, TypeError):
        src += repr(fn)
    return hashlib.sha256(src.encode()).hexdigest()[:16]


def _aval_key(tree) -> str:
    leaves = jax.tree.leaves(
        jax.tree.map(lambda x: (tuple(x.shape), str(x.dtype)), tree))
    return hashlib.sha256(repr(leaves).encode()).hexdigest()[:16]


@dataclass
class ProgramEntry:
    fingerprint: str
    compiled: Any                 # jax compiled executable
    lowered_text: Optional[str]   # HLO for admission inspection / roofline
    compile_time_s: float
    flops: float = 0.0
    bytes_accessed: float = 0.0


class ProgramCache:
    """Executable cache ≈ the provider's pre-built bitfile store (BAaaS).

    Doubly indexed: by full key (fingerprint, input avals, kernel geometry)
    for PR swaps, and by fingerprint alone for the hypervisor's execute
    path. Optionally bounded: ``max_entries`` evicts least-recently-used
    programs, the analogue of a finite on-device bitfile library.

    Kernel geometry is part of the key: a tuned program and the default
    program for the same model/avals are distinct executables and must
    never collide (the auto-tuner compiles several geometries of one
    fingerprintable core).
    """

    def __init__(self, max_entries: Optional[int] = None):
        from collections import OrderedDict
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Tuple[str, str, str], ProgramEntry]" = \
            OrderedDict()
        self._by_fp: Dict[str, ProgramEntry] = {}
        self._fp_key: Dict[str, Tuple[str, str, str]] = {}
        self._tuned: Dict[Tuple[str, str], dict] = {}
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def key(self, fp: str, example_inputs,
            geometry: str = "") -> Tuple[str, str, str]:
        return (fp, _aval_key(example_inputs), geometry)

    def get(self, key) -> Optional[ProgramEntry]:
        with self._lock:
            e = self._entries.get(key)
            if e is not None:
                self.hits += 1
                self._entries.move_to_end(key)
            else:
                self.misses += 1
            return e

    def put(self, key, entry: ProgramEntry):
        with self._lock:
            self._entries[key] = entry
            self._entries.move_to_end(key)
            self._by_fp[entry.fingerprint] = entry
            self._fp_key[entry.fingerprint] = key
            while self.max_entries is not None \
                    and len(self._entries) > self.max_entries:
                _, old = self._entries.popitem(last=False)
                self._drop_fp(old)
                self.evictions += 1

    def entry_for(self, fingerprint: str) -> ProgramEntry:
        """O(1) lookup by program fingerprint (the 'bitfile hash'). Counts
        as a use for the LRU bound — a program that keeps executing stays
        resident.

        Raises KeyError if the program was evicted or never configured —
        callers holding a stale fingerprint must reconfigure.
        """
        with self._lock:
            try:
                entry = self._by_fp[fingerprint]
            except KeyError:
                raise KeyError(
                    f"program {fingerprint} evicted or never configured"
                ) from None
            self._entries.move_to_end(self._fp_key[fingerprint])
            return entry

    def evict(self, fingerprint: str) -> None:
        """Drop every entry for a fingerprint (bitfile withdrawn)."""
        with self._lock:
            for k in [k for k in self._entries if k[0] == fingerprint]:
                old = self._entries.pop(k)
                self._drop_fp(old)
                self.evictions += 1

    def _drop_fp(self, entry: ProgramEntry) -> None:
        # repoint the fingerprint index at the most-recently-used surviving
        # aval-variant, or clear it when none remains
        for k in reversed(self._entries):
            if k[0] == entry.fingerprint:
                self._by_fp[entry.fingerprint] = self._entries[k]
                self._fp_key[entry.fingerprint] = k
                return
        self._by_fp.pop(entry.fingerprint, None)
        self._fp_key.pop(entry.fingerprint, None)

    def __len__(self):
        return len(self._entries)

    # ---------------- tuned-config store (auto-tuner winners) ------------

    def put_tuned(self, model_fp: str, device_class: str,
                  cfg: dict) -> None:
        """Persist the auto-tuner's winning geometry for a
        (model fingerprint, device class) pair."""
        with self._lock:
            self._tuned[(model_fp, device_class)] = dict(cfg)

    def get_tuned(self, model_fp: str,
                  device_class: str) -> Optional[dict]:
        with self._lock:
            rec = self._tuned.get((model_fp, device_class))
            return dict(rec) if rec is not None else None

    def tuned_configs(self) -> Dict[Tuple[str, str], dict]:
        with self._lock:
            return {k: dict(v) for k, v in self._tuned.items()}

    def save_tuned(self, path: str) -> None:
        """JSON-persist the tuned library (survives restarts like a
        provider's bitfile store)."""
        with self._lock:
            blob = {f"{fp}|{cls}": cfg
                    for (fp, cls), cfg in sorted(self._tuned.items())}
        with open(path, "w") as f:
            json.dump(blob, f, indent=2, sort_keys=True)

    def load_tuned(self, path: str) -> int:
        with open(path) as f:
            blob = json.load(f)
        with self._lock:
            for key, cfg in blob.items():
                fp, _, cls = key.partition("|")
                self._tuned[(fp, cls)] = dict(cfg)
        return len(blob)


class Reconfigurator:
    """Implements full configure vs partial reconfigure for vSlices."""

    def __init__(self, cache: Optional[ProgramCache] = None):
        # NOT `cache or ...`: an empty ProgramCache is falsy via __len__
        self.cache = cache if cache is not None else ProgramCache()

    def configure(self, fn: Callable, example_inputs, *,
                  static_desc: str = "", jit_kwargs: Optional[dict] = None,
                  keep_hlo: bool = False,
                  geometry: str = "") -> Tuple[ProgramEntry, float]:
        """Full configuration: lower + compile (slow; paper's ~29 s path).

        Returns (entry, elapsed_seconds). Cached afterwards for PR swaps.
        """
        fp = fingerprint(fn, static_desc)
        key = self.cache.key(fp, example_inputs, geometry)
        t0 = time.perf_counter()
        jitted = jax.jit(fn, **(jit_kwargs or {}))
        lowered = jitted.lower(*example_inputs) if isinstance(example_inputs, tuple) \
            else jitted.lower(example_inputs)
        compiled = lowered.compile()
        dt = time.perf_counter() - t0
        cost = {}
        try:
            cost = compiled.cost_analysis() or {}
        except Exception:
            pass
        if isinstance(cost, (list, tuple)):   # older jax returns [dict]
            cost = cost[0] if cost else {}
        entry = ProgramEntry(
            fingerprint=fp, compiled=compiled,
            lowered_text=lowered.as_text() if keep_hlo else None,
            compile_time_s=dt,
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=float(cost.get("bytes accessed", 0.0)))
        self.cache.put(key, entry)
        return entry, dt

    def partial_reconfigure(self, fn: Callable, example_inputs, *,
                            static_desc: str = "",
                            geometry: str = "") -> Tuple[ProgramEntry, float, bool]:
        """PR swap: reuse a cached executable if present (fast; ~ms), else
        fall back to full configuration. Returns (entry, seconds, was_hit)."""
        fp = fingerprint(fn, static_desc)
        key = self.cache.key(fp, example_inputs, geometry)
        t0 = time.perf_counter()
        entry = self.cache.get(key)
        if entry is not None:
            return entry, time.perf_counter() - t0, True
        entry, dt = self.configure(fn, example_inputs, static_desc=static_desc,
                                   geometry=geometry)
        return entry, dt, False
