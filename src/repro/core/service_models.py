"""User-facing sessions for the three cloud service models (paper §III).

These wrap the hypervisor with the per-model *capability* restrictions the
paper describes: RSaaS exposes raw device control; RAaaS only exposes the
RC2F core interface; BAaaS exposes nothing but named services.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

from repro.core.hypervisor import Hypervisor


class RSaaSSession:
    """Reconfigurable Silicon as a Service: full physical device, arbitrary
    programs (≈ IaaS). The user may replace 'the PCIe endpoint' — here, run
    any jitted function, including ones bypassing the RC2F shell."""

    def __init__(self, hv: Hypervisor, owner: str):
        self.hv = hv
        self.owner = owner
        self.device = hv.allocate_physical(owner)
        self.slice_id = next(iter(hv.db.device(self.device.device_id)
                                  .slices.keys()))

    def program(self, fn: Callable, example_inputs, desc: str = ""):
        return self.hv.program_slice(self.slice_id, fn, example_inputs, desc)

    def run(self, *args):
        return self.hv.execute(self.slice_id, *args)

    def close(self):
        self.hv.release(self.slice_id)


class RAaaSSession:
    """Reconfigurable Accelerators as a Service: a vSlice + the RC2F core
    interface only (≈ PaaS). Admission-checks the user core against its
    declared stream shapes before programming (the paper's planned
    'bitstream sanity checking')."""

    def __init__(self, hv: Hypervisor, owner: str, slots: int = 1):
        self.hv = hv
        self.owner = owner
        self.vslice = hv.allocate_vslice(owner, slots, "raas")

    def deploy_core(self, core_fn: Callable, example_inputs,
                    desc: str = "") -> Any:
        from repro.rc2f.admission import admit_core
        admit_core(core_fn, example_inputs)
        return self.hv.program_slice(self.vslice.slice_id, core_fn,
                                     example_inputs, desc)

    def run(self, *args):
        return self.hv.execute(self.vslice.slice_id, *args)

    def submit_batch(self, run: Callable, priority: int = 10):
        """Paper §III-B: host program submitted to the batch system."""
        return self.hv.scheduler.submit(self.owner, self.vslice.slots,
                                        "raas", run, priority)

    def close(self):
        self.hv.release(self.vslice.slice_id)


class BAaaSSession:
    """Background Acceleration as a Service: only named services are visible;
    vFPGAs/vSlices are never exposed (≈ SaaS)."""

    def __init__(self, hv: Hypervisor, owner: str):
        self.hv = hv
        self.owner = owner

    def list_services(self):
        return sorted(self.hv.services.keys())

    def invoke(self, service: str, *args, slots: int = 1):
        """Invoke with the given inputs; with none, the service runs on its
        registered example inputs. To call a zero-input core explicitly,
        pass ``args=()`` to ``Hypervisor.invoke_service`` directly."""
        return self.hv.invoke_service(service, self.owner,
                                      args if args else None, slots=slots)
