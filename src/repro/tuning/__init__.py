"""Design-space auto-tuner for dataplane geometry (CDSE discipline).

``space`` declares the TunedConfig knobs and enumerates legal candidates,
``cost_model`` scores each candidate with a roofline-backed analytical
model under hard VMEM/HBM/divisibility constraints, and ``explorer``
sweeps the space and persists the winner per (model fingerprint, device
class) in the ProgramCache — so the hypervisor binds tuned programs
automatically, per device class, with zero operator input.

All of it is pure math — no device, no tracing, deterministic across
hosts (the benchmark JSON diffs cleanly in CI). The only import weight
is ``kernels.registry`` via the ``repro.kernels`` package; the analysis
pass guards its import accordingly.
"""
from repro.tuning.cost_model import (DeviceProfile, candidate_cost,
                                     profile_for_speed, prune_reason)
from repro.tuning.explorer import (device_class, model_fingerprint,
                                   resolve_tuned, tune)
from repro.tuning.space import (TunedConfig, enumerate_candidates,
                                legal_reason)

__all__ = [
    "TunedConfig", "enumerate_candidates", "legal_reason",
    "DeviceProfile", "profile_for_speed", "prune_reason", "candidate_cost",
    "tune", "resolve_tuned", "device_class", "model_fingerprint",
]
