"""Design-space exploration + persistence of winners.

``tune`` sweeps every legal candidate through the cost model and returns
a report (winner + ranked table + prune census). ``resolve_tuned`` is
the runtime entry point: look up the persisted winner for this
``(model fingerprint, device class)`` in the ProgramCache's tuned-config
store, tuning on first use — the hypervisor/fleet call it at bind time
so tenants land on class-appropriate geometry with zero operator input.

Optional ``measure`` hook: a callable scoring a candidate empirically
(seeded wall-clock timing); when given, the modeled top-k are re-ranked
by measurement. CI never passes it — the JSON stays deterministic.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.tuning.cost_model import (Cost, DeviceProfile, candidate_cost,
                                     profile_for_speed)
from repro.tuning.space import TunedConfig, enumerate_candidates


def device_class(speed: float) -> str:
    """Canonical device-class name for a PhysicalDevice speed."""
    return f"c{float(speed):.2f}x"


def model_fingerprint(cfg: ModelConfig, max_len: int, paged: bool) -> str:
    """Stable key for 'this model served this way' — what tuned configs
    are persisted under."""
    desc = (f"{cfg.name}:{cfg.n_layers}x{cfg.d_model}"
            f":h{cfg.n_heads}/{cfg.n_kv_heads}:hd{cfg.resolved_head_dim}"
            f":ff{cfg.d_ff}:v{cfg.vocab_size}:{cfg.dtype}"
            f":kvq{int(cfg.kv_quant)}:len{max_len}:paged{int(paged)}")
    return hashlib.sha256(desc.encode()).hexdigest()[:16]


@dataclass
class TuneReport:
    best: TunedConfig
    best_cost: Cost
    default_cost: Cost
    device_class: str
    model_fp: str
    n_candidates: int = 0
    n_pruned: int = 0
    prune_census: dict = field(default_factory=dict)
    table: List[Tuple[TunedConfig, Cost]] = field(default_factory=list)

    @property
    def win(self) -> float:
        """default/tuned service-time ratio (>1 means the tuner won)."""
        if self.best_cost.us_per_token <= 0:
            return 1.0
        return self.default_cost.us_per_token / self.best_cost.us_per_token


def tune(cfg: ModelConfig, profile: DeviceProfile, *, max_len: int,
         paged: bool, top_k: int = 8,
         measure: Optional[Callable[[TunedConfig], float]] = None
         ) -> TuneReport:
    """Exhaustive sweep of the legal space, ranked by modeled
    us_per_token; ties break toward the default geometry, then toward
    smaller blocks (cheaper VMEM), keeping results deterministic."""
    default = TunedConfig()
    fp = model_fingerprint(cfg, max_len, paged)
    scored: List[Tuple[TunedConfig, Cost]] = []
    census: dict = {}
    n_all = n_pruned = 0
    for cand in enumerate_candidates(max_len=max_len,
                                     head_dim=cfg.resolved_head_dim,
                                     paged=paged):
        n_all += 1
        c = candidate_cost(cand, cfg, profile, max_len=max_len, paged=paged)
        if c.pruned is not None:
            n_pruned += 1
            rule = c.pruned.split(" ", 1)[0]
            census[rule] = census.get(rule, 0) + 1
            continue
        scored.append((cand, c))
    if not scored:
        raise ValueError(
            f"design space empty for {cfg.name} on {profile.name}: "
            f"{n_pruned}/{n_all} pruned ({census})")
    scored.sort(key=lambda t: (t[1].us_per_token, t[0] != default,
                               t[0].geometry_key()))
    top = scored[:top_k]
    if measure is not None:
        top = sorted(top, key=lambda t: measure(t[0]))
    best, best_cost = top[0]
    return TuneReport(
        best=best, best_cost=best_cost,
        default_cost=candidate_cost(default, cfg, profile,
                                    max_len=max_len, paged=paged),
        device_class=profile.name, model_fp=fp,
        n_candidates=n_all, n_pruned=n_pruned, prune_census=census,
        table=scored[:top_k])


def resolve_tuned(cache, cfg: ModelConfig, speed: float, *, max_len: int,
                  paged: bool) -> TunedConfig:
    """Cached winner for (model fingerprint, device class), tuning once
    on first use. ``cache`` is a ``ProgramCache`` (its tuned-config side
    store); safe under concurrent callers — worst case both tune and one
    result (identical — the sweep is deterministic) is stored twice."""
    cls = device_class(speed)
    fp = model_fingerprint(cfg, max_len, paged)
    rec = cache.get_tuned(fp, cls)
    if rec is not None:
        return TunedConfig.from_dict(rec)
    report = tune(cfg, profile_for_speed(speed, cls),
                  max_len=max_len, paged=paged)
    cache.put_tuned(fp, cls, report.best.to_dict())
    return report.best
