"""The tunable design space: one frozen ``TunedConfig`` per candidate.

Knobs cover every geometry decision the dataplane makes:

  decode_block_k          Pallas decode-attention cache-sweep block
  flash_block_q/_k        Pallas flash-attention prefill tiles
  mm_block_m/_n/_k        Pallas stream-matmul tiles
  page_size               KV pool page length (paged serving)
  n_slots                 decode slots per device
  prefill_chunk           async-loop prefill chunk (requests per slice)

``enumerate_candidates`` yields every combination that passes the
kernels' own divisibility rules (``repro.kernels.registry``); resource
fits are the cost model's job.
"""
from __future__ import annotations

import itertools
from dataclasses import asdict, dataclass, replace
from typing import Iterator, Optional

from repro.kernels import registry as kreg


@dataclass(frozen=True)
class TunedConfig:
    decode_block_k: int = kreg.DECODE_BLOCK_DEFAULT
    flash_block_q: int = kreg.FLASH_BLOCK_DEFAULT
    flash_block_k: int = kreg.FLASH_BLOCK_DEFAULT
    mm_block_m: int = kreg.MM_BLOCK_DEFAULT
    mm_block_n: int = kreg.MM_BLOCK_DEFAULT
    mm_block_k: int = kreg.MM_BLOCK_DEFAULT
    page_size: int = kreg.PAGE_SIZE_DEFAULT
    n_slots: int = kreg.SLOTS_DEFAULT
    prefill_chunk: int = kreg.PREFILL_CHUNK_DEFAULT

    def geometry_key(self) -> str:
        """Compact stable string — becomes part of the ProgramCache key and
        the program descriptor, so tuned/default programs never collide."""
        return (f"dk{self.decode_block_k}"
                f".fq{self.flash_block_q}.fk{self.flash_block_k}"
                f".mm{self.mm_block_m}x{self.mm_block_n}x{self.mm_block_k}"
                f".ps{self.page_size}.s{self.n_slots}.pc{self.prefill_chunk}")

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TunedConfig":
        return cls(**{k: int(v) for k, v in d.items()
                      if k in cls.__dataclass_fields__})

    def replace(self, **kw) -> "TunedConfig":
        return replace(self, **kw)


DEFAULT = TunedConfig()


def legal_reason(cand: TunedConfig, *, max_len: int, head_dim: int,
                 paged: bool) -> Optional[str]:
    """Divisibility legality (mirrors the kernels' own asserts). Returns
    None when legal, else the first violated rule."""
    r = kreg.check_decode_block(max_len, cand.decode_block_k)
    if r is None:
        r = kreg.check_flash_blocks(max_len, cand.flash_block_q,
                                    cand.flash_block_k)
    if r is None and paged:
        r = kreg.check_page_size(max_len, cand.page_size)
        if r is None and cand.decode_block_k % cand.page_size != 0 \
                and cand.page_size % cand.decode_block_k != 0:
            r = (f"decode block_k={cand.decode_block_k} and "
                 f"page_size={cand.page_size} do not nest")
    if r is None:
        r = kreg.check_head_alignment(head_dim)
    if r is None and max_len % cand.n_slots != 0 and cand.n_slots > max_len:
        r = f"n_slots={cand.n_slots} > max_len={max_len}"
    return r


def enumerate_candidates(*, max_len: int, head_dim: int,
                         paged: bool) -> Iterator[TunedConfig]:
    """Every divisibility-legal combination. Matmul tiles sweep a square
    subset (bm=bn=bk) — rectangular tiles add little on the MXU and cube
    the space."""
    page_sizes = kreg.PAGE_SIZE_CHOICES if paged \
        else (kreg.PAGE_SIZE_DEFAULT,)
    for (dk, fq, fk, mm, ps, ns, pc) in itertools.product(
            kreg.DECODE_BLOCK_CHOICES, kreg.FLASH_BLOCK_CHOICES,
            kreg.FLASH_BLOCK_CHOICES, kreg.MM_BLOCK_CHOICES,
            page_sizes, kreg.SLOTS_CHOICES, kreg.PREFILL_CHUNK_CHOICES):
        cand = TunedConfig(
            decode_block_k=dk, flash_block_q=fq, flash_block_k=fk,
            mm_block_m=mm, mm_block_n=mm, mm_block_k=mm,
            page_size=ps, n_slots=ns, prefill_chunk=pc)
        if legal_reason(cand, max_len=max_len, head_dim=head_dim,
                        paged=paged) is None:
            yield cand
