"""Roofline-backed analytical cost model for geometry candidates.

Grows the discipline of ``benchmarks/roofline.py`` (bytes-moved vs flops
vs the hardware ceilings, `launch/mesh.py` constants) into a per-candidate
score the design-space explorer can rank on, entirely offline:

  stream term      max(bytes moved / HBM bandwidth, flops / peak) — the
                   classic roofline bound for the decode step
  overhead term    fixed host/scalar-core cost per Pallas grid step —
                   shrinks as blocks grow (fewer steps)
  fill term        pipeline fill/imbalance cost of one block per grid row
                   (the first DMA is not overlapped) — grows with block
                   size, so the optimum tile is finite and scales with
                   device speed (fast class => bigger tiles)
  fragmentation    paged pools round each context up to whole pages:
                   bigger pages waste bandwidth, fewer pages cost more
                   grid steps — the page-size optimum is class-dependent
  slot term        parameters stream once per step regardless of batch,
                   so more slots amortize them; KV bytes stay per-slot
  chunk term       async prefill chunking: big chunks stall decode,
                   small chunks delay admission (convex in the chunk)

Hard constraints prune before scoring: VMEM fit of every kernel's
working set, HBM fit of params + KV pool, and the kernels' divisibility
rules. All pure math — no tracing, no device, deterministic across
hosts — so the benchmark JSON diffs cleanly in CI.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, MIXER_SHARED_ATTN,
                                ModelConfig)
from repro.kernels import registry as kreg
from repro.tuning.space import TunedConfig, legal_reason

# TPU v5e-class ceilings (launch/mesh.py) — scaled by device speed below.
PEAK_FLOPS = 197e12                # FLOP/s, bf16
HBM_BW = 819e9                     # bytes/s
HBM_CAP = 16 * 1024 ** 3           # bytes
HOST_OVERHEAD_S = 1e-7             # per Pallas grid step (host issue, fixed)
SLOT_HOST_S = 2e-6                 # per-slot host work per step (sampling &c)

_ATTN_KINDS = (ATTN_GLOBAL, ATTN_LOCAL, MIXER_SHARED_ATTN)


@dataclass(frozen=True)
class DeviceProfile:
    """What a device class looks like to the tuner. ``speed`` matches
    ``PhysicalDevice.speed`` (ClusterSpec.device_speeds); sub-half-speed
    classes are cut-down parts with half the VMEM and HBM."""
    name: str
    speed: float
    flops: float
    hbm_bw: float
    vmem_bytes: int
    hbm_bytes: int
    host_overhead_s: float = HOST_OVERHEAD_S


def profile_for_speed(speed: float, name: str = "") -> DeviceProfile:
    s = max(float(speed), 1e-6)
    small = s < 0.5
    return DeviceProfile(
        name=name or f"c{s:.2f}x",
        speed=s,
        flops=PEAK_FLOPS * s,
        hbm_bw=HBM_BW * s,
        vmem_bytes=kreg.VMEM_BYTES // (2 if small else 1),
        hbm_bytes=HBM_CAP // (2 if small else 1))


@dataclass
class Cost:
    """Modeled serving cost of one candidate on one device class."""
    step_s: float                  # one decode step at the candidate's slots
    us_per_token: float            # amortized service time per decoded token
    pruned: Optional[str] = None   # non-None => candidate violates a hard fit
    terms: Dict[str, float] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# Model byte/flop accounting
# ---------------------------------------------------------------------------

def _attn_layers(cfg: ModelConfig) -> int:
    return sum(1 for k in cfg.layer_kinds() if k in _ATTN_KINDS)


def kv_bytes_per_pos(cfg: ModelConfig) -> float:
    """KV-cache bytes per cached position, summed over attention layers."""
    if cfg.mla is not None:
        per = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) \
            * kreg.dtype_bytes(cfg.dtype)
    else:
        per = 2 * cfg.n_kv_heads * cfg.resolved_head_dim
        if cfg.kv_quant:
            per = per * 1 + 2 * cfg.n_kv_heads * 4   # int8 + fp32 row scales
        else:
            per *= kreg.dtype_bytes(cfg.dtype)
    return float(per * _attn_layers(cfg))


def _param_bytes(cfg: ModelConfig) -> float:
    return float(cfg.param_count()) * kreg.dtype_bytes(cfg.dtype)


# ---------------------------------------------------------------------------
# Hard-constraint pruning
# ---------------------------------------------------------------------------

def prune_reason(cand: TunedConfig, cfg: ModelConfig, prof: DeviceProfile,
                 *, max_len: int, paged: bool) -> Optional[str]:
    r = legal_reason(cand, max_len=max_len, head_dim=cfg.resolved_head_dim,
                     paged=paged)
    if r is not None:
        return r
    hd = cfg.resolved_head_dim
    vmem = max(
        kreg.decode_vmem_bytes(min(cand.decode_block_k, max_len), hd,
                               "int8" if cfg.kv_quant else cfg.dtype),
        kreg.flash_vmem_bytes(min(cand.flash_block_q, max_len),
                              min(cand.flash_block_k, max_len), hd,
                              cfg.dtype),
        kreg.matmul_vmem_bytes(cand.mm_block_m, cand.mm_block_n,
                               cand.mm_block_k, cfg.dtype))
    if vmem > prof.vmem_bytes:
        return f"VMEM {vmem} > {prof.vmem_bytes}"
    pool_positions = cand.n_slots * max_len
    if paged:
        # whole-page rounding wastes (ps - 1) positions worst-case per slot
        pool_positions += cand.n_slots * (cand.page_size - 1)
    hbm = _param_bytes(cfg) + pool_positions * kv_bytes_per_pos(cfg)
    if hbm > prof.hbm_bytes:
        return f"HBM {hbm / 2 ** 30:.2f}GiB > {prof.hbm_bytes / 2 ** 30:.2f}GiB"
    return None


# ---------------------------------------------------------------------------
# Scoring
# ---------------------------------------------------------------------------

def _tiled_cost(bytes_moved: float, flops: float, grid_steps: float,
                fill_bytes: float, prof: DeviceProfile) -> float:
    stream = max(bytes_moved / prof.hbm_bw, flops / prof.flops)
    return (stream
            + grid_steps * prof.host_overhead_s
            + fill_bytes / prof.hbm_bw)


def candidate_cost(cand: TunedConfig, cfg: ModelConfig, prof: DeviceProfile,
                   *, max_len: int, paged: bool) -> Cost:
    """Score one candidate. Workload assumption (fixed, documented):
    steady-state context = max_len/2, prompts = max_len/4, and each
    request decodes max_len/2 tokens."""
    pr = prune_reason(cand, cfg, prof, max_len=max_len, paged=paged)
    if pr is not None:
        return Cost(step_s=float("inf"), us_per_token=float("inf"), pruned=pr)

    hd, ns = cfg.resolved_head_dim, cand.n_slots
    layers = _attn_layers(cfg)
    kvpp = kv_bytes_per_pos(cfg)
    avg_ctx = max(max_len // 2, 1)
    kvb = 1 if cfg.kv_quant else kreg.dtype_bytes(cfg.dtype)

    # ---- decode step: params once + KV sweep per slot -------------------
    if paged:
        ps = cand.page_size
        pages = -(-avg_ctx // ps)                     # ceil
        swept = pages * ps                            # fragmentation waste
        sweep_steps = ns * cfg.n_heads * pages * layers
        bk_fill = ps
    else:
        bk = min(cand.decode_block_k, max_len)
        swept = max_len                               # dense sweeps full L
        sweep_steps = ns * cfg.n_heads * (max_len // bk) * layers
        bk_fill = bk
    kv_bytes = ns * swept * kvpp
    fill = ns * cfg.n_heads * layers * bk_fill * 2 * hd * kvb
    dec_flops = 2.0 * cfg.param_count() * ns \
        + 4.0 * ns * avg_ctx * cfg.n_heads * hd * layers
    t_dec = _tiled_cost(_param_bytes(cfg) + kv_bytes, dec_flops,
                        sweep_steps, fill, prof) + ns * SLOT_HOST_S

    # ---- prefill (flash + matmul tiles), amortized per decoded token ----
    S = max(max_len // 4, 1)
    bq, fbk = min(cand.flash_block_q, S), min(cand.flash_block_k, S)
    flash_steps = cfg.n_heads * (-(-S // bq)) * (-(-S // fbk)) * layers
    flash_fill = cfg.n_heads * layers * (bq + fbk) * hd \
        * kreg.dtype_bytes(cfg.dtype)
    pf_flops = 2.0 * cfg.param_count() * S \
        + 4.0 * S * S * cfg.n_heads * hd * layers
    bm, bn, mbk = cand.mm_block_m, cand.mm_block_n, cand.mm_block_k
    mm_steps = (-(-S // bm)) * (-(-cfg.d_ff // bn)) \
        * (-(-cfg.d_model // mbk)) * cfg.n_layers * 3
    mm_fill = (bm * mbk + mbk * bn) * kreg.dtype_bytes(cfg.dtype) \
        * cfg.n_layers * 3
    t_prefill = _tiled_cost(
        _param_bytes(cfg) + S * kvpp, pf_flops,
        flash_steps + mm_steps, flash_fill + mm_fill, prof)

    decode_tokens = max(max_len // 2, 1)
    # ---- async prefill chunking: stall vs admission delay (convex) ------
    pc = cand.prefill_chunk
    t_chunk = (pc * t_prefill + t_dec / pc) / decode_tokens

    us_per_token = (t_dec / ns + t_prefill / decode_tokens + t_chunk) * 1e6
    return Cost(
        step_s=t_dec,
        us_per_token=us_per_token,
        terms={
            "decode_us": t_dec * 1e6,
            "prefill_us": t_prefill * 1e6,
            "chunk_us": t_chunk * 1e6,
            "kv_gb_per_step": kv_bytes / 1e9,
            "grid_steps": float(sweep_steps),
        })
