"""repro: RC3E on TPU — a multi-tenant accelerator-cloud hypervisor and
computing framework (vFPGA -> vSlice virtualization) in JAX.

Subpackages:
  core     RC3E hypervisor: device DB, vSlices, service models, scheduler,
           partial reconfiguration, monitoring, elasticity
  rc2f     RC2F dataplane: streaming FIFOs, shell (co-resident user cores),
           config spaces, core API + admission
  models   10 assigned architectures (dense/MoE/SSM/hybrid/enc-dec/VLM)
  runtime  train/serve steps, sharding rules, losses, batching engine
  optim    AdamW + int8-compressed gradient all-reduce
  data     synthetic token pipeline
  ckpt     checkpoint/restore/reshard
  kernels  Pallas TPU kernels (+ refs, interpret-mode validated)
  launch   production meshes, multi-pod dry-run, sweep, train/serve
"""
__version__ = "1.0.0"
