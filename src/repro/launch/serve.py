"""Serving launcher: stands up the multi-tenant serving FLEET for an arch
and runs a synthetic request workload from several tenants through the RC3E
hypervisor — every request is admitted, bound to a vSlice, batched across
tenants on its vSlice's device, and logged by the hypervisor. With
``--devices N`` the fleet runs one engine per physical device and the
DeviceDB's placement decides where each tenant decodes.

Example (CPU-runnable):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduce \
      --requests 12 --devices 2
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import MAX_SLOTS, ClusterSpec, Hypervisor
from repro.models import get_model
from repro.rc2f import AdmissionError
from repro.runtime import GatewayFleet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--devices", type=int, default=0,
                    help="physical devices in the inventory "
                         "(0 = size to the tenant count)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--paged", action="store_true",
                    help="paged KV-cache pool engines (block tables, "
                         "per-tenant page budgets, COW prefix sharing)")
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    cfg = cfg.replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # size the simulated inventory to the tenant count unless --devices set:
    # first tenant gets a 2-slot vSlice, the rest 1 slot each
    total_slots = args.tenants + 1
    n_devices = args.devices or max(1, -(-total_slots // MAX_SLOTS))
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=n_devices))
    fleet = GatewayFleet(hv, model, params, n_slots=args.slots,
                         max_len=args.max_len, paged=args.paged,
                         page_size=args.page_size)
    tenants = [f"tenant-{i}" for i in range(args.tenants)]
    for i, t in enumerate(tenants):
        sess = fleet.open_session(t, slots=2 if i == 0 else 1)
        print(f"{t}: session on {sess.slice_id} "
              f"({sess.slots} slot(s), {fleet.device_of(t)})")
    print(f"{cfg.name} fleet up: {len(fleet._engines)} engine(s) across "
          f"{n_devices} device(s), {args.slots} decode slots each, "
          f"{len(tenants)} tenants")

    def submit_throttled(tenant, prompt):
        """Back-pressure instead of failing when a tenant hits its
        in-flight quota: drive the fleet until the backlog drains."""
        while True:
            try:
                return fleet.submit(tenant, prompt,
                                    max_new_tokens=args.max_new)
            except AdmissionError:
                if fleet.step() == 0:
                    raise       # nothing draining: structurally rejected
    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    reqs = [submit_throttled(tenants[i % len(tenants)],
                             rng.integers(0, cfg.vocab_size,
                                          size=rng.integers(2, 9)).tolist())
            for i in range(args.requests)]
    fleet.run_until_idle()
    wall = time.monotonic() - t0

    total = sum(len(r.out_tokens) for r in reqs)
    lat = [(r.finished_at - r.submitted_at) for r in reqs]
    print(f"\n{len(reqs)} requests, {total} tokens, {wall:.2f}s wall "
          f"({total/wall:.1f} tok/s), median latency "
          f"{np.median(lat)*1e3:.0f} ms")
    if args.paged:
        for dev, fs in sorted(fleet.fleet_stats().items()):
            if "pages" in fs:
                print(f"  {dev} pages: {fs['pages']}")
    for t, s in sorted(fleet.stats().items()):
        print(f"  {t}: {s['served']} served on {s['slice']} "
              f"({s['device']}), {s['tokens_out']} tokens, "
              f"quota {s['quota']}")

    # audit: every request must have been served through a hypervisor vSlice
    serve_events = {e["request"]: e for e in hv.log if e["kind"] == "serve"}
    assert len(serve_events) == len(reqs), \
        f"{len(reqs) - len(serve_events)} requests missing from hv.log"
    assert all(e["slice"].startswith("vs-") for e in serve_events.values())
    print(f"\naudit: all {len(serve_events)} requests logged against "
          f"hypervisor vSlices "
          f"({sorted({e['slice'] for e in serve_events.values()})})")
    fleet.close()


if __name__ == "__main__":
    main()
