"""Serving launcher: stands up the BAaaS service for an arch and runs a
synthetic request workload through the continuous-batching engine.

Example (CPU-runnable):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduce \
      --requests 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import ClusterSpec, Hypervisor
from repro.models import get_model
from repro.runtime import BatchingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    cfg = cfg.replace(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1))
    vs = hv.allocate_vslice(f"svc:{cfg.name}", slots=2, service_model="baas")
    engine = BatchingEngine(model, params, n_slots=args.slots,
                            max_len=args.max_len)
    print(f"{cfg.name} service on {vs.slice_id}, {args.slots} slots")

    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    reqs = [engine.submit(rng.integers(0, cfg.vocab_size,
                                       size=rng.integers(2, 9)).tolist(),
                          max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    engine.run_until_idle()
    wall = time.monotonic() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    lat = [(r.finished_at - r.submitted_at) for r in reqs]
    print(f"{len(reqs)} requests, {total} tokens, {wall:.2f}s wall "
          f"({total/wall:.1f} tok/s), median latency {np.median(lat)*1e3:.0f} ms")
    hv.release(vs.slice_id)


if __name__ == "__main__":
    main()
