"""Production training launcher.

On a real TPU cluster this process runs per host (jax.distributed handles
rendezvous); on this container it drives the same code path over the local
device. The mesh comes from --mesh {host|single|multi}; "single"/"multi"
are the production meshes (dry-run scale) and require the forced-device-
count env (use launch/dryrun.py for compile-only checks there).

Example (CPU-runnable):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --reduce --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.ckpt import restore, save
from repro.configs import SHAPES, get_config, reduced
from repro.data import DataConfig, DataPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.runtime import TrainOpts, init_train_state, make_train_step
from repro.runtime.sharding import (batch_specs, named, param_specs,
                                    zero1_specs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true",
                    help="width-reduced config for CPU runs")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--data", type=int, default=1, help="mesh data axis")
    ap.add_argument("--model", type=int, default=1, help="mesh model axis")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    cfg = cfg.replace(dtype="float32")
    model = get_model(cfg)
    mesh = make_host_mesh(args.data, args.model)
    print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M params) on "
          f"mesh {dict(mesh.shape)}")

    opts = TrainOpts(opt=AdamWConfig(lr=args.lr, warmup_steps=10,
                                     total_steps=args.steps),
                     microbatches=args.microbatches, remat=args.remat,
                     loss_chunk=min(64, args.seq))
    state = init_train_state(model, jax.random.PRNGKey(0), opts)
    start = 0
    if args.ckpt_dir:
        try:
            state, start = restore(args.ckpt_dir, jax.eval_shape(lambda: state))
            print(f"resumed from step {start}")
        except FileNotFoundError:
            pass

    state_shape = jax.eval_shape(lambda: state)
    pspecs = param_specs(cfg, state_shape["params"], mesh)
    step = jax.jit(make_train_step(model, opts))

    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                   batch_size=args.batch))
    t0 = time.time()
    with mesh:
        for i in range(start, args.steps):
            state, metrics = step(state, data.batch_at(i))
            if (i + 1) % 10 == 0:
                print(f"step {i+1:4d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.2f}")
            if args.ckpt_dir and (i + 1) % 25 == 0:
                save(state, args.ckpt_dir, step=i + 1, keep=2)
    dt = time.time() - t0
    toks = args.batch * args.seq * (args.steps - start)
    print(f"done: {toks/dt:,.0f} tok/s")


if __name__ == "__main__":
    main()
