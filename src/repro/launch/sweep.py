"""Dry-run sweep driver: every (arch × shape × mesh) cell in a subprocess
(each needs a fresh XLA with 512 host devices), results as JSON into
results/dryrun/, plus a markdown summary for EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.sweep             # all cells
  PYTHONPATH=src python -m repro.launch.sweep --mesh single --arch gemma3-1b
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import SHAPES
from repro.configs.registry import ARCH_IDS

RESULTS_DIR = os.environ.get("SWEEP_RESULTS_DIR", "results/dryrun")


def cell_path(arch: str, shape: str, mesh: str) -> str:
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh}.json")


def run_one(arch: str, shape: str, mesh: str, timeout: int = 3000,
            force: bool = False) -> dict:
    out = cell_path(arch, shape, mesh)
    if os.path.exists(out) and not force:
        with open(out) as f:
            return json.load(f)
    env = dict(os.environ)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--json", out]
    t0 = time.time()
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout, env=env)
    if proc.returncode != 0:
        err = {"arch": arch, "shape": shape, "mesh": mesh,
               "error": proc.stderr[-2000:], "wall_s": time.time() - t0}
        os.makedirs(RESULTS_DIR, exist_ok=True)
        with open(out, "w") as f:
            json.dump(err, f, indent=1)
        return err
    with open(out) as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=(None, "single", "multi"))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    os.makedirs(RESULTS_DIR, exist_ok=True)
    n_total = len(archs) * len(shapes) * len(meshes)
    i = 0
    for mesh in meshes:
        for arch in archs:
            for shape in shapes:
                i += 1
                t0 = time.time()
                res = run_one(arch, shape, mesh, force=args.force)
                dt = time.time() - t0
                status = ("SKIP " + res.get("skipped", "")[:40]
                          if "skipped" in res else
                          "ERROR" if "error" in res else
                          f"ok fits={res['memory']['fits_16GB']} "
                          f"dom={res['roofline']['dominant']}")
                print(f"[{i}/{n_total}] {arch} {shape} {mesh}: {status} "
                      f"({dt:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
