"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun/*.json.

Usage: PYTHONPATH=src python -m repro.launch.report [--out EXPERIMENTS.md]
(writes markdown fragments to results/report_*.md for manual assembly, or
prints to stdout)
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS_DIR = "results/dryrun"
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["gemma3-1b", "gemma2-9b", "phi3-mini-3.8b", "smollm-135m",
              "mamba2-370m", "deepseek-v2-lite-16b", "qwen3-moe-30b-a3b",
              "zamba2-7b", "whisper-tiny", "llava-next-34b"]


def load():
    cells = {}
    for p in glob.glob(os.path.join(RESULTS_DIR, "*.json")):
        with open(p) as f:
            r = json.load(f)
        mesh = r.get("mesh", "single" if "__single" in p else "multi")
        mesh = "single" if "16x16" == mesh.replace("pod", "") or \
            p.endswith("__single.json") else "multi"
        cells[(r.get("arch"), r.get("shape"), mesh)] = r
    return cells


def _gb(x):
    return f"{x / 2**30:.2f}"


def dryrun_table(cells, mesh: str) -> str:
    lines = [
        f"### Mesh: {'16×16 (256 chips)' if mesh == 'single' else '2×16×16 (512 chips)'}",
        "",
        "| arch | shape | compile | per-dev GiB (proj. TPU) | fits 16GB | "
        "HLO GFLOPs/dev | dot GiB/dev | coll. wire GiB/dev | top collective |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = cells.get((arch, shape, mesh))
            if r is None:
                continue
            if "skipped" in r:
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | — | "
                             f"skipped: {r['skipped'][:45]} |")
                continue
            if "error" in r:
                lines.append(f"| {arch} | {shape} | ERROR | | | | | | "
                             f"{r['error'][:40]} |")
                continue
            pd = r["per_device"]
            colls = pd.get("collective_breakdown", {})
            top = max(colls, key=colls.get) if colls else "-"
            lines.append(
                f"| {arch} | {shape} | {r['compile_s']:.0f}s "
                f"| {_gb(r['memory']['projected_tpu_bytes'])} "
                f"| {'✓' if r['memory']['fits_16GB'] else '✗'} "
                f"| {pd['flops'] / 1e9:,.0f} "
                f"| {_gb(pd['dot_bytes'])} "
                f"| {_gb(pd['collective_wire_bytes'])} "
                f"| {top} |")
    return "\n".join(lines)


def roofline_table(cells, mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute s | memory s | mem s (flash kernel) | "
        "collective s | dominant | MODEL_FLOPS | useful ratio | "
        "roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    levers = {
        "memory": "cut HBM traffic of the dominant dots (flash-attention "
                  "kernel / fusion)",
        "collective": "reshard to cut the top collective (overlap or axis "
                      "change)",
        "compute": "raise MXU utilization (already compute-limited)",
    }
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = cells.get((arch, shape, mesh))
            if r is None or "skipped" in r or "error" in r:
                continue
            rl = r["roofline"]
            lines.append(
                f"| {arch} | {shape} "
                f"| {rl['compute_s']:.3g} | {rl['memory_s']:.3g} "
                f"| {rl.get('memory_s_flash_kernel', rl['memory_s']):.3g} "
                f"| {rl['collective_s']:.3g} | **{rl['dominant']}** "
                f"| {rl['model_flops_global']:.3g} "
                f"| {rl['useful_flops_ratio']:.3f} "
                f"| {rl['roofline_fraction']:.3f} "
                f"| {levers[rl['dominant']]} |")
    return "\n".join(lines)


def summary(cells) -> str:
    n_ok = sum(1 for r in cells.values()
               if "skipped" not in r and "error" not in r)
    n_fit = sum(1 for r in cells.values()
                if "memory" in r and r["memory"]["fits_16GB"])
    n_skip = sum(1 for r in cells.values() if "skipped" in r)
    n_err = sum(1 for r in cells.values() if "error" in r)
    worst = min((r for r in cells.values() if "roofline" in r),
                key=lambda r: r["roofline"]["roofline_fraction"])
    most_coll = max((r for r in cells.values() if "roofline" in r),
                    key=lambda r: r["roofline"]["collective_s"])
    return (f"- compiled cells: **{n_ok}** (all lower+compile on the "
            f"production meshes), fits-16GB: **{n_fit}/{n_ok}**, documented "
            f"skips: {n_skip}, errors: {n_err}\n"
            f"- worst roofline fraction: {worst['arch']} {worst['shape']} "
            f"{worst['mesh']} ({worst['roofline']['roofline_fraction']:.3f})\n"
            f"- most collective-bound: {most_coll['arch']} "
            f"{most_coll['shape']} {most_coll['mesh']} "
            f"({most_coll['roofline']['collective_s']:.2f}s wire time)")


def load_dir(d):
    cells = {}
    for p in glob.glob(os.path.join(d, "*.json")):
        with open(p) as f:
            r = json.load(f)
        mesh = "single" if p.endswith("__single.json") else "multi"
        cells[(r.get("arch"), r.get("shape"), mesh)] = r
    return cells


def optimized_table(base, opt) -> str:
    lines = [
        "| arch | shape | mesh | frac before | frac after | coll s before | "
        "coll s after | dominant after | scheme |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    schemes = {
        "smollm-135m": "pure-DP ×256", "whisper-tiny": "pure-DP ×256",
        "mamba2-370m": "pure-DP ×256",
        "gemma3-1b": "SP + seq-attn TP", "gemma2-9b": "SP + seq-attn TP",
        "llava-next-34b": "SP + seq-attn TP",
        "qwen3-moe-30b-a3b": "SP + seq-attn TP + EP",
        "phi3-mini-3.8b": "SP (heads TP)",
        "deepseek-v2-lite-16b": "SP + EP (MLA heads TP)",
        "zamba2-7b": "SP + SSM head TP",
    }
    for key in sorted(opt):
        o = opt[key]
        if "roofline" not in o:
            continue
        b = base.get(key)
        if b is None or "roofline" not in b:
            continue
        arch, shape, mesh = key
        lines.append(
            f"| {arch} | {shape} | {mesh} "
            f"| {b['roofline']['roofline_fraction']:.4f} "
            f"| **{o['roofline']['roofline_fraction']:.4f}** "
            f"| {b['roofline']['collective_s']:.2f} "
            f"| {o['roofline']['collective_s']:.3f} "
            f"| {o['roofline']['dominant']} "
            f"| {schemes.get(arch, '')} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    cells = load()
    parts = [
        "## §Dry-run\n", summary(cells), "\n",
        dryrun_table(cells, "single"), "\n",
        dryrun_table(cells, "multi"), "\n",
        "## §Roofline (single-pod 16×16, per §ROOFLINE formulas)\n",
        roofline_table(cells, "single"),
    ]
    opt = load_dir("results/optimized")
    if opt:
        parts += ["\n## §Optimized (post-hillclimb schemes, baseline vs "
                  "final)\n", optimized_table(cells, opt)]
    text = "\n".join(parts)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    else:
        print(text)


if __name__ == "__main__":
    main()
