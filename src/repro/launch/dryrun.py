import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, prove memory fits, and extract roofline terms.

MUST be run as its own process (`python -m repro.launch.dryrun ...`) so the
XLA_FLAGS above take effect before jax initializes.

Per cell this prints/saves:
  - compiled.memory_analysis()  (per-device bytes: proof it fits)
  - compiled.cost_analysis()    (XLA's aggregate — loop-UNDERCOUNTED, kept
                                 for reference)
  - loop-corrected per-device flops / dot-bytes / collective wire bytes from
    repro.launch.hlo_analysis
  - three-term roofline + dominant bottleneck + MODEL_FLOPS ratio
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LONG_CONTEXT_ARCHS, SHAPES, get_config
from repro.configs.base import ShapeCell
from repro.launch.hlo_analysis import analyze_hlo, cpu_dus_legalization_bytes
from repro.launch.mesh import (HBM_BYTES_S, ICI_BYTES_S, PEAK_FLOPS_BF16,
                               chips, make_production_mesh)
from repro.models.api import (WHISPER_DEC_LEN, get_model, input_specs)
from repro.optim.adamw import AdamWConfig
from repro.runtime.serve import jit_serve_step
from repro.runtime.sharding import (batch_specs, named, param_specs,
                                    zero1_specs)
from repro.runtime.train import TrainOpts, init_train_state, make_train_step

# Cells skipped with a documented reason (DESIGN.md §4)
SKIPS = {
    ("long_500k", arch): "full-attention cache at 500k infeasible by design"
    for arch in ("phi3-mini-3.8b", "smollm-135m", "deepseek-v2-lite-16b",
                 "qwen3-moe-30b-a3b", "llava-next-34b", "whisper-tiny")
}


def dryrun_cfg(arch: str, dp_total: int = 16, tp: int = 16,
               cell_kind: str = "train"):
    """Dry-run flavor: bf16 params+compute (production numerics); MoE
    dispatch made local to the mesh's data-parallel extent; attention TP
    switches to query-seq sharding on train cells when kv heads don't
    divide the model axis (the score einsum would otherwise replicate)."""
    cfg = get_config(arch).replace(dtype="bfloat16", param_dtype="bfloat16")
    if cfg.moe is not None:
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  dp_shards=dp_total))
    # sub-GB models: the whole mesh is better used as pure DP (weights
    # replicated, one grad all-reduce) than as 16-way TP of tiny matmuls
    if cell_kind == "train" and cfg.param_count() * 2 <= 800e6:
        return cfg.replace(tp_mode="pure_dp", attn_tp="none")
    # NOTE: tp_mode="fsdp" exists but is NOT the default — measured on
    # gemma2/llava/zamba2, GSPMD re-gathers the full scan-stacked weights
    # every layer iteration (283-673 s of wire vs 9.6-29 s for Megatron-SP).
    # Proper ZeRO-3 needs per-layer gather scheduling that scan+GSPMD does
    # not express; recorded as a refuted hypothesis in EXPERIMENTS.md §Perf.
    if (cell_kind == "train" and cfg.mla is None
            and cfg.n_kv_heads % tp != 0):
        cfg = cfg.replace(attn_tp="seq")
    # int8 KV cache for decode cells (optimized variant; RC3E_KV_QUANT=1)
    if (cell_kind == "decode" and cfg.mla is None
            and os.environ.get("RC3E_KV_QUANT") == "1"):
        cfg = cfg.replace(kv_quant=True)
    return cfg


def _train_lowerable(model, mesh, cell: ShapeCell):
    cfg = model.cfg
    opts = TrainOpts(remat=True, loss_chunk=512)
    state_shape = jax.eval_shape(
        lambda: init_train_state(model, jax.random.key(0), opts))
    batch_shape = input_specs(cfg, cell)
    pspecs = param_specs(cfg, state_shape["params"], mesh)
    ospecs = zero1_specs(cfg, pspecs, state_shape["params"], mesh)
    state_specs = {
        "params": pspecs,
        "opt_state": {"mu": ospecs, "nu": ospecs,
                      "count": jax.sharding.PartitionSpec()},
        "step": jax.sharding.PartitionSpec(),
    }
    bspecs = batch_specs(cfg, batch_shape, mesh)
    step = make_train_step(model, opts, grad_specs=ospecs)
    jitted = jax.jit(step,
                     in_shardings=(named(mesh, state_specs),
                                   named(mesh, bspecs)),
                     donate_argnums=(0,))
    return jitted, (state_shape, batch_shape)


def _prefill_lowerable(model, mesh, cell: ShapeCell):
    from repro.runtime.sharding import cache_specs
    cfg = model.cfg
    params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    batch_shape = input_specs(cfg, cell)
    pspecs = param_specs(cfg, params_shape, mesh)
    bspecs = batch_specs(cfg, batch_shape, mesh)

    def prefill_step(params, batch):
        return model.prefill(params, batch, cell.seq_len)

    # pin the produced caches to the decode-cell sharding (otherwise XLA
    # may leave multi-GB caches replicated across the model axis)
    cshape = jax.eval_shape(
        lambda: model.make_caches(cell.global_batch, cell.seq_len))
    cspecs = cache_specs(cfg, cshape, mesh, cell.global_batch)
    dp = None
    h_spec = jax.sharding.PartitionSpec()
    from repro.runtime.sharding import dp_axes
    dp = dp_axes(mesh)
    if cell.global_batch % (chips(mesh) // mesh.shape["model"]) == 0:
        h_spec = jax.sharding.PartitionSpec(dp, None, None)
    jitted = jax.jit(prefill_step,
                     in_shardings=(named(mesh, pspecs),
                                   named(mesh, bspecs)),
                     out_shardings=(
                         jax.sharding.NamedSharding(mesh, h_spec),
                         named(mesh, cspecs)))
    return jitted, (params_shape, batch_shape)


def _decode_lowerable(model, mesh, cell: ShapeCell):
    cfg = model.cfg
    params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    specs = input_specs(cfg, cell)
    jitted, _ = jit_serve_step(model, mesh, cell.global_batch, cell.seq_len,
                               params_shape, specs["caches"])
    return jitted, (params_shape, specs["caches"], specs["tokens"],
                    specs["pos"])


def model_flops(cfg, cell: ShapeCell) -> float:
    """6·N_active·D for train, 2·N_active·D forward-only."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch        # one token per sequence


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             keep_hlo: bool = False) -> dict:
    cell = SHAPES[shape]
    reason = SKIPS.get((shape, arch))
    if reason:
        return {"arch": arch, "shape": shape, "skipped": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = chips(mesh)
    dp_total = n_chips // mesh.shape["model"]
    cfg = dryrun_cfg(arch, dp_total=dp_total, tp=mesh.shape["model"],
                     cell_kind=cell.kind)
    model = get_model(cfg)

    t0 = time.time()
    if cell.kind == "train":
        jitted, args = _train_lowerable(model, mesh, cell)
    elif cell.kind == "prefill":
        jitted, args = _prefill_lowerable(model, mesh, cell)
    else:
        jitted, args = _decode_lowerable(model, mesh, cell)

    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    costs = analyze_hlo(hlo, n_chips)

    arg_b = getattr(ma, "argument_size_in_bytes", 0)
    out_b = getattr(ma, "output_size_in_bytes", 0)
    tmp_b = getattr(ma, "temp_size_in_bytes", 0)
    alias_b = getattr(ma, "alias_size_in_bytes", 0)
    peak_b = arg_b + out_b + tmp_b - alias_b
    # XLA-CPU legalizes bf16 dynamic-update-slice through f32 copies of the
    # whole residual stack (TPU has native bf16 DUS) — project those out.
    legal_b = cpu_dus_legalization_bytes(hlo)
    # detected stacks may share one allocation across sequential loops, so
    # bound the correction: never project below arguments+outputs
    tpu_peak_b = max(arg_b + out_b, peak_b - legal_b)

    t_compute = costs.flops / PEAK_FLOPS_BF16
    t_memory = costs.dot_bytes / HBM_BYTES_S
    # with the Pallas flash-attention kernel, score/prob matrices stay in
    # VMEM — subtract their HBM traffic (kernel validated in tests/)
    t_memory_flash = (costs.dot_bytes - costs.score_bytes) / HBM_BYTES_S
    t_coll = costs.collective_bytes / ICI_BYTES_S
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, cell)
    hlo_flops_global = costs.flops * n_chips

    result = {
        "arch": arch, "shape": shape,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "per_device_bytes": int(peak_b),
            "arguments": int(arg_b), "outputs": int(out_b),
            "temps": int(tmp_b), "aliased": int(alias_b),
            "cpu_dus_legalization_bytes": int(legal_b),
            "projected_tpu_bytes": int(tpu_peak_b),
            "fits_16GB": bool(tpu_peak_b < 16e9),
        },
        "xla_cost_analysis": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "note": "loop bodies counted once (verified undercount)",
        },
        "per_device": {
            "flops": costs.flops,
            "dot_bytes": costs.dot_bytes,
            "collective_wire_bytes": costs.collective_bytes,
            "collective_breakdown": dict(costs.collectives),
            "collective_ops": costs.collective_count,
        },
        "roofline": {
            "compute_s": t_compute, "memory_s": t_memory,
            "memory_s_flash_kernel": t_memory_flash,
            "score_bytes": costs.score_bytes,
            "collective_s": t_coll, "dominant": dominant,
            "model_flops_global": mf,
            "hlo_flops_global": hlo_flops_global,
            "useful_flops_ratio": mf / hlo_flops_global
            if hlo_flops_global else 0.0,
            "step_time_bound_s": max(terms.values()),
            "roofline_fraction": t_compute / max(terms.values())
            if max(terms.values()) > 0 else 0.0,
        },
    }
    if keep_hlo:
        result["hlo_path"] = _save_hlo(arch, shape, result["mesh"], hlo)
    return result


def _save_hlo(arch, shape, mesh_name, hlo) -> str:
    d = os.path.join("results", "hlo")
    os.makedirs(d, exist_ok=True)
    p = os.path.join(d, f"{arch}_{shape}_{mesh_name}.hlo.txt")
    with open(p, "w") as f:
        f.write(hlo)
    return p


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--json", default=None, help="write result JSON here")
    ap.add_argument("--keep-hlo", action="store_true")
    args = ap.parse_args()

    res = run_cell(args.arch, args.shape, multi_pod=(args.mesh == "multi"),
                   keep_hlo=args.keep_hlo)
    text = json.dumps(res, indent=1)
    print(text)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            f.write(text)


if __name__ == "__main__":
    main()
