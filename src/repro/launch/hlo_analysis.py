"""Loop-aware HLO cost analyzer.

XLA's built-in ``compiled.cost_analysis()`` counts each while-loop body ONCE
(verified empirically: a 10-iteration scanned matmul reports 1 matmul of
FLOPs), which would undercount every scanned layer stack by its depth. This
analyzer parses the post-SPMD HLO text (``compiled.as_text()`` — per-device
shapes), walks the computation graph through fusions / calls / whiles /
conditionals, multiplies by parsed while trip counts, and reports:

  flops             — dot + convolution FLOPs, loop-corrected, per device
  dot_bytes         — Σ operand+result bytes of dots (un-fused upper bound
                      on HBM traffic of the matmul-shaped working set)
  collective_bytes  — per-device *wire* bytes under ring algorithms:
                        all-reduce        2·B·(n-1)/n
                        all-gather        O·(n-1)/n   (O = gathered output)
                        reduce-scatter    o·(n-1)     (o = scattered output)
                        all-to-all        B·(n-1)/n
                        collective-permute B
  per-op collective breakdown for the bottleneck report.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->", re.M)
_CALL_ATTRS = ("calls=", "to_apply=", "body=", "condition=",
               "branch_computations=", "true_computation=",
               "false_computation=")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> Tuple[int, int]:
    """Returns (elements, bytes)."""
    if dims.strip() == "":
        n = 1
    else:
        n = 1
        for d in dims.split(","):
            n *= int(d)
    return n, n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    dot_bytes: float = 0.0
    collective_bytes: float = 0.0
    score_bytes: float = 0.0   # traffic of attention-score-shaped tensors —
                               # what a flash-attention kernel keeps in VMEM
    collectives: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_count: int = 0

    def scaled(self, k: float) -> "Costs":
        c = Costs(self.flops * k, self.dot_bytes * k,
                  self.collective_bytes * k, self.score_bytes * k)
        c.collectives = defaultdict(
            float, {op: v * k for op, v in self.collectives.items()})
        c.collective_count = int(self.collective_count * k)
        return c

    def add(self, o: "Costs"):
        self.flops += o.flops
        self.dot_bytes += o.dot_bytes
        self.collective_bytes += o.collective_bytes
        self.score_bytes += o.score_bytes
        for op, v in o.collectives.items():
            self.collectives[op] += v
        self.collective_count += o.collective_count


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> list of body lines."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = _COMP_HDR_RE.match(line) if (line and not line[0].isspace()) else None
        if m and stripped.endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _result_shapes(line: str) -> List[Tuple[str, str]]:
    """dtype/dims pairs of the op's result type (left of the opcode)."""
    eq = line.find(" = ")
    if eq < 0:
        return []
    rest = line[eq + 3:]
    # result type runs until the opcode token; grab shapes up to the first '('
    paren = rest.find("(")
    # tuple results start with '(' immediately: '(f32[..], ..) op(..)'
    if rest.startswith("("):
        close = rest.find(")")
        seg = rest[: close + 1]
    else:
        seg = rest[:paren] if paren > 0 else rest
    return _SHAPE_RE.findall(seg)


def _operand_segment(line: str) -> str:
    """Text inside the op's argument parens."""
    eq = line.find(" = ")
    rest = line[eq + 3:]
    start = rest.find("(")
    if rest.startswith("("):                      # tuple result; find op parens
        start = rest.find("(", rest.find(")") + 1)
    if start < 0:
        return ""
    depth = 0
    for i in range(start, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                return rest[start:i + 1]
    return rest[start:]


def _operand_shapes(line: str, symtab: Dict[str, List[Tuple[str, str]]]
                    ) -> List[Tuple[str, str]]:
    """Operand shapes: inline if present, else looked up from the symbol
    table (scheduled HLO prints operands as bare %names)."""
    seg = _operand_segment(line)
    inline = _SHAPE_RE.findall(seg)
    if inline:
        return inline
    out = []
    for name in re.findall(r"%([\w.\-]+)", seg):
        shapes = symtab.get(name)
        if shapes:
            out.extend(shapes)
    return out


def _def_name(line: str) -> Optional[str]:
    m = re.match(r"(?:ROOT\s+)?%([\w.\-]+)\s+=", line)
    return m.group(1) if m else None


def build_symtab(lines: List[str]) -> Dict[str, List[Tuple[str, str]]]:
    tab: Dict[str, List[Tuple[str, str]]] = {}
    for line in lines:
        name = _def_name(line)
        if name:
            tab[name] = _result_shapes(line)
    return tab


def _opcode(line: str) -> Optional[str]:
    eq = line.find(" = ")
    if eq < 0:
        return None
    rest = line[eq + 3:]
    if rest.startswith("("):                      # tuple result type
        rest = rest[rest.find(")") + 1:].strip()
    m = re.match(r"(?:[a-z0-9]+\[[0-9,]*\]\S*\s+)?([\w\-]+)\(", rest)
    return m.group(1) if m else None


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return max(total_devices, 1)


def _dot_flops(line: str, symtab) -> Tuple[float, float, float]:
    """(flops, operand+result bytes, score-shaped bytes) for a dot line.

    Score-shaped = an attention (…, q, S) matrix that dwarfs the dot's
    other tensors: the traffic a flash kernel never sends to HBM. Detected
    as result ≥2× both operands (score-producing dot) or lhs ≥2× the rest
    (probs×V dot), rank ≥ 3.
    """
    res = _result_shapes(line)
    ops = _operand_shapes(line, symtab)
    if not res or len(ops) < 2:
        return 0.0, 0.0, 0.0
    out_elems, out_bytes = _shape_bytes(*res[0])
    lhs_dims = [int(d) for d in ops[0][1].split(",") if d]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    contract = 1
    if m and m.group(1):
        for ci in m.group(1).split(","):
            if int(ci) < len(lhs_dims):
                contract *= lhs_dims[int(ci)]
    lhs_b = _shape_bytes(*ops[0])[1]
    rhs_b = _shape_bytes(*ops[1])[1] if len(ops) > 1 else 0
    score_b = 0.0
    if len([d for d in res[0][1].split(",") if d]) >= 3 \
            and out_bytes >= 2 * (lhs_b + rhs_b) and out_bytes >= 1 << 24:
        score_b += out_bytes
    if len(lhs_dims) >= 3 and lhs_b >= 2 * (rhs_b + out_bytes) \
            and lhs_b >= 1 << 24:
        score_b += lhs_b
    return (2.0 * out_elems * contract,
            float(lhs_b + rhs_b + out_bytes), score_b)


def _conv_flops(line: str, symtab) -> Tuple[float, float]:
    res = _result_shapes(line)
    ops = _operand_shapes(line, symtab)
    if not res or len(ops) < 2:
        return 0.0, 0.0
    out_elems, out_bytes = _shape_bytes(*res[0])
    m = re.search(r"window=\{size=([0-9x]+)", line)
    ksize = 1
    if m:
        for d in m.group(1).split("x"):
            ksize *= int(d)
    # depthwise (feature_group_count=C) -> contraction is just kernel window;
    # dense conv would multiply by in_features/groups — our convs are
    # depthwise so this is exact, and a lower bound otherwise.
    in_bytes = sum(_shape_bytes(*o)[1] for o in ops[:2])
    return 2.0 * out_elems * ksize, float(in_bytes + out_bytes)


def _trip_count(cond_lines: List[str]) -> int:
    consts = []
    for ln in cond_lines:
        if "constant(" in ln and ("s32" in ln or "u32" in ln):
            consts += [int(x) for x in re.findall(r"constant\((\d+)\)", ln)]
    return max(consts) if consts else 1


def _called_comps(line: str) -> List[Tuple[str, str]]:
    """(attr, computation_name) pairs referenced by this op."""
    out = []
    for attr in _CALL_ATTRS:
        for m in re.finditer(re.escape(attr) + r"\{?%?([\w.\-]+)", line):
            name = m.group(1).rstrip(",}")
            out.append((attr.rstrip("="), name))
        if attr == "branch_computations=" and attr in line:
            m = re.search(r"branch_computations=\{([^}]*)\}", line)
            if m:
                out = [(a, n) for a, n in out if a != "branch_computations"]
                for nm in m.group(1).split(","):
                    out.append(("branch_computations",
                                nm.strip().lstrip("%")))
    return out


def analyze_computation(name: str, comps: Dict[str, List[str]],
                        total_devices: int, memo: Dict[str, Costs]) -> Costs:
    if name in memo:
        return memo[name]
    memo[name] = Costs()          # break cycles defensively
    total = Costs()
    lines = comps.get(name, ())
    symtab = build_symtab(list(lines))
    for line in lines:
        op = _opcode(line)
        if op is None:
            continue
        if op == "dot":
            f, b, sb = _dot_flops(line, symtab)
            total.flops += f
            total.dot_bytes += b
            total.score_bytes += sb
        elif op == "convolution":
            f, b = _conv_flops(line, symtab)
            total.flops += f
            total.dot_bytes += b
        elif any(op.startswith(c) for c in COLLECTIVES):
            if op.endswith("-done"):
                continue
            base = next(c for c in COLLECTIVES if op.startswith(c))
            shapes = _result_shapes(line)
            if base == "reduce-scatter" or base == "all-reduce":
                shapes = shapes or _operand_shapes(line, symtab)
            nbytes = sum(_shape_bytes(*s)[1] for s in shapes)
            n = _group_size(line, total_devices)
            if n <= 1:
                continue
            if base == "all-reduce":
                wire = 2.0 * nbytes * (n - 1) / n
            elif base == "all-gather":
                wire = nbytes * (n - 1) / n
            elif base == "reduce-scatter":
                wire = nbytes * (n - 1)
            elif base == "all-to-all":
                wire = nbytes * (n - 1) / n
            else:                              # collective-permute
                wire = float(nbytes)
            total.collective_bytes += wire
            total.collectives[base] += wire
            total.collective_count += 1
        if op == "while":
            calls = dict(_called_comps(line))
            body = calls.get("body")
            cond = calls.get("condition")
            trips = _trip_count(comps.get(cond, [])) if cond else 1
            if body:
                total.add(analyze_computation(body, comps, total_devices,
                                              memo).scaled(trips))
        elif op in ("fusion", "call", "conditional", "async-start"):
            for attr, cname in _called_comps(line):
                if attr in ("calls", "to_apply", "branch_computations",
                            "true_computation", "false_computation"):
                    total.add(analyze_computation(cname, comps,
                                                  total_devices, memo))
    memo[name] = total
    return total


def cpu_dus_legalization_bytes(hlo_text: str) -> int:
    """Bytes of f32 buffers created by XLA-CPU's float normalization of
    bf16 dynamic-update-slice (scan residual stacks): the CPU backend
    rewrites  DUS(bf16_stack, bf16_slice)  as
    convert_f32 -> DUS -> convert_bf16, materializing an f32 copy of every
    stacked residual buffer. TPU has native bf16 DUS, so these buffers do
    not exist on the target — subtract them when projecting TPU memory.

    Detection (conservative, deduped by (computation, dims)):
      a) f32 dynamic-update-slice whose first operand is a bf16->f32 convert
         of the same dims (in-loop store legalization), any size;
      b) bf16->f32 converts of rank>=4 buffers >= 1 GB (hoisted whole-stack
         upcasts feeding the backward while loop) — real models never
         semantically upcast a full residual *stack*.
    """
    comps = split_computations(hlo_text)
    seen = set()
    for name, lines in comps.items():
        symtab = build_symtab(list(lines))
        converts_from_bf16 = {}
        for ln in lines:
            if _opcode(ln) == "convert":
                src = _operand_shapes(ln, symtab)
                dst = _result_shapes(ln)
                if src and dst and src[0][0] == "bf16" and dst[0][0] == "f32":
                    nm = _def_name(ln)
                    if nm:
                        converts_from_bf16[nm] = dst[0]
                    dims = [int(x) for x in dst[0][1].split(",") if x]
                    if (len(dims) >= 3
                            and _shape_bytes(*dst[0])[1] >= 1 << 30):
                        seen.add((name, dst[0]))
        for ln in lines:
            if _opcode(ln) != "dynamic-update-slice":
                continue
            res = _result_shapes(ln)
            if not res or res[0][0] != "f32":
                continue
            seg = _operand_segment(ln)
            ops = re.findall(r"%([\w.\-]+)", seg)
            if ops and ops[0] in converts_from_bf16:
                seen.add((name, res[0]))
    return sum(_shape_bytes(*shape)[1] for _, shape in seen)


def analyze_hlo(hlo_text: str, total_devices: int) -> Costs:
    comps = split_computations(hlo_text)
    entry = None
    for m in re.finditer(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.M):
        entry = m.group(1)
    if entry is None:
        # fall back: biggest computation
        entry = max(comps, key=lambda k: len(comps[k])) if comps else ""
    memo: Dict[str, Costs] = {}
    return analyze_computation(entry, comps, total_devices, memo)
