"""Launchers: mesh definitions, multi-pod dry-run, sweep, train, serve.

NOTE: never import repro.launch.dryrun from library code — importing it
sets XLA_FLAGS for 512 host devices (it must only run as __main__)."""
from repro.launch.mesh import (HBM_BYTES_S, ICI_BYTES_S, PEAK_FLOPS_BF16,
                               chips, make_host_mesh, make_production_mesh)
