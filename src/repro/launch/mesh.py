"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): the single-pod mesh is 16×16 = 256 chips (TPU v5e pod,
axes data×model); multi-pod adds a leading "pod" axis (2×16×16 = 512 chips).

Hardware constants for the roofline live here too (TPU v5e).
"""
from __future__ import annotations

import jax
import numpy as np

# TPU v5e per-chip roofline constants
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BYTES_S = 819e9             # bytes/s
ICI_BYTES_S = 50e9              # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devs)} exist — "
            "the dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist —
    used by tests and the local trainer."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    devs = np.array(jax.devices()[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(devs, ("data", "model"))


def chips(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
