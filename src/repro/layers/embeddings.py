"""Token embeddings, LM head, sinusoidal positions (whisper stub frontends)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {"tok": jax.random.normal(key, (vocab, d_model), dtype)
            * d_model ** -0.5}


def embed(p, tokens, scale_by_dim: bool = False):
    x = jnp.take(p["tok"], tokens, axis=0)
    if scale_by_dim:
        x = x * jnp.asarray(x.shape[-1] ** 0.5, x.dtype)
    return x


def logits(p_embed, h, head=None):
    """Tied (h @ E^T) or untied (h @ W_head) vocab projection."""
    w = p_embed["tok"].T if head is None else head
    return jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype))


def sinusoidal_positions(seq: int, d_model: int, dtype=jnp.float32):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe[:, :d_model].astype(dtype)
