from repro.layers.attention import (AttnOpts, attn_decode, attn_forward,
                                    fill_kv_cache, init_attention,
                                    init_kv_cache)
from repro.layers.embeddings import embed, init_embedding, logits
from repro.layers.mla import (MLAOpts, fill_mla_cache, init_mla,
                              init_mla_cache, mla_decode, mla_forward)
from repro.layers.mlp import init_mlp, mlp_forward
from repro.layers.moe import MoEOpts, init_moe, moe_forward
from repro.layers.norms import init_rms_norm, rms_norm, softcap
from repro.layers.rope import apply_rope
from repro.layers.ssm import (SSMOpts, init_ssm, init_ssm_cache, ssm_decode,
                              ssm_forward)
