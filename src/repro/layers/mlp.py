"""Gated MLP (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _act(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown act {name}")


def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    kg, ku, kd = jax.random.split(key, 3)
    s_in, s_out = d_model ** -0.5, d_ff ** -0.5
    return {
        "wg": jax.random.normal(kg, (d_model, d_ff), dtype) * s_in,
        "wu": jax.random.normal(ku, (d_model, d_ff), dtype) * s_in,
        "wd": jax.random.normal(kd, (d_ff, d_model), dtype) * s_out,
    }


def mlp_forward(p, x, act: str = "silu"):
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(x.dtype))
    h = _act(act)(g) * u
    return jnp.einsum("bsf,fd->bsd", h, p["wd"].astype(x.dtype))
