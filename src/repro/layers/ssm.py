"""Mamba2 (state-space duality) block: chunked SSD scan + single-token decode.

Follows the minimal-SSD formulation (Dao & Gu 2024): within a chunk the
computation is a masked (B,Q,Q,H) "attention-like" matmul; across chunks a
scan carries the (B,H,P,N) state. Decode is the pure recurrence
  state' = exp(dt*A) * state + dt * x ⊗ B ;  y = C · state' + D * x
with a (d_conv-1)-deep ring buffer for the causal conv.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.layers.norms import rms_norm


@dataclasses.dataclass(frozen=True)
class SSMOpts:
    d_model: int
    cfg: SSMConfig
    tp: bool = True          # False = pure-DP mode, no TP constraints

    @property
    def d_inner(self) -> int:
        return self.cfg.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.cfg.head_dim

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.cfg.n_groups * self.cfg.d_state


def init_ssm(key, opts: SSMOpts, dtype=jnp.float32):
    c = opts.cfg
    d, d_in, H = opts.d_model, opts.d_inner, opts.n_heads
    conv_ch = opts.conv_channels
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * c.n_groups * c.d_state + H
    lo, hi = c.a_init_range
    a = jnp.exp(jax.random.uniform(k4, (H,), jnp.float32,
                                   jnp.log(lo), jnp.log(hi)))
    return {
        "in_proj": jax.random.normal(k1, (d, proj_out), dtype) * d ** -0.5,
        "conv_w": jax.random.normal(k2, (c.d_conv, conv_ch), dtype) * 0.1,
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(a),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": jax.random.normal(k3, (d_in, d), dtype) * d_in ** -0.5,
    }


def _shard_tail(t, tail_axis_from_end: int):
    """Constrain a (B, S, ...) ssm tensor: batch over dp, the channel/head
    dim (``tail_axis_from_end`` from the right) over "model". GSPMD loses
    propagation at the grouped conv, replicating (B, S, conv_ch) fp32
    tensors (1.9 GB each on zamba2 train_4k) without this. No-op on CPU."""
    from jax.sharding import PartitionSpec as P
    spec_tail = [None] * (t.ndim - 1)
    spec_tail[-tail_axis_from_end] = "model"
    for dp in (("pod", "data"), "data", None):
        try:
            return jax.lax.with_sharding_constraint(t, P(dp, *spec_tail))
        except Exception:  # noqa: BLE001 - axis not in ambient mesh
            continue
    return t


def _causal_conv(x, w, b):
    """Depthwise causal conv. x (B,S,C), w (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, [(0, 0), (K - 1, 0), (0, 0)])
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out + b.astype(x.dtype)


def _split_proj(zxbcdt, opts: SSMOpts):
    c, d_in, H = opts.cfg, opts.d_inner, opts.n_heads
    gn = c.n_groups * c.d_state
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in: d_in + d_in + 2 * gn]
    dt = zxbcdt[..., -H:]
    return z, xbc, dt


def _split_xbc(xbc, opts: SSMOpts):
    c, d_in = opts.cfg, opts.d_inner
    gn = c.n_groups * c.d_state
    xs = xbc[..., :d_in]
    Bm = xbc[..., d_in: d_in + gn]
    Cm = xbc[..., d_in + gn:]
    B = xs.shape[0]
    S = xs.shape[1] if xs.ndim == 3 else 1
    xs = xs.reshape(B, S, opts.n_heads, c.head_dim)
    Bm = Bm.reshape(B, S, c.n_groups, c.d_state)
    Cm = Cm.reshape(B, S, c.n_groups, c.d_state)
    return xs, Bm, Cm


def ssd_scan(xs, dt, A, Bm, Cm, D, chunk: int, init_state=None):
    """Chunked SSD. xs (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,G,N), D (H,).

    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = xs.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    Q = min(chunk, S)
    orig_S = S
    if S % Q:
        # pad with dt=0 steps: dA=exp(0)=1 keeps state, dtx=0 adds nothing
        pad = Q - S % Q
        xs = jnp.pad(xs, [(0, 0), (0, pad), (0, 0), (0, 0)])
        dt = jnp.pad(dt, [(0, 0), (0, pad), (0, 0)])
        Bm = jnp.pad(Bm, [(0, 0), (0, pad), (0, 0), (0, 0)])
        Cm = jnp.pad(Cm, [(0, 0), (0, pad), (0, 0), (0, 0)])
        S = S + pad
    nc = S // Q

    def to_chunks(a):
        return jnp.moveaxis(a.reshape((Bsz, nc, Q) + a.shape[2:]), 1, 0)

    xc, dtc, Bc, Cc = map(to_chunks, (xs, dt, Bm, Cm))
    state0 = (jnp.zeros((Bsz, H, P, N), jnp.float32)
              if init_state is None else init_state.astype(jnp.float32))

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def body(state, inp):
        xq, dtq, Bq, Cq = inp                       # (B,Q,H,P) (B,Q,H) (B,Q,G,N)
        dA = dtq.astype(jnp.float32) * A            # (B,Q,H), negative
        cums = jnp.cumsum(dA, axis=1)               # (B,Q,H)
        seg = cums[:, :, None, :] - cums[:, None, :, :]     # (B,Qi,Qj,H)
        # mask BEFORE exp: upper-triangle seg is positive (dA < 0), exp(seg)
        # overflows to inf and inf*0 in the backward of `where` makes every
        # SSM gradient NaN on the very first step
        seg = jnp.where(tri[None, :, :, None], seg, -jnp.inf)
        L = jnp.exp(seg)
        CB = jnp.einsum("bqgn,bkgn->bqkg", Cq, Bq,
                        preferred_element_type=jnp.float32)
        M = jnp.repeat(CB, hpg, axis=-1) * L        # (B,Q,Q,H)
        dtx = (xq * dtq[..., None]).astype(jnp.float32)     # (B,Q,H,P)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", M, dtx)
        # inter-chunk: decay from chunk start to position i
        decay_in = jnp.exp(cums)                    # (B,Q,H)
        Ch = jnp.repeat(Cq, hpg, axis=2)            # (B,Q,H,N)
        y_inter = jnp.einsum("bqhn,bhpn->bqhp", Ch.astype(jnp.float32),
                             state) * decay_in[..., None]
        # state update
        total = cums[:, -1]                         # (B,H)
        decay_out = jnp.exp(total[:, None] - cums)  # (B,Q,H): prod_{l>j} dA
        Bh = jnp.repeat(Bq, hpg, axis=2)            # (B,Q,H,N)
        contrib = jnp.einsum("bqhn,bqhp->bhpn",
                             (Bh * decay_out[..., None]).astype(jnp.float32),
                             dtx)
        state = state * jnp.exp(total)[:, :, None, None] + contrib
        y = y_intra + y_inter + D[None, None, :, None] * xq.astype(jnp.float32)
        return state, y.astype(xs.dtype)

    # checkpoint: recompute the (B,Q,Q,H) chunk matrices in backward instead
    # of storing them for all chunks (7.5 GB/layer on zamba2 train_4k)
    state, yc = jax.lax.scan(jax.checkpoint(body), state0,
                             (xc, dtc, Bc, Cc))
    # yc: (nc, B, Q, H, P) -> (B, S, H, P)
    y = jnp.moveaxis(yc, 0, 1).reshape(Bsz, S, H, P)[:, :orig_S]
    return y, state


def ssm_forward(p, x, opts: SSMOpts, init_state=None):
    """Full-sequence Mamba2 block. Returns (y, (ssd_state, conv_tail))."""
    Bsz, S, d = x.shape
    c = opts.cfg
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xbc, dt = _split_proj(zxbcdt, opts)
    conv_tail = xbc[:, -(c.d_conv - 1):, :]          # decode conv cache
    if opts.tp:
        xbc = _shard_tail(xbc, 1)                    # channels over model
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    if opts.tp:
        xbc = _shard_tail(xbc, 1)
    xs, Bm, Cm = _split_xbc(xbc, opts)
    if opts.tp:
        xs = _shard_tail(xs, 2)                      # ssd heads over model
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssd_scan(xs, dt, A, Bm, Cm, p["D"], c.chunk, init_state)
    y = y.reshape(Bsz, S, opts.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], plus_one=False)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, (state, conv_tail)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_ssm_cache(batch: int, opts: SSMOpts, dtype):
    c = opts.cfg
    return {
        "state": jnp.zeros((batch, opts.n_heads, c.head_dim, c.d_state),
                           jnp.float32),
        "conv": jnp.zeros((batch, c.d_conv - 1, opts.conv_channels), dtype),
    }


def ssm_decode(p, x, cache, opts: SSMOpts):
    """x (B,1,d). Returns (y (B,1,d), cache')."""
    Bsz = x.shape[0]
    c = opts.cfg
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xbc_t, dt = _split_proj(zxbcdt, opts)         # xbc_t (B,1,C)
    window = jnp.concatenate([cache["conv"], xbc_t], axis=1)  # (B,K,C)
    new_conv = window[:, 1:, :]
    w = p["conv_w"].astype(x.dtype)                  # (K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(x.dtype)
    xbc = jax.nn.silu(conv_out)[:, None, :]          # (B,1,C)
    xs, Bm, Cm = _split_xbc(xbc, opts)               # (B,1,H,P),(B,1,G,N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                             # (B,H)
    hpg = opts.n_heads // c.n_groups
    Bh = jnp.repeat(Bm[:, 0], hpg, axis=1)           # (B,H,N)
    Ch = jnp.repeat(Cm[:, 0], hpg, axis=1)
    dtx = (xs[:, 0] * dt[..., None]).astype(jnp.float32)   # (B,H,P)
    state = (cache["state"] * dA[:, :, None, None]
             + jnp.einsum("bhp,bhn->bhpn", dtx, Bh.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xs[:, 0].astype(jnp.float32)
    y = y.reshape(Bsz, 1, opts.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], plus_one=False)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, {"state": state, "conv": new_conv}
