"""Attention layers: GQA (full / sliding-window) with RoPE, decode with KV cache.

All functions are pure; params are plain dict pytrees. Shapes:
  x          (B, S, d_model)
  q          (B, S, n_kv, q_per_kv, hd)   -- GQA grouping kept explicit so the
  k, v       (B, S, n_kv, hd)                n_kv dim is the shardable "heads" dim
  cache k/v  (B, L, n_kv, hd), cache positions (B, L) int32 (-1 = empty)

Long sequences are processed in query chunks (a scan) so the score matrix never
materializes at (S, S); sliding-window layers additionally slice keys to the
window, making local attention linear in S.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import registry as kreg
from repro.layers.norms import rms_norm, softcap
from repro.layers.rope import apply_rope

NEG_INF = -2.3819763e38  # matches gemma reference


@dataclasses.dataclass(frozen=True)
class AttnOpts:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    window: int = 0              # 0 = global (full causal)
    causal: bool = True
    rope_theta: float = 10000.0
    use_rope: bool = True
    softcap: float = 0.0
    qk_norm: bool = False
    query_scale: float = 0.0     # 0 -> head_dim ** -0.5
    q_chunk: int = 256           # query-chunk size for long sequences
    attn_tp: str = "heads"       # "heads" | "seq": TP axis for the score
                                 # einsum; "seq" shards query positions over
                                 # "model" (for kv_heads % tp != 0 archs)
    # tuned Pallas geometry (threaded from ModelConfig.geometry by the
    # stage planner; swept per device class by repro.tuning)
    decode_block_k: int = 512
    flash_block_q: int = 256
    flash_block_k: int = 256
    kernel_force: str = ""       # "" = by backend | kernel|interpret|ref


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, opts: AttnOpts, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    h, g, hd = opts.n_kv_heads, opts.n_heads // opts.n_kv_heads, opts.head_dim
    s = d_model ** -0.5
    p = {
        "wq": jax.random.normal(kq, (d_model, h, g, hd), dtype) * s,
        "wk": jax.random.normal(kk, (d_model, h, hd), dtype) * s,
        "wv": jax.random.normal(kv, (d_model, h, hd), dtype) * s,
        "wo": jax.random.normal(ko, (h, g, hd, d_model), dtype) * s,
    }
    if opts.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


# ---------------------------------------------------------------------------
# Core score/combine helpers
# ---------------------------------------------------------------------------

def _scale(opts: AttnOpts) -> float:
    return opts.query_scale if opts.query_scale else opts.head_dim ** -0.5


def _qkv(p, x, positions, opts: AttnOpts, kv_src=None, kv_pos=None):
    """Project and rope. Returns q (B,S,kv,g,hd), k/v (B,Skv,kv,hd).

    ``kv_src``: source sequence for k/v (cross-attention); defaults to x.
    """
    xs = x if kv_src is None else kv_src
    q = jnp.einsum("bsd,dhgk->bshgk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xs, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xs, p["wv"].astype(x.dtype))
    if opts.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if opts.use_rope:
        B, S = x.shape[:2]
        qf = q.reshape(B, S, -1, opts.head_dim)
        qf = apply_rope(qf, positions, opts.rope_theta)
        q = qf.reshape(q.shape)
        k = apply_rope(k, positions if kv_pos is None else kv_pos,
                       opts.rope_theta)
    return q * _scale(opts), k, v


def _attend(q, k, v, mask, opts: AttnOpts):
    """q (B,Sq,kv,g,hd), k/v (B,Sk,kv,hd), mask (B,Sq,Sk) -> (B,Sq,kv,g,hd)."""
    scores = jnp.einsum("bqhgc,bshc->bhgqs", q, k,
                        preferred_element_type=jnp.float32)
    scores = softcap(scores, opts.softcap)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqs,bshc->bqhgc", probs, v)


def _causal_mask(q_pos, k_pos, window: int, causal: bool, k_valid=None):
    """q_pos (B,Sq), k_pos (B,Sk) -> bool (B,Sq,Sk)."""
    diff = q_pos[:, :, None] - k_pos[:, None, :]
    m = diff >= 0 if causal else jnp.ones_like(diff, dtype=bool)
    if window:
        m = m & (diff < window)
    if k_valid is not None:
        m = m & k_valid[:, None, :]
    return m


# ---------------------------------------------------------------------------
# Pallas dispatch (tuned geometry)
# ---------------------------------------------------------------------------

def _decode_kernel_mode(opts: AttnOpts) -> Optional[str]:
    """Pallas mode for the decode sweep: forced, else by backend."""
    if opts.kernel_force:
        return None if opts.kernel_force == "ref" else opts.kernel_force
    return "kernel" if jax.default_backend() == "tpu" else None


def _forward_kernel_mode(opts: AttnOpts) -> Optional[str]:
    """Pallas mode for full-sequence attention. Opt-in only
    (``kernel_force``): attn_forward is shared with training and the flash
    kernel defines no VJP — serving sets the force via ModelConfig.geometry."""
    if opts.kernel_force and opts.kernel_force != "ref":
        return opts.kernel_force
    return None


def _decode_kernel_attend(q, cache, positions, opts: AttnOpts, mode: str):
    """Decode sweep via the Pallas kernel at the tuned ``decode_block_k``.
    q (B,1,kv,g,hd) already query-scaled -> kernel scale=1."""
    from repro.kernels import ops
    B, _, kv, g, hd = q.shape
    qk = q[:, 0].reshape(B, kv * g, hd)
    kk = cache["k"].transpose(0, 2, 1, 3)        # (B, kv, L, hd)
    vk = cache["v"].transpose(0, 2, 1, 3)
    ks = vs = None
    if "k_scale" in cache:
        ks = cache["k_scale"].transpose(0, 2, 1)
        vs = cache["v_scale"].transpose(0, 2, 1)
    o = ops.decode_attention(qk, kk, vk, cache["pos"], positions[:, 0],
                             window=opts.window, scale=1.0,
                             block_k=opts.decode_block_k,
                             k_scale=ks, v_scale=vs, force=mode)
    return o.reshape(B, 1, kv, g, hd)


def _flash_kernel_attend(q, k, v, opts: AttnOpts, mode: str):
    """Prefill attention via the Pallas flash kernel at the tuned
    (block_q, block_k) tiles. Assumes standard prefill positions
    (``arange`` per row — the kernel masks from block offsets)."""
    from repro.kernels import ops
    B, S, kv, g, hd = q.shape
    qk = q.transpose(0, 2, 3, 1, 4).reshape(B, kv * g, S, hd)
    kk = k.transpose(0, 2, 1, 3)                 # (B, kv, S, hd)
    vk = v.transpose(0, 2, 1, 3)
    o = ops.flash_attention(qk, kk, vk, window=opts.window, scale=1.0,
                            softcap=opts.softcap,
                            block_q=opts.flash_block_q,
                            block_k=opts.flash_block_k, force=mode)
    return o.reshape(B, kv, g, S, hd).transpose(0, 3, 1, 2, 4)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill), query-chunked
# ---------------------------------------------------------------------------

def attn_forward(p, x, positions, opts: AttnOpts,
                 kv_src=None, kv_pos=None, kv_valid=None):
    """Full-sequence attention. Returns (y, (k, v)) -- k/v for cache building.

    ``kv_src``/``kv_pos``/``kv_valid``: encoder states for cross-attention.
    """
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, positions, opts, kv_src, kv_pos)
    if kv_src is not None:
        k_pos, k_valid = kv_pos, kv_valid
    else:
        k_pos, k_valid = positions, None

    qc = opts.q_chunk
    fmode = _forward_kernel_mode(opts)
    if opts.attn_tp == "seq":
        # indivisible kv-heads: shard QUERY positions over the model axis so
        # score compute is TP-distributed (heads replicated); k/v gathered.
        q = _shard_q_seq(q)
        k = _gather_seq(k)
        v = _gather_seq(v)
        mask = _causal_mask(positions, k_pos, opts.window, opts.causal,
                            k_valid)
        y = _attend(q, k, v, mask, opts)
    elif (fmode is not None and opts.causal and kv_src is None
          and kreg.check_flash_blocks(S, opts.flash_block_q,
                                      opts.flash_block_k) is None):
        y = _flash_kernel_attend(q, k, v, opts, fmode)
    elif qc and S > qc and S % qc == 0:
        y = _chunked_attend(q, k, v, positions, k_pos, k_valid, opts)
    else:
        mask = _causal_mask(positions, k_pos, opts.window, opts.causal, k_valid)
        y = _attend(q, k, v, mask, opts)
    out = jnp.einsum("bshgk,hgkd->bsd", y, p["wo"].astype(x.dtype))
    return out, (k, v)


def _shard_q_seq(q):
    from jax.sharding import PartitionSpec as P
    for dp in (("pod", "data"), "data", None):
        try:
            return jax.lax.with_sharding_constraint(
                q, P(dp, "model", *([None] * (q.ndim - 2))))
        except Exception:  # noqa: BLE001 - axis not in ambient mesh
            continue
    return q


def _gather_seq(t):
    """Pin k/v to batch-only sharding (seq gathered) BEFORE the q-chunk scan:
    with sequence-parallel activations, XLA otherwise re-all-gathers k/v on
    every chunk iteration inside the while loop (measured 3.9 TB/device of
    all-gather on llava train_4k — 16× the hoisted cost). No-op without a
    mesh."""
    from jax.sharding import PartitionSpec as P
    for dp in (("pod", "data"), "data", None):
        try:
            return jax.lax.with_sharding_constraint(
                t, P(dp, *([None] * (t.ndim - 1))))
        except Exception:  # noqa: BLE001 - axis not in ambient mesh
            continue
    return t


def _chunked_attend(q, k, v, q_pos, k_pos, k_valid, opts: AttnOpts):
    """Scan over query chunks; local layers slice keys to the window."""
    B, S = q.shape[:2]
    qc = opts.q_chunk
    n_chunks = S // qc
    w = opts.window
    if opts.attn_tp == "heads":
        # hoist the k/v seq-gather out of the chunk loop (Megatron-SP
        # residuals are seq-sharded); "none" = pure-DP, no TP constraints
        k = _gather_seq(k)
        v = _gather_seq(v)

    use_local_slice = bool(w) and w < S and k.shape[1] == S
    if use_local_slice:
        # Pad keys on the left by `w` so chunk i reads keys [i*qc - w, i*qc + qc).
        pad = [(0, 0), (w, 0), (0, 0), (0, 0)]
        k_pad = jnp.pad(k, pad)
        v_pad = jnp.pad(v, pad)
        kp_pad = jnp.pad(k_pos, [(0, 0), (w, 0)], constant_values=-1)
        kval_pad = jnp.pad(jnp.ones((B, S), bool) if k_valid is None else k_valid,
                           [(0, 0), (w, 0)], constant_values=False)

        def body(carry, i):
            qs = jax.lax.dynamic_slice_in_dim(q, i * qc, qc, axis=1)
            qp = jax.lax.dynamic_slice_in_dim(q_pos, i * qc, qc, axis=1)
            ks = jax.lax.dynamic_slice_in_dim(k_pad, i * qc, qc + w, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v_pad, i * qc, qc + w, axis=1)
            kps = jax.lax.dynamic_slice_in_dim(kp_pad, i * qc, qc + w, axis=1)
            kvs = jax.lax.dynamic_slice_in_dim(kval_pad, i * qc, qc + w, axis=1)
            mask = _causal_mask(qp, kps, w, opts.causal, kvs)
            return carry, _attend(qs, ks, vs, mask, opts)
    else:
        def body(carry, i):
            qs = jax.lax.dynamic_slice_in_dim(q, i * qc, qc, axis=1)
            qp = jax.lax.dynamic_slice_in_dim(q_pos, i * qc, qc, axis=1)
            mask = _causal_mask(qp, k_pos, w, opts.causal, k_valid)
            return carry, _attend(qs, k, v, mask, opts)

    # checkpoint: a chunk's backward recomputes its (qc, S) score matrix
    # instead of storing scores/probs for every chunk (tens of GB at 4k+)
    _, ys = jax.lax.scan(jax.checkpoint(body), None, jnp.arange(n_chunks))
    # ys: (n_chunks, B, qc, kv, g, hd) -> (B, S, kv, g, hd)
    return jnp.moveaxis(ys, 0, 1).reshape(q.shape)


# ---------------------------------------------------------------------------
# Decode (single new token against a KV cache)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, cache_len: int, opts: AttnOpts, dtype,
                  quant: bool = False):
    """KV cache. ``quant`` stores k/v as int8 with per-(b,l,h) fp32 scales —
    halves cache bytes per device (2× serving density); the Pallas
    ``decode_attention`` kernel reads the int8 form directly on TPU."""
    shp = (batch, cache_len, opts.n_kv_heads, opts.head_dim)
    cache = {
        "k": jnp.zeros(shp, jnp.int8 if quant else dtype),
        "v": jnp.zeros(shp, jnp.int8 if quant else dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }
    if quant:
        cache["k_scale"] = jnp.ones(shp[:3], jnp.float32)
        cache["v_scale"] = jnp.ones(shp[:3], jnp.float32)
    return cache


def _quant_rows(x):
    """(…, hd) -> int8 values + fp32 scale over the last dim."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _deq(k, scale, dtype):
    return (k.astype(jnp.float32) * scale[..., None]).astype(dtype)


def fill_kv_cache(cache, k, v, positions):
    """Write prefill k/v (B,S,kv,hd) into the cache (ring for local layers)."""
    L = cache["k"].shape[1]
    S = k.shape[1]
    if S <= L:
        idx = positions % L                       # (B, S)
    else:                                         # keep last L entries (ring)
        k, v, positions = k[:, -L:], v[:, -L:], positions[:, -L:]
        idx = positions % L
    b = jnp.arange(k.shape[0])[:, None]
    out = dict(cache)
    if "k_scale" in cache:
        kq, ks = _quant_rows(k)
        vq, vs = _quant_rows(v)
        out["k"] = cache["k"].at[b, idx].set(kq)
        out["v"] = cache["v"].at[b, idx].set(vq)
        out["k_scale"] = cache["k_scale"].at[b, idx].set(ks)
        out["v_scale"] = cache["v_scale"].at[b, idx].set(vs)
    else:
        out["k"] = cache["k"].at[b, idx].set(k)
        out["v"] = cache["v"].at[b, idx].set(v)
    out["pos"] = cache["pos"].at[b, idx].set(positions)
    return out


def init_paged_kv_pool(n_pages: int, page_size: int, opts: AttnOpts, dtype,
                       quant: bool = False):
    """Paged KV pool: one shared page set instead of per-sequence rows.
    Page 0 is reserved by the engine as the null/scratch page — unused
    block-table entries point at it, and inactive batch rows write their
    (discarded) k/v there with pos -1, so gathers through any table never
    see a valid-looking stale position."""
    shp = (n_pages, page_size, opts.n_kv_heads, opts.head_dim)
    pool = {
        "k": jnp.zeros(shp, jnp.int8 if quant else dtype),
        "v": jnp.zeros(shp, jnp.int8 if quant else dtype),
        "pos": jnp.full((n_pages, page_size), -1, jnp.int32),
    }
    if quant:
        pool["k_scale"] = jnp.ones(shp[:3], jnp.float32)
        pool["v_scale"] = jnp.ones(shp[:3], jnp.float32)
    return pool


def attn_decode_paged(p, x, positions, cache, block_tables, opts: AttnOpts):
    """Paged-cache decode step. x (B,1,d); positions (B,1) absolute with -1
    for inactive batch rows; cache leaves (P, ps, kv, hd) / pos (P, ps);
    block_tables (B, nb) int32 page ids (0 pads unused entries).

    The new k/v lands at page ``block_tables[b, pos // ps]`` offset
    ``pos % ps`` — the engine guarantees that page is privately owned
    (copy-on-write happens host-side before a shared page is written)."""
    B = x.shape[0]
    ps = cache["k"].shape[1]
    q, k, v = _qkv(p, x, positions, opts)        # k/v (B,1,kv,hd)
    quant = "k_scale" in cache
    pos = positions[:, 0]
    active = pos >= 0
    safe = jnp.maximum(pos, 0)
    pid = jnp.take_along_axis(block_tables, (safe // ps)[:, None],
                              axis=1)[:, 0]                      # (B,)
    # inactive rows write the reserved scratch page with pos -1
    pid = jnp.where(active, pid, 0)
    off = jnp.where(active, safe % ps, 0)
    new = dict(cache)
    if quant:
        kq, ks = _quant_rows(k[:, 0])
        vq, vs = _quant_rows(v[:, 0])
        new["k"] = cache["k"].at[pid, off].set(kq)
        new["v"] = cache["v"].at[pid, off].set(vq)
        new["k_scale"] = cache["k_scale"].at[pid, off].set(ks)
        new["v_scale"] = cache["v_scale"].at[pid, off].set(vs)
    else:
        new["k"] = cache["k"].at[pid, off].set(k[:, 0])
        new["v"] = cache["v"].at[pid, off].set(v[:, 0])
    new["pos"] = cache["pos"].at[pid, off].set(jnp.where(active, pos, -1))
    cache = new
    # gather this batch's pages into the (B, L, kv, hd) view the score
    # einsum expects (L = nb * ps). The Pallas paged kernel
    # (kernels/decode_attention.py) sweeps a pool in place on TPU but
    # consumes the (P, Hkv, ps, D) layout — wiring it in here requires
    # transposing this pool's (P, ps, kv, hd) leaves (axes 1<->2)
    if quant:
        k_all = _deq(cache["k"][block_tables],
                     cache["k_scale"][block_tables], x.dtype)
        v_all = _deq(cache["v"][block_tables],
                     cache["v_scale"][block_tables], x.dtype)
    else:
        k_all = cache["k"][block_tables]         # (B, nb, ps, kv, hd)
        v_all = cache["v"][block_tables]
    k_all = k_all.reshape((B, -1) + k_all.shape[3:])
    v_all = v_all.reshape((B, -1) + v_all.shape[3:])
    kpos = cache["pos"][block_tables].reshape(B, -1)
    mask = _causal_mask(positions, kpos, opts.window, opts.causal,
                        k_valid=kpos >= 0)
    y = _attend(q, k_all, v_all, mask, opts)
    out = jnp.einsum("bshgk,hgkd->bsd", y, p["wo"].astype(x.dtype))
    return out, cache


def attn_decode(p, x, positions, cache, opts: AttnOpts, update_cache=True):
    """x (B,1,d); positions (B,1) absolute. Returns (y, cache').

    With a quantized cache (int8 + scales) the XLA path dequantizes before
    the score dots; on TPU, ``kernels.ops.decode_attention`` consumes the
    int8 arrays directly (dequant in VMEM).
    """
    B = x.shape[0]
    q, k, v = _qkv(p, x, positions, opts)        # k/v (B,1,kv,hd)
    quant = "k_scale" in cache
    if update_cache:
        L = cache["k"].shape[1]
        idx = (positions[:, 0] % L)
        b = jnp.arange(B)
        new = dict(cache)
        if quant:
            kq, ks = _quant_rows(k[:, 0])
            vq, vs = _quant_rows(v[:, 0])
            new["k"] = cache["k"].at[b, idx].set(kq)
            new["v"] = cache["v"].at[b, idx].set(vq)
            new["k_scale"] = cache["k_scale"].at[b, idx].set(ks)
            new["v_scale"] = cache["v_scale"].at[b, idx].set(vs)
        else:
            new["k"] = cache["k"].at[b, idx].set(k[:, 0])
            new["v"] = cache["v"].at[b, idx].set(v[:, 0])
        new["pos"] = cache["pos"].at[b, idx].set(positions[:, 0])
        cache = new
    dmode = _decode_kernel_mode(opts)
    if (dmode is not None and opts.causal and not opts.softcap
            and kreg.check_decode_block(cache["k"].shape[1],
                                        opts.decode_block_k) is None):
        y = _decode_kernel_attend(q, cache, positions, opts, dmode)
    else:
        if quant:
            k_all = _deq(cache["k"], cache["k_scale"], x.dtype)
            v_all = _deq(cache["v"], cache["v_scale"], x.dtype)
        else:
            k_all, v_all = cache["k"], cache["v"]
        kpos = cache["pos"]
        mask = _causal_mask(positions, kpos, opts.window, opts.causal,
                            k_valid=kpos >= 0)
        y = _attend(q, k_all, v_all, mask, opts)
    out = jnp.einsum("bshgk,hgkd->bsd", y, p["wo"].astype(x.dtype))
    return out, cache
