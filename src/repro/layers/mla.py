"""Multi-head Latent Attention (DeepSeek-V2) with compressed-latent KV cache.

Prefill/train use the expanded formulation (materialize per-head K/V).
Decode uses the *absorbed* formulation: queries are projected into latent space
via W_uk so the cache stays compressed (B, L, kv_lora_rank + rope_dim) — this is
the faithful DeepSeek serving scheme and is what makes decode_32k × batch=128
memory-feasible.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.layers.norms import rms_norm
from repro.layers.rope import apply_rope

NEG_INF = -2.3819763e38


@dataclasses.dataclass(frozen=True)
class MLAOpts:
    n_heads: int
    cfg: MLAConfig
    rope_theta: float = 10000.0
    q_chunk: int = 256

    @property
    def scale(self) -> float:
        c = self.cfg
        return (c.qk_nope_head_dim + c.qk_rope_head_dim) ** -0.5


def init_mla(key, d_model: int, opts: MLAOpts, dtype=jnp.float32):
    c = opts.cfg
    h = opts.n_heads
    ks = jax.random.split(key, 5)
    s = d_model ** -0.5
    qd = c.qk_nope_head_dim + c.qk_rope_head_dim
    return {
        "wq": jax.random.normal(ks[0], (d_model, h, qd), dtype) * s,
        "w_dkv": jax.random.normal(ks[1], (d_model, c.kv_lora_rank + c.qk_rope_head_dim), dtype) * s,
        "kv_norm": jnp.zeros((c.kv_lora_rank,), dtype),
        "w_uk": jax.random.normal(ks[2], (c.kv_lora_rank, h, c.qk_nope_head_dim), dtype) * c.kv_lora_rank ** -0.5,
        "w_uv": jax.random.normal(ks[3], (c.kv_lora_rank, h, c.v_head_dim), dtype) * c.kv_lora_rank ** -0.5,
        "wo": jax.random.normal(ks[4], (h, c.v_head_dim, d_model), dtype) * s,
    }


def _project_q(p, x, positions, opts: MLAOpts):
    """Returns q_nope (B,S,h,nope), q_rope (B,S,h,rope)."""
    c = opts.cfg
    q = jnp.einsum("bsd,dhq->bshq", x, p["wq"].astype(x.dtype))
    q_nope = q[..., : c.qk_nope_head_dim]
    q_rope = apply_rope(q[..., c.qk_nope_head_dim:], positions, opts.rope_theta)
    return q_nope, q_rope


def _latent(p, x, positions, opts: MLAOpts):
    """Compressed latent ``c_kv`` (B,S,r) + shared rope key (B,S,rope)."""
    c = opts.cfg
    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype))
    c_kv = rms_norm(dkv[..., : c.kv_lora_rank], p["kv_norm"], plus_one=False)
    k_rope = dkv[..., c.kv_lora_rank:][:, :, None, :]          # (B,S,1,rope)
    k_rope = apply_rope(k_rope, positions, opts.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_forward(p, x, positions, opts: MLAOpts):
    """Expanded-form full-sequence MLA. Returns (y, (c_kv, k_rope))."""
    c = opts.cfg
    B, S, _ = x.shape
    q_nope, q_rope = _project_q(p, x, positions, opts)
    c_kv, k_rope = _latent(p, x, positions, opts)
    k_nope = jnp.einsum("bsr,rhn->bshn", c_kv, p["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsr,rhn->bshn", c_kv, p["w_uv"].astype(x.dtype))

    qc = opts.q_chunk
    if qc and S > qc and S % qc == 0:
        y = _chunked(q_nope, q_rope, k_nope, k_rope, v, positions, opts)
    else:
        y = _attend(q_nope, q_rope, k_nope, k_rope, v, positions, positions,
                    None, opts)
    out = jnp.einsum("bshv,hvd->bsd", y, p["wo"].astype(x.dtype))
    return out, (c_kv, k_rope)


def _attend(q_nope, q_rope, k_nope, k_rope, v, q_pos, k_pos, k_valid,
            opts: MLAOpts):
    scores = (jnp.einsum("bqhn,bshn->bhqs", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhr,bsr->bhqs", q_rope, k_rope,
                           preferred_element_type=jnp.float32))
    scores = scores * opts.scale
    mask = q_pos[:, :, None] >= k_pos[:, None, :]
    if k_valid is not None:
        mask = mask & k_valid[:, None, :]
    scores = jnp.where(mask[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshv->bqhv", probs, v)


def _chunked(q_nope, q_rope, k_nope, k_rope, v, positions, opts: MLAOpts):
    from repro.layers.attention import _gather_seq
    B, S = q_nope.shape[:2]
    qc = opts.q_chunk
    k_nope, k_rope, v = map(_gather_seq, (k_nope, k_rope, v))

    def body(_, i):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i * qc, qc, axis=1)
        y = _attend(sl(q_nope), sl(q_rope), k_nope, k_rope, v,
                    sl(positions), positions, None, opts)
        return None, y

    _, ys = jax.lax.scan(jax.checkpoint(body), None, jnp.arange(S // qc))
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, opts.n_heads, opts.cfg.v_head_dim)


# ---------------------------------------------------------------------------
# Decode: absorbed formulation, compressed cache
# ---------------------------------------------------------------------------

def init_mla_cache(batch: int, cache_len: int, opts: MLAOpts, dtype):
    c = opts.cfg
    return {
        "c_kv": jnp.zeros((batch, cache_len, c.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cache_len, c.qk_rope_head_dim), dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def fill_mla_cache(cache, c_kv, k_rope, positions):
    L = cache["c_kv"].shape[1]
    b = jnp.arange(c_kv.shape[0])[:, None]
    idx = positions % L
    return {
        "c_kv": cache["c_kv"].at[b, idx].set(c_kv),
        "k_rope": cache["k_rope"].at[b, idx].set(k_rope),
        "pos": cache["pos"].at[b, idx].set(positions),
    }


def mla_decode(p, x, positions, cache, opts: MLAOpts):
    """Absorbed decode: scores/values computed in the compressed latent space."""
    B = x.shape[0]
    q_nope, q_rope = _project_q(p, x, positions, opts)      # (B,1,h,·)
    c_kv_t, k_rope_t = _latent(p, x, positions, opts)
    L = cache["c_kv"].shape[1]
    b = jnp.arange(B)
    idx = positions[:, 0] % L
    cache = {
        "c_kv": cache["c_kv"].at[b, idx].set(c_kv_t[:, 0]),
        "k_rope": cache["k_rope"].at[b, idx].set(k_rope_t[:, 0]),
        "pos": cache["pos"].at[b, idx].set(positions[:, 0]),
    }
    # Absorb W_uk into the query: q_lat (B,1,h,r)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, p["w_uk"].astype(x.dtype))
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_lat, cache["c_kv"],
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhr,bsr->bhqs", q_rope, cache["k_rope"],
                           preferred_element_type=jnp.float32)) * opts.scale
    kpos = cache["pos"]
    mask = (positions[:, :, None] >= kpos[:, None, :]) & (kpos >= 0)[:, None, :]
    scores = jnp.where(mask[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", probs, cache["c_kv"])   # (B,1,h,r)
    y = jnp.einsum("bqhr,rhv->bqhv", o_lat, p["w_uv"].astype(x.dtype))
    out = jnp.einsum("bshv,hvd->bsd", y, p["wo"].astype(x.dtype))
    return out, cache
