"""Mixture-of-Experts layer with capacity-based sort-free dispatch.

Dispatch is gather/scatter-based (no one-hot matmuls), so HLO FLOPs stay close
to the *active* expert FLOPs (E·C ≈ T·top_k·capacity_factor rows of SwiGLU):
  1. router logits -> top_k experts per token
  2. position-in-expert via a cumsum over the flattened assignment list
  3. gather tokens into (E, C, d), run per-expert SwiGLU as a batched einsum
     (the E dim is the EP-shardable axis), scatter-add back weighted by gate.

Tokens beyond an expert's capacity C are dropped (standard Switch behaviour);
with capacity_factor 1.25 and balanced routing, drops are rare.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.layers.mlp import _act


@dataclasses.dataclass(frozen=True)
class MoEOpts:
    cfg: MoEConfig
    act: str = "silu"
    norm_topk: bool = True


def init_moe(key, d_model: int, opts: MoEOpts, dtype=jnp.float32):
    c = opts.cfg
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    s_in, s_out = d_model ** -0.5, c.d_expert ** -0.5
    p = {
        "router": jax.random.normal(kr, (d_model, c.n_experts), jnp.float32) * s_in,
        "wg": jax.random.normal(kg, (c.n_experts, d_model, c.d_expert), dtype) * s_in,
        "wu": jax.random.normal(ku, (c.n_experts, d_model, c.d_expert), dtype) * s_in,
        "wd": jax.random.normal(kd, (c.n_experts, c.d_expert, d_model), dtype) * s_out,
    }
    if c.n_shared:
        f = c.n_shared * c.d_expert
        k1, k2, k3 = jax.random.split(ks, 3)
        p["shared"] = {
            "wg": jax.random.normal(k1, (d_model, f), dtype) * s_in,
            "wu": jax.random.normal(k2, (d_model, f), dtype) * s_in,
            "wd": jax.random.normal(k3, (f, d_model), dtype) * f ** -0.5,
        }
    return p


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(4, -(-c // 4) * 4)  # round up to multiple of 4


def _ep_constrain(t, n_tail: int):
    """Pin (D, E, ...) tensors to (dp-axes, "model", ...): gather-based
    dispatch blocks GSPMD's sharding propagation, so without this the expert
    einsums replicate E across the model axis (verified: 16× flops + 550 GB
    of all-gathers per device on qwen3 train_4k). Falls back gracefully when
    the ambient mesh lacks the axes (CPU tests)."""
    from jax.sharding import PartitionSpec as P
    for dp in (("pod", "data"), "data", None):
        try:
            return jax.lax.with_sharding_constraint(
                t, P(dp, "model", *([None] * n_tail)))
        except Exception:  # noqa: BLE001 - axis not in ambient mesh
            continue
    return t


def moe_forward(p, x, opts: MoEOpts):
    """x (B,S,d) -> (y (B,S,d), aux_loss scalar).

    Dispatch is *shard-local*: tokens reshape to (D, Tl) where
    D = cfg.dp_shards (set by the launcher to the mesh's data-parallel
    extent) so the position-in-expert cumsum runs inside each shard and
    GSPMD never inserts cross-shard prefix sums or dispatch-table gathers.
    Capacity is per shard; expert compute keeps the E dim as the
    EP-shardable axis: xg (D, E, Cl, d).
    """
    c = opts.cfg
    B, S, d = x.shape
    T = B * S
    D = c.dp_shards if T % c.dp_shards == 0 else 1
    Tl = T // D
    xf = x.reshape(D, Tl, d)
    logits = jnp.einsum("dtc,ce->dte", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # (D, Tl, E)
    gate, expert_idx = jax.lax.top_k(probs, c.top_k)           # (D, Tl, k)
    if opts.norm_topk:
        gate = gate / (jnp.sum(gate, axis=-1, keepdims=True) + 1e-9)

    # Load-balance aux loss (Switch): E * sum_e f_e * P_e (global means)
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    onehot_top1 = jax.nn.one_hot(expert_idx[..., 0], c.n_experts,
                                 dtype=jnp.float32)
    fe = jnp.mean(onehot_top1, axis=(0, 1))
    aux = c.n_experts * jnp.sum(me * fe)

    Cl = capacity(Tl, c)
    flat_e = expert_idx.reshape(D, Tl * c.top_k)
    flat_g = gate.reshape(D, Tl * c.top_k).astype(x.dtype)
    token_id = jnp.repeat(jnp.arange(Tl), c.top_k)              # (Tl*k,)

    # position of each assignment within its expert queue (per shard)
    onehot = (flat_e[..., None] == jnp.arange(c.n_experts)[None, None, :])
    pos = jnp.sum(jnp.cumsum(onehot.astype(jnp.int32), axis=1) * onehot,
                  axis=-1) - 1                                  # (D, Tl*k)
    keep = pos < Cl

    # Dispatch/gather/scatter are vmapped over the shard dim D so it stays a
    # *batch* dim of the scatter/gather ops — explicit D indices would make
    # GSPMD replicate the (D, Tl, d) tensors and all-reduce them (verified:
    # 8.6 GB all-reduces per layer pass on qwen3 train_4k).
    row = jnp.where(keep, flat_e, c.n_experts)   # OOB row = dropped
    col = jnp.where(keep, pos, 0)

    def dispatch_one(row1, col1, gate1):
        # (Tl*k,) -> disp (E, Cl) token ids (Tl = pad sentinel), gates (E, Cl)
        disp1 = jnp.full((c.n_experts, Cl), Tl, jnp.int32)
        disp1 = disp1.at[row1, col1].set(token_id, mode="drop")
        g1 = jnp.zeros((c.n_experts, Cl), x.dtype)
        g1 = g1.at[row1, col1].set(gate1, mode="drop")
        return disp1, g1

    disp, gates_ec = jax.vmap(dispatch_one)(row, col, flat_g)

    xpad = jnp.concatenate([xf, jnp.zeros((D, 1, d), x.dtype)], axis=1)
    xg = jax.vmap(lambda xp, dp1: xp[dp1.reshape(-1)])(xpad, disp)
    xg = _ep_constrain(xg.reshape(D, c.n_experts, Cl, d), 2)

    act = _act(opts.act)
    h = act(jnp.einsum("xecd,edf->xecf", xg, p["wg"].astype(x.dtype))) \
        * jnp.einsum("xecd,edf->xecf", xg, p["wu"].astype(x.dtype))
    h = _ep_constrain(h, 2)
    y = jnp.einsum("xecf,efd->xecd", h, p["wd"].astype(x.dtype))
    y = _ep_constrain(y, 2)
    y = y * _ep_constrain(gates_ec, 1)[..., None]

    out = jax.vmap(
        lambda y1, dp1: jnp.zeros((Tl + 1, d), x.dtype)
        .at[dp1.reshape(-1)].add(y1.reshape(-1, d)))(y, disp)
    out = out[:, :Tl]

    if c.n_shared:
        sp = p["shared"]
        xfl = xf.reshape(T, d)
        g = act(xfl @ sp["wg"].astype(x.dtype)) \
            * (xfl @ sp["wu"].astype(x.dtype))
        out = out.reshape(T, d) + g @ sp["wd"].astype(x.dtype)

    return out.reshape(B, S, d), aux
