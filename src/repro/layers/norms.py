"""Normalization layers (pure functions over param pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, weight, eps: float = 1e-6, *, plus_one: bool = True):
    """RMSNorm. ``plus_one`` follows gemma convention (weight stored as w-1)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    x32 = x32 * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:
        w = w + 1.0
    return (x32 * w).astype(dtype)


def init_rms_norm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((dim,), dtype=dtype)}


def softcap(x, cap: float):
    """Gemma-style logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)
