"""Rotary position embeddings."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    """Inverse frequencies, shape (head_dim//2,)."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x, positions, theta: float = 10000.0):
    """Rotate ``x`` of shape (..., seq, heads, head_dim) by ``positions`` (..., seq).

    Uses the split-halves convention (llama/gemma): the head_dim is split into
    two halves rather than interleaved pairs.
    """
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                      # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]                    # (..., seq, 1, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
