"""Unified decoder-only LM covering dense / MoE / SSM / hybrid / VLM families.

Public surface (all pure functions):
  init_lm(cfg, key)                         -> params
  lm_forward(cfg, params, tokens, ...)      -> (hidden, aux)        [train]
  lm_logits(cfg, params, hidden)            -> logits
  lm_prefill(cfg, params, tokens, max_len)  -> (hidden, caches)
  lm_decode(cfg, params, caches, tok, pos)  -> (logits, caches)

VLM (llava): `patches` (B, P, d_model) precomputed patch embeddings (stub
frontend per assignment) are prepended to the token embeddings; `tokens` then
has S - P entries so the combined length equals the cell's seq_len.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MIXER_SHARED_ATTN, ModelConfig
from repro.layers.embeddings import embed, init_embedding
from repro.layers.norms import rms_norm, softcap
from repro.models.stages import (apply_stages, init_cache, init_paged_cache,
                                 init_shared_block, init_stage, plan_stages)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _param_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def init_lm(cfg: ModelConfig, key) -> dict:
    pdt = _param_dtype(cfg)
    stages = plan_stages(cfg)
    keys = jax.random.split(key, len(stages) + 3)
    params = {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model, pdt),
        "final_norm": jnp.zeros((cfg.d_model,), pdt),
        "stages": tuple(init_stage(cfg, st, keys[3 + i], pdt)
                        for i, st in enumerate(stages)),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size), pdt) * cfg.d_model ** -0.5)
    if any(s.mixer == MIXER_SHARED_ATTN for st in stages for s in st.sites):
        params["shared"] = init_shared_block(cfg, keys[2], pdt)
    return params


def _embed_tokens(cfg, params, tokens, patches=None):
    x = embed(params["embed"], tokens, scale_by_dim=cfg.embed_scale)
    x = x.astype(_dtype(cfg))
    if patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    return x


def _positions(x):
    B, S = x.shape[:2]
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))


def lm_forward(cfg: ModelConfig, params, tokens, patches=None,
               remat: bool = False):
    """Teacher-forced full-sequence forward. Returns (hidden, aux_loss)."""
    x = _embed_tokens(cfg, params, tokens, patches)
    pos = _positions(x)
    x, _, aux = apply_stages(cfg, params, x, pos, mode="train", remat=remat)
    h = rms_norm(x, params["final_norm"])
    return h, aux


def lm_logits(cfg: ModelConfig, params, h):
    w = params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]
    out = jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype))
    return softcap(out, cfg.final_softcap)


def lm_prefill(cfg: ModelConfig, params, tokens, max_len: int, patches=None,
               clamp_window: bool = True):
    """Run the prompt, building decode caches sized ``max_len``.

    ``clamp_window=False`` builds full-length (non-ring) caches even for
    windowed sites — the layout the paged page-splice expects."""
    x = _embed_tokens(cfg, params, tokens, patches)
    pos = _positions(x)
    x, caches, _ = apply_stages(cfg, params, x, pos, mode="prefill",
                                max_len=max_len, cache_dtype=_dtype(cfg),
                                clamp_window=clamp_window)
    h = rms_norm(x, params["final_norm"])
    return h, caches


def lm_decode(cfg: ModelConfig, params, caches, tokens, pos):
    """One decode step. tokens (B,1) int32, pos (B,) absolute positions."""
    x = _embed_tokens(cfg, params, tokens)
    positions = pos[:, None].astype(jnp.int32)
    x, caches, _ = apply_stages(cfg, params, x, positions, mode="decode",
                                caches=caches)
    h = rms_norm(x, params["final_norm"])
    return lm_logits(cfg, params, h), caches


def lm_decode_paged(cfg: ModelConfig, params, caches, tokens, pos,
                    block_tables):
    """One decode step against the paged KV pool. tokens (B,1) int32;
    pos (B,) absolute positions (-1 = inactive row); block_tables (B, nb)
    int32 page ids."""
    x = _embed_tokens(cfg, params, tokens)
    positions = pos[:, None].astype(jnp.int32)
    x, caches, _ = apply_stages(cfg, params, x, positions, mode="decode",
                                caches=caches, block_tables=block_tables)
    h = rms_norm(x, params["final_norm"])
    return lm_logits(cfg, params, h), caches


def make_decode_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Empty caches (for dry-run input specs and serving allocation)."""
    return init_cache(cfg, batch, max_len, _dtype(cfg))


def make_paged_caches(cfg: ModelConfig, n_pages: int, page_size: int):
    """Empty paged KV pool (shared across every serving slot)."""
    return init_paged_cache(cfg, n_pages, page_size, _dtype(cfg))
