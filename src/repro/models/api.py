"""Family-dispatching model API + dry-run input specs.

``Model`` bundles init / forward / prefill / decode for any assigned arch.
``input_specs(cfg, shape_cell)`` returns ShapeDtypeStruct stand-ins for every
input of the corresponding step function (no device allocation) — the
dry-run and the roofline tooling lower against these.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import encdec, lm

WHISPER_DEC_LEN = 448


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---------------- init ----------------
    def init(self, key):
        if self.cfg.family == "audio":
            return encdec.init_encdec(self.cfg, key)
        return lm.init_lm(self.cfg, key)

    # ---------------- training forward ----------------
    def forward(self, params, batch, remat: bool = False):
        """batch dict -> (hidden, aux). Keys per family (see input_specs)."""
        cfg = self.cfg
        if cfg.family == "audio":
            return encdec.encdec_forward(cfg, params, batch["frames"],
                                         batch["tokens"], remat=remat)
        return lm.lm_forward(cfg, params, batch["tokens"],
                             patches=batch.get("patches"), remat=remat)

    def logits(self, params, hidden):
        if self.cfg.family == "audio":
            return encdec.encdec_logits(self.cfg, params, hidden)
        return lm.lm_logits(self.cfg, params, hidden)

    # ---------------- serving ----------------
    def prefill(self, params, batch, max_len: int, clamp_window: bool = True):
        cfg = self.cfg
        if cfg.family == "audio":
            return encdec.encdec_prefill(cfg, params, batch["frames"],
                                         batch["tokens"])
        return lm.lm_prefill(cfg, params, batch["tokens"], max_len,
                             patches=batch.get("patches"),
                             clamp_window=clamp_window)

    def decode(self, params, caches, tokens, pos):
        if self.cfg.family == "audio":
            return encdec.encdec_decode(self.cfg, params, caches, tokens, pos)
        return lm.lm_decode(self.cfg, params, caches, tokens, pos)

    def decode_paged(self, params, caches, tokens, pos, block_tables):
        """One decode step against the paged KV pool (block-table
        indirection; attention-family LMs only)."""
        if self.cfg.family == "audio":
            raise ValueError("paged decode supports decoder-only LMs")
        return lm.lm_decode_paged(self.cfg, params, caches, tokens, pos,
                                  block_tables)

    def make_caches(self, batch: int, max_len: int):
        if self.cfg.family == "audio":
            return encdec.make_encdec_caches(self.cfg, batch, max_len)
        return lm.make_decode_caches(self.cfg, batch, max_len)

    def make_paged_caches(self, n_pages: int, page_size: int):
        """Empty paged KV pool (see ``models.stages.init_paged_cache``)."""
        if self.cfg.family == "audio":
            raise ValueError("paged caches support decoder-only LMs")
        return lm.make_paged_caches(self.cfg, n_pages, page_size)


def get_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct, no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_input_specs(cfg: ModelConfig, cell: ShapeCell):
    """Inputs of train_step: {tokens, labels[, patches | frames]}."""
    B, S = cell.global_batch, cell.seq_len
    if cfg.family == "audio":
        return {
            "frames": _sds((B, S, cfg.d_model), cfg.dtype),
            "tokens": _sds((B, WHISPER_DEC_LEN), jnp.int32),
            "labels": _sds((B, WHISPER_DEC_LEN), jnp.int32),
        }
    specs = {
        "tokens": _sds((B, S - cfg.n_patches), jnp.int32),
        "labels": _sds((B, S - cfg.n_patches), jnp.int32),
    }
    if cfg.n_patches:
        specs["patches"] = _sds((B, cfg.n_patches, cfg.d_model), cfg.dtype)
    return specs


def prefill_input_specs(cfg: ModelConfig, cell: ShapeCell):
    B, S = cell.global_batch, cell.seq_len
    if cfg.family == "audio":
        return {
            "frames": _sds((B, S, cfg.d_model), cfg.dtype),
            "tokens": _sds((B, WHISPER_DEC_LEN), jnp.int32),
        }
    specs = {"tokens": _sds((B, S - cfg.n_patches), jnp.int32)}
    if cfg.n_patches:
        specs["patches"] = _sds((B, cfg.n_patches, cfg.d_model), cfg.dtype)
    return specs


def decode_input_specs(cfg: ModelConfig, cell: ShapeCell):
    """Inputs of serve_step: one new token + caches over cell.seq_len."""
    B, S = cell.global_batch, cell.seq_len
    model = get_model(cfg)
    caches = jax.eval_shape(lambda: model.make_caches(B, S))
    return {
        "tokens": _sds((B, 1), jnp.int32),
        "pos": _sds((B,), jnp.int32),
        "caches": caches,
    }


def input_specs(cfg: ModelConfig, cell: ShapeCell):
    if cell.kind == "train":
        return train_input_specs(cfg, cell)
    if cell.kind == "prefill":
        return prefill_input_specs(cfg, cell)
    return decode_input_specs(cfg, cell)
