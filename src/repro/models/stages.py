"""Stage planner: compile a ModelConfig's per-layer pattern into grouped
``lax.scan`` stages so deep/heterogeneous stacks lower to small HLO.

A *site* is one layer's static description (mixer kind, mlp kind, rope theta,
window). Consecutive identical sites become a "run" stage (weights stacked over
the run, one scan). A repeating multi-site pattern (gemma2 local/global
alternation, zamba2 [5×ssm, shared-attn]) becomes a "pattern" stage: a scan
over repeats whose body unrolls one period.

Zamba2's shared attention block is one weight set applied at every
``shared_attn`` site (params live in ``params['shared']``, not in the stage).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, MIXER_SHARED_ATTN,
                                MIXER_SSM, ModelConfig)
from repro.layers.attention import (AttnOpts, attn_decode, attn_decode_paged,
                                    attn_forward, fill_kv_cache,
                                    init_attention, init_kv_cache,
                                    init_paged_kv_pool)
from repro.layers.mla import (MLAOpts, fill_mla_cache, init_mla,
                              init_mla_cache, mla_decode, mla_forward)
from repro.layers.mlp import init_mlp, mlp_forward
from repro.layers.moe import MoEOpts, init_moe, moe_forward
from repro.layers.norms import init_rms_norm, rms_norm
from repro.layers.ssm import (SSMOpts, init_ssm, init_ssm_cache, ssm_decode,
                              ssm_forward)


# ---------------------------------------------------------------------------
# Static plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerSite:
    mixer: str                  # global | local | ssm | shared_attn
    mlp: str                    # dense | moe | none
    d_ff: int = 0
    rope_theta: float = 10000.0
    window: int = 0

    @property
    def is_attn(self) -> bool:
        return self.mixer in (ATTN_GLOBAL, ATTN_LOCAL, MIXER_SHARED_ATTN)


@dataclasses.dataclass(frozen=True)
class Stage:
    kind: str                   # run | pattern
    sites: Tuple[LayerSite, ...]
    repeats: int


def _make_site(cfg: ModelConfig, i: int) -> LayerSite:
    mixer = cfg.layer_kinds()[i]
    if mixer == MIXER_SSM:
        return LayerSite(mixer=mixer, mlp="none")
    theta = cfg.rope_theta
    window = 0
    if mixer == ATTN_LOCAL:
        window = cfg.window
        if cfg.rope_local_theta:
            theta = cfg.rope_local_theta
    if mixer == MIXER_SHARED_ATTN:
        return LayerSite(mixer=mixer, mlp="dense", d_ff=cfg.d_ff,
                         rope_theta=theta)
    if cfg.moe is not None:
        if i < cfg.moe.first_k_dense:
            return LayerSite(mixer, "dense", cfg.moe.dense_d_ff or cfg.d_ff,
                             theta, window)
        return LayerSite(mixer, "moe", 0, theta, window)
    return LayerSite(mixer, "dense", cfg.d_ff, theta, window)


def plan_stages(cfg: ModelConfig) -> Tuple[Stage, ...]:
    sites = [_make_site(cfg, i) for i in range(cfg.n_layers)]
    stages = []
    i = 0
    # prefix exceptions (e.g. deepseek first_k_dense) peel off as run stages
    k_dense = cfg.moe.first_k_dense if cfg.moe is not None else 0
    while i < k_dense:
        j = i
        while j < k_dense and sites[j] == sites[i]:
            j += 1
        stages.append(Stage("run", (sites[i],), j - i))
        i = j
    rest = sites[i:]
    p = len(cfg.pattern)
    reps, rem = divmod(len(rest), p)
    body = rest[: reps * p]
    if reps:
        period = tuple(rest[:p])
        assert body == list(period) * reps, "pattern does not tile layer list"
        if p == 1:
            stages.append(Stage("run", period, reps))
        else:
            stages.append(Stage("pattern", period, reps))
    j = i + reps * p
    while j < cfg.n_layers:
        k = j
        while k < cfg.n_layers and sites[k] == sites[j]:
            k += 1
        stages.append(Stage("run", (sites[j],), k - j))
        j = k
    return tuple(stages)


# ---------------------------------------------------------------------------
# Opts helpers
# ---------------------------------------------------------------------------

def attn_opts(cfg: ModelConfig, site: LayerSite) -> AttnOpts:
    g = cfg.geometry
    return AttnOpts(
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, window=site.window, causal=cfg.causal,
        rope_theta=site.rope_theta, use_rope=cfg.use_rope,
        softcap=cfg.attn_softcap, qk_norm=cfg.qk_norm,
        query_scale=cfg.query_scale, attn_tp=cfg.attn_tp,
        decode_block_k=g.decode_block_k, flash_block_q=g.flash_block_q,
        flash_block_k=g.flash_block_k, kernel_force=g.kernel_force)


def mla_opts(cfg: ModelConfig) -> MLAOpts:
    return MLAOpts(n_heads=cfg.n_heads, cfg=cfg.mla,
                   rope_theta=cfg.rope_theta)


def ssm_opts(cfg: ModelConfig) -> SSMOpts:
    return SSMOpts(d_model=cfg.d_model, cfg=cfg.ssm,
                   tp=cfg.tp_mode == "tp")


def moe_opts(cfg: ModelConfig) -> MoEOpts:
    return MoEOpts(cfg=cfg.moe, act=cfg.act, norm_topk=cfg.moe.norm_topk)


# ---------------------------------------------------------------------------
# Per-site init
# ---------------------------------------------------------------------------

def _init_site(cfg: ModelConfig, site: LayerSite, key, dtype):
    if site.mixer == MIXER_SSM:
        k1, = jax.random.split(key, 1)
        return {"ssm": init_ssm(k1, ssm_opts(cfg), dtype),
                "norm1": jnp.zeros((cfg.d_model,), dtype)}
    if site.mixer == MIXER_SHARED_ATTN:
        return {}  # weights live in params["shared"]
    k1, k2 = jax.random.split(key)
    p = {"norm1": jnp.zeros((cfg.d_model,), dtype),
         "norm2": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.post_norm:
        p["norm1_post"] = jnp.zeros((cfg.d_model,), dtype)
        p["norm2_post"] = jnp.zeros((cfg.d_model,), dtype)
    if cfg.mla is not None:
        p["attn"] = init_mla(k1, cfg.d_model, mla_opts(cfg), dtype)
    else:
        p["attn"] = init_attention(k1, cfg.d_model, attn_opts(cfg, site), dtype)
    if site.mlp == "dense":
        p["mlp"] = init_mlp(k2, cfg.d_model, site.d_ff, dtype)
    elif site.mlp == "moe":
        p["moe"] = init_moe(k2, cfg.d_model, moe_opts(cfg), dtype)
    return p


def init_shared_block(cfg: ModelConfig, key, dtype):
    """Zamba2 shared attention+mlp block (one copy)."""
    site = LayerSite(MIXER_SHARED_ATTN, "dense", cfg.d_ff, cfg.rope_theta)
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.zeros((cfg.d_model,), dtype),
        "norm2": jnp.zeros((cfg.d_model,), dtype),
        "attn": init_attention(k1, cfg.d_model,
                               attn_opts(cfg, site), dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _stack_init(fn, key, n: int):
    """Initialize n copies with different keys, stacked on axis 0."""
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def init_stage(cfg: ModelConfig, stage: Stage, key, dtype):
    if stage.kind == "run":
        site = stage.sites[0]
        return _stack_init(lambda k: _init_site(cfg, site, k, dtype), key,
                           stage.repeats)
    # pattern: tuple over period positions, each stacked over repeats
    keys = jax.random.split(key, len(stage.sites))
    return tuple(
        _stack_init(lambda k, s=s: _init_site(cfg, s, k, dtype), kk,
                    stage.repeats)
        for s, kk in zip(stage.sites, keys))


# ---------------------------------------------------------------------------
# Per-site caches
# ---------------------------------------------------------------------------

def _site_cache_len(site: LayerSite, max_len: int) -> int:
    if site.window:
        return min(site.window, max_len)
    return max_len


def _init_site_cache(cfg: ModelConfig, site: LayerSite, batch: int,
                     max_len: int, dtype):
    if site.mixer == MIXER_SSM:
        return init_ssm_cache(batch, ssm_opts(cfg), dtype)
    if cfg.mla is not None:
        return init_mla_cache(batch, _site_cache_len(site, max_len),
                              mla_opts(cfg), dtype)
    return init_kv_cache(batch, _site_cache_len(site, max_len),
                         attn_opts(cfg, site), dtype, quant=cfg.kv_quant)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """Empty cache pytree mirroring the stage structure."""
    def stacked(site, n):
        one = _init_site_cache(cfg, site, batch, max_len, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), one)

    out = []
    for st in plan_stages(cfg):
        if st.kind == "run":
            out.append(stacked(st.sites[0], st.repeats))
        else:
            out.append(tuple(stacked(s, st.repeats) for s in st.sites))
    return tuple(out)


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int, dtype):
    """Empty paged KV pool pytree mirroring the stage structure: every
    attention site gets (n_pages, page_size, kv, hd) pool tensors instead
    of per-sequence (batch, L) rows. One logical page allocates the same
    physical row in every layer's pool, so a single block table per
    sequence addresses the whole stack. Windowed sites share the layout
    (the decode mask enforces the window); SSM/MLA archs have no paged
    form."""
    if cfg.ssm is not None or cfg.mla is not None:
        raise ValueError("paged KV caches support attention-family models "
                         "(SSM state and MLA latents are not paged)")

    def stacked(site, n):
        one = init_paged_kv_pool(n_pages, page_size, attn_opts(cfg, site),
                                 dtype, quant=cfg.kv_quant)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), one)

    out = []
    for st in plan_stages(cfg):
        if st.kind == "run":
            out.append(stacked(st.sites[0], st.repeats))
        else:
            out.append(tuple(stacked(s, st.repeats) for s in st.sites))
    return tuple(out)


# ---------------------------------------------------------------------------
# Site application
# ---------------------------------------------------------------------------

def _apply_site_full(cfg, site, p, shared, x, positions, mode, max_len, dtype,
                     clamp_window: bool = True):
    """Full-sequence site application.

    mode: "train" (no cache) | "prefill" (returns filled cache).
    ``clamp_window=False`` builds full-``max_len`` caches even for windowed
    sites (no ring) — the layout the paged splice expects.
    Returns (x', cache_or_None, aux).
    """
    aux = jnp.zeros((), jnp.float32)
    if site.mixer == MIXER_SSM:
        h = rms_norm(x, p["norm1"])
        y, (state, conv_tail) = ssm_forward(p["ssm"], h, ssm_opts(cfg))
        x = x + y
        cache = None
        if mode == "prefill":
            cache = {"state": state, "conv": conv_tail}
        return x, cache, aux

    pp = shared if site.mixer == MIXER_SHARED_ATTN else p
    h = rms_norm(x, pp["norm1"])
    if cfg.mla is not None:
        y, (c_kv, k_rope) = mla_forward(pp["attn"], h, positions,
                                        mla_opts(cfg))
    else:
        y, (k, v) = attn_forward(pp["attn"], h, positions,
                                 attn_opts(cfg, site))
    if cfg.post_norm:
        y = rms_norm(y, p["norm1_post"])
    x = x + y

    h = rms_norm(x, pp["norm2"])
    if site.mlp == "dense":
        y = mlp_forward(pp["mlp"], h, cfg.act)
    elif site.mlp == "moe":
        y, aux = moe_forward(pp["moe"], h, moe_opts(cfg))
    else:
        y = jnp.zeros_like(x)
    if cfg.post_norm:
        y = rms_norm(y, p["norm2_post"])
    x = x + y

    cache = None
    if mode == "prefill":
        L = _site_cache_len(site, max_len) if clamp_window else max_len
        if cfg.mla is not None:
            cache = fill_mla_cache(
                init_mla_cache(x.shape[0], L, mla_opts(cfg), dtype),
                c_kv, k_rope, positions)
        else:
            cache = fill_kv_cache(
                init_kv_cache(x.shape[0], L, attn_opts(cfg, site), dtype,
                              quant=cfg.kv_quant),
                k, v, positions)
    return x, cache, aux


def _apply_site_decode_paged(cfg, site, p, shared, x, positions, cache,
                             block_tables):
    """Decode one site against its paged pool (block-table indirection)."""
    aux = jnp.zeros((), jnp.float32)
    pp = shared if site.mixer == MIXER_SHARED_ATTN else p
    h = rms_norm(x, pp["norm1"])
    y, cache = attn_decode_paged(pp["attn"], h, positions, cache,
                                 block_tables, attn_opts(cfg, site))
    if cfg.post_norm:
        y = rms_norm(y, p["norm1_post"])
    x = x + y
    h = rms_norm(x, pp["norm2"])
    if site.mlp == "dense":
        y = mlp_forward(pp["mlp"], h, cfg.act)
    elif site.mlp == "moe":
        y, aux = moe_forward(pp["moe"], h, moe_opts(cfg))
    else:
        y = jnp.zeros_like(x)
    if cfg.post_norm:
        y = rms_norm(y, p["norm2_post"])
    return x + y, cache, aux


def _apply_site_decode(cfg, site, p, shared, x, positions, cache):
    aux = jnp.zeros((), jnp.float32)
    if site.mixer == MIXER_SSM:
        h = rms_norm(x, p["norm1"])
        y, cache = ssm_decode(p["ssm"], h, cache, ssm_opts(cfg))
        return x + y, cache, aux

    pp = shared if site.mixer == MIXER_SHARED_ATTN else p
    h = rms_norm(x, pp["norm1"])
    if cfg.mla is not None:
        y, cache = mla_decode(pp["attn"], h, positions, cache, mla_opts(cfg))
    else:
        y, cache = attn_decode(pp["attn"], h, positions, cache,
                               attn_opts(cfg, site))
    if cfg.post_norm:
        y = rms_norm(y, p["norm1_post"])
    x = x + y
    h = rms_norm(x, pp["norm2"])
    if site.mlp == "dense":
        y = mlp_forward(pp["mlp"], h, cfg.act)
    elif site.mlp == "moe":
        y, aux = moe_forward(pp["moe"], h, moe_opts(cfg))
    else:
        y = jnp.zeros_like(x)
    if cfg.post_norm:
        y = rms_norm(y, p["norm2_post"])
    return x + y, cache, aux


# ---------------------------------------------------------------------------
# Stage execution
# ---------------------------------------------------------------------------

def _seq_shard(x):
    """Sequence parallelism for remat residuals (Megatron-SP): constrain the
    carried (B, S, d) activation to (dp, "model", None) so the per-layer
    residual stack saved by checkpoint is sharded over the TP axis too —
    without this the stack is (L, B/dp, S, d) bf16 per device (12.9 GB on
    qwen3 train_4k), with it L·B·S·d/(dp·tp). No-op without a mesh.

    Applied at the END of each scan body (the loop-carry boundary): the
    saved residual is the body *input*, so only the carry needs the small
    sharding; compute inside the body runs on gathered activations."""
    from jax.sharding import PartitionSpec as P
    for dp in (("pod", "data"), "data", None):
        try:
            return jax.lax.with_sharding_constraint(x, P(dp, "model", None))
        except Exception:  # noqa: BLE001 - axis not in ambient mesh
            continue
    return x


def _gather_act(x):
    """Applied at the START of each scan body: re-gather the seq dim so the
    layer's dots see (dp, None, None) activations against model-sharded
    weights. Without this GSPMD resolves the axis conflict by all-gathering
    the WEIGHTS instead — measured 3.9 TB/device per step on llava-34B
    train_4k (f32 weight gathers ×60 layers in fwd+bwd loops)."""
    from jax.sharding import PartitionSpec as P
    for dp in (("pod", "data"), "data", None):
        try:
            return jax.lax.with_sharding_constraint(x, P(dp, None, None))
        except Exception:  # noqa: BLE001 - axis not in ambient mesh
            continue
    return x


def apply_stages(cfg: ModelConfig, params, x, positions, *,
                 mode: str = "train", caches=None, max_len: int = 0,
                 remat: bool = False, cache_dtype=None, block_tables=None,
                 clamp_window: bool = True):
    """Run all stages. mode: train | prefill | decode.

    ``block_tables`` (B, nb) switches decode to the paged-pool path (caches
    must come from ``init_paged_cache``). ``clamp_window=False`` makes
    prefill build full-length caches for windowed sites (paged splice
    layout). Returns (x, new_caches_or_None, aux_sum).
    """
    stages = plan_stages(cfg)
    shared = params.get("shared")
    dtype = cache_dtype or x.dtype
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    # Megatron-SP constraints only make sense with a TP axis in play
    use_sp = remat and cfg.tp_mode == "tp"

    def decode_site(site, p_i, c_i, xx):
        if block_tables is not None:
            return _apply_site_decode_paged(cfg, site, p_i, shared, xx,
                                            positions, c_i, block_tables)
        return _apply_site_decode(cfg, site, p_i, shared, xx, positions, c_i)

    for si, st in enumerate(stages):
        sp = params["stages"][si]
        sc = caches[si] if caches is not None else None

        if st.kind == "run":
            site = st.sites[0]
            if mode == "decode":
                def body(carry, xs, site=site):
                    xx, aux = carry
                    p_i, c_i = xs
                    xx, c_i, a = decode_site(site, p_i, c_i, xx)
                    return (xx, aux + a), c_i
            else:
                def body(carry, p_i, site=site):
                    xx, aux = carry
                    if use_sp:
                        xx = _gather_act(xx)
                    xx, c_i, a = _apply_site_full(cfg, site, p_i, shared, xx,
                                                  positions, mode, max_len,
                                                  dtype, clamp_window)
                    if use_sp:
                        xx = _seq_shard(xx)
                    return (xx, aux + a), c_i
            if remat:
                body = jax.checkpoint(body)
            xs = (sp, sc) if mode == "decode" else sp
            (x, aux_total), ys = jax.lax.scan(
                body, (x, aux_total), xs)
            new_caches.append(ys)
        else:  # pattern
            sites = st.sites
            if mode == "decode":
                def body(carry, xs, sites=sites):
                    xx, aux = carry
                    ps, cs = xs
                    outc = []
                    for site_i, (p_i, c_i) in zip(sites, zip(ps, cs)):
                        xx, c_i, a = decode_site(site_i, p_i, c_i, xx)
                        aux = aux + a
                        outc.append(c_i)
                    return (xx, aux), tuple(outc)
            else:
                def body(carry, ps, sites=sites):
                    xx, aux = carry
                    if use_sp:
                        xx = _gather_act(xx)
                    outc = []
                    for site_i, p_i in zip(sites, ps):
                        xx, c_i, a = _apply_site_full(
                            cfg, site_i, p_i, shared, xx, positions, mode,
                            max_len, dtype, clamp_window)
                        aux = aux + a
                        outc.append(c_i)
                    if use_sp:
                        xx = _seq_shard(xx)
                    return (xx, aux), tuple(outc)
            if remat:
                body = jax.checkpoint(body)
            xs = (sp, sc) if mode == "decode" else sp
            (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), xs)
            new_caches.append(ys)

    out_caches = tuple(new_caches) if mode in ("prefill", "decode") else None
    return x, out_caches, aux_total
