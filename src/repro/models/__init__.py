from repro.models.api import (Model, decode_input_specs, get_model,
                              input_specs, prefill_input_specs,
                              train_input_specs)
