"""Encoder-decoder model (whisper-tiny backbone).

The conv/mel frontend is a STUB per the assignment: inputs are precomputed
frame embeddings (B, F, d_model). Sinusoidal positions replace whisper's
learned embeddings (documented deviation, DESIGN.md §4).

decode_32k semantics for enc-dec: the 32k context is the *encoder output*
(cross-attention KV cache); decoder self-attention is bounded at
``dec_max_len`` (448), faithful to whisper's decoding window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN_GLOBAL, ModelConfig
from repro.layers.attention import (attn_decode, attn_forward, fill_kv_cache,
                                    init_attention, init_kv_cache)
from repro.layers.embeddings import embed, init_embedding, sinusoidal_positions
from repro.layers.mlp import init_mlp, mlp_forward
from repro.layers.norms import rms_norm
from repro.models.stages import LayerSite, attn_opts

DEC_MAX_LEN = 448


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    return cfg.replace(causal=False, use_rope=False,
                       n_layers=cfg.encoder.n_layers)


def _site(cfg) -> LayerSite:
    return LayerSite(ATTN_GLOBAL, "dense", cfg.d_ff, cfg.rope_theta)


def init_encdec(cfg: ModelConfig, key) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    ecfg = _enc_cfg(cfg)
    n_enc, n_dec = cfg.encoder.n_layers, cfg.n_layers
    keys = jax.random.split(key, 8)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": jnp.zeros((cfg.d_model,), pdt),
            "norm2": jnp.zeros((cfg.d_model,), pdt),
            "attn": init_attention(k1, cfg.d_model, attn_opts(ecfg, _site(ecfg)), pdt),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, pdt),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": jnp.zeros((cfg.d_model,), pdt),
            "norm2": jnp.zeros((cfg.d_model,), pdt),
            "norm3": jnp.zeros((cfg.d_model,), pdt),
            "self_attn": init_attention(k1, cfg.d_model, attn_opts(cfg, _site(cfg)), pdt),
            "cross_attn": init_attention(k2, cfg.d_model, attn_opts(ecfg, _site(ecfg)), pdt),
            "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, pdt),
        }

    return {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model, pdt),
        "enc_layers": jax.vmap(enc_layer)(jax.random.split(keys[1], n_enc)),
        "enc_norm": jnp.zeros((cfg.d_model,), pdt),
        "dec_layers": jax.vmap(dec_layer)(jax.random.split(keys[2], n_dec)),
        "final_norm": jnp.zeros((cfg.d_model,), pdt),
    }


def encode(cfg: ModelConfig, params, frames):
    """frames (B, F, d_model) precomputed embeddings -> (B, F, d_model)."""
    ecfg = _enc_cfg(cfg)
    dt = jnp.dtype(cfg.dtype)
    B, F, _ = frames.shape
    x = frames.astype(dt) + sinusoidal_positions(F, cfg.d_model, dt)[None]
    pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))
    opts = attn_opts(ecfg, _site(ecfg))

    def body(x, p):
        h = rms_norm(x, p["norm1"])
        y, _ = attn_forward(p["attn"], h, pos, opts)
        x = x + y
        h = rms_norm(x, p["norm2"])
        return x + mlp_forward(p["mlp"], h, cfg.act), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"])


def decoder_forward(cfg: ModelConfig, params, tokens, enc_out):
    """Teacher-forced decoder. tokens (B, St). Returns hidden (B, St, d)."""
    dt = jnp.dtype(cfg.dtype)
    B, St = tokens.shape
    F = enc_out.shape[1]
    x = embed(params["embed"], tokens).astype(dt)
    x = x + sinusoidal_positions(St, cfg.d_model, dt)[None]
    pos = jnp.broadcast_to(jnp.arange(St, dtype=jnp.int32)[None], (B, St))
    enc_pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))
    self_opts = attn_opts(cfg, _site(cfg))
    cross_opts = attn_opts(_enc_cfg(cfg), _site(_enc_cfg(cfg)))

    def body(x, p):
        h = rms_norm(x, p["norm1"])
        y, _ = attn_forward(p["self_attn"], h, pos, self_opts)
        x = x + y
        h = rms_norm(x, p["norm2"])
        y, _ = attn_forward(p["cross_attn"], h, pos, cross_opts,
                            kv_src=enc_out, kv_pos=enc_pos)
        x = x + y
        h = rms_norm(x, p["norm3"])
        return x + mlp_forward(p["mlp"], h, cfg.act), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return rms_norm(x, params["final_norm"])


def encdec_forward(cfg: ModelConfig, params, frames, tokens, remat=False):
    """Full training forward. Returns (hidden, aux=0)."""
    enc_out = encode(cfg, params, frames)
    h = decoder_forward(cfg, params, tokens, enc_out)
    return h, jnp.zeros((), jnp.float32)


def encdec_logits(cfg: ModelConfig, params, h):
    w = params["embed"]["tok"].T
    return jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype))


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def encdec_prefill(cfg: ModelConfig, params, frames, prompt):
    """Encode + run decoder prompt; build self- and cross-attention caches."""
    dt = jnp.dtype(cfg.dtype)
    enc_out = encode(cfg, params, frames)
    B, F, _ = enc_out.shape
    enc_pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))
    St = prompt.shape[1]
    x = embed(params["embed"], prompt).astype(dt)
    x = x + sinusoidal_positions(St, cfg.d_model, dt)[None]
    pos = jnp.broadcast_to(jnp.arange(St, dtype=jnp.int32)[None], (B, St))
    self_opts = attn_opts(cfg, _site(cfg))
    cross_opts = attn_opts(_enc_cfg(cfg), _site(_enc_cfg(cfg)))

    def body(x, p):
        h = rms_norm(x, p["norm1"])
        y, (k, v) = attn_forward(p["self_attn"], h, pos, self_opts)
        sc = fill_kv_cache(
            init_kv_cache(B, DEC_MAX_LEN, self_opts, dt), k, v, pos)
        x = x + y
        h = rms_norm(x, p["norm2"])
        y, (ck, cv) = attn_forward(p["cross_attn"], h, pos, cross_opts,
                                   kv_src=enc_out, kv_pos=enc_pos)
        x = x + y
        h = rms_norm(x, p["norm3"])
        x = x + mlp_forward(p["mlp"], h, cfg.act)
        return x, {"self": sc, "cross_k": ck, "cross_v": cv}

    x, caches = jax.lax.scan(body, x, params["dec_layers"])
    return rms_norm(x, params["final_norm"]), caches


def make_encdec_caches(cfg: ModelConfig, batch: int, enc_len: int):
    """Empty cache pytree for dry-run specs (cross KV over enc_len)."""
    dt = jnp.dtype(cfg.dtype)
    self_opts = attn_opts(cfg, _site(cfg))
    L = cfg.n_layers
    one_self = init_kv_cache(batch, DEC_MAX_LEN, self_opts, dt)
    return {
        "self": jax.tree.map(
            lambda a: jnp.zeros((L,) + a.shape, a.dtype), one_self),
        "cross_k": jnp.zeros((L, batch, enc_len, cfg.n_kv_heads,
                              cfg.resolved_head_dim), dt),
        "cross_v": jnp.zeros((L, batch, enc_len, cfg.n_kv_heads,
                              cfg.resolved_head_dim), dt),
    }


def encdec_decode(cfg: ModelConfig, params, caches, tokens, pos):
    """One decode token against self cache + fixed cross KV."""
    dt = jnp.dtype(cfg.dtype)
    B = tokens.shape[0]
    x = embed(params["embed"], tokens).astype(dt)
    posc = jnp.clip(pos, 0, DEC_MAX_LEN - 1)
    x = x + sinusoidal_positions(DEC_MAX_LEN, cfg.d_model, dt)[posc][:, None]
    positions = pos[:, None].astype(jnp.int32)
    self_opts = attn_opts(cfg, _site(cfg))
    cross_opts = attn_opts(_enc_cfg(cfg), _site(_enc_cfg(cfg)))
    F = caches["cross_k"].shape[2]

    def body(x, inp):
        p, sc, ck, cv = inp
        h = rms_norm(x, p["norm1"])
        y, sc = attn_decode(p["self_attn"], h, positions, sc, self_opts)
        x = x + y
        h = rms_norm(x, p["norm2"])
        # cross attention: fixed cache, all positions valid
        cross_cache = {"k": ck, "v": cv,
                       "pos": jnp.broadcast_to(
                           jnp.arange(F, dtype=jnp.int32)[None], (B, F))}
        y, _ = attn_decode(p["cross_attn"], h, positions, cross_cache,
                           cross_opts, update_cache=False)
        x = x + y
        h = rms_norm(x, p["norm3"])
        x = x + mlp_forward(p["mlp"], h, cfg.act)
        return x, sc

    x, new_self = jax.lax.scan(
        body, x, (params["dec_layers"], caches["self"],
                  caches["cross_k"], caches["cross_v"]))
    h = rms_norm(x, params["final_norm"])
    logits = encdec_logits(cfg, params, h)
    return logits, {"self": new_self, "cross_k": caches["cross_k"],
                    "cross_v": caches["cross_v"]}
