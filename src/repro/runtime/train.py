"""Training step builders.

``make_train_step``   — pjit/GSPMD path: shardings via runtime.sharding, XLA
                        inserts gradient reduction; microbatch gradient
                        accumulation via ``lax.scan``; optional remat.
``make_dp_train_step``— explicit shard_map DP path used to demonstrate and
                        test the int8-compressed gradient all-reduce with
                        error feedback.

TrainState is a plain dict: {params, opt_state, residuals?, step}.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.api import Model
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim.compress import compressed_psum, init_residuals
from repro.runtime.losses import chunked_xent
from repro.runtime.sharding import (batch_specs, dp_axes, named, param_specs,
                                    shard_map)


@dataclasses.dataclass(frozen=True)
class TrainOpts:
    opt: AdamWConfig = AdamWConfig()
    microbatches: int = 1          # gradient-accumulation splits
    remat: bool = False
    loss_chunk: int = 512
    aux_weight: float = 0.001      # MoE load-balance weight
    compress_grads: bool = False   # int8 DP all-reduce (shard_map path only)


def make_loss_fn(model: Model, opts: TrainOpts):
    cfg = model.cfg

    def loss_fn(params, batch):
        h, aux = model.forward(params, batch, remat=opts.remat)
        if cfg.family == "audio":
            labels = batch["labels"]
        else:
            labels = batch["labels"]
        loss = chunked_xent(cfg, params, h, labels, chunk=opts.loss_chunk)
        return loss + opts.aux_weight * aux, {"xent": loss, "aux": aux}

    return loss_fn


def init_train_state(model: Model, key, opts: Optional[TrainOpts] = None):
    opts = opts if opts is not None else TrainOpts()
    params = model.init(key)
    state = {"params": params, "opt_state": init_opt_state(params),
             "step": jnp.zeros((), jnp.int32)}
    if opts.compress_grads:
        state["residuals"] = init_residuals(params)
    return state


def _split_micro(batch, n: int):
    """(B, ...) -> (n, B/n, ...) for scan-based grad accumulation."""
    return jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def make_train_step(model: Model, opts: Optional[TrainOpts] = None,
                    grad_specs=None):
    """GSPMD train step: state/batch shardings supplied at jit time.

    ``grad_specs``: optional PartitionSpec pytree (usually the ZeRO-1
    optimizer-state specs) the gradients are constrained to before the
    update — forces the DP reduce-scatter to happen in bf16 on the grads
    instead of materializing fp32 full-weight transients in the update.
    """
    opts = opts if opts is not None else TrainOpts()
    loss_fn = make_loss_fn(model, opts)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _constrain_grads(grads):
        if grad_specs is None:
            return grads
        try:
            flat_g, td = jax.tree.flatten(grads)
            flat_s = td.flatten_up_to(grad_specs)
            return td.unflatten([
                jax.lax.with_sharding_constraint(g, s)
                for g, s in zip(flat_g, flat_s)])
        except Exception:  # noqa: BLE001 - no mesh context (CPU tests)
            return grads

    def train_step(state, batch):
        params = state["params"]
        if opts.microbatches > 1:
            micro = _split_micro(batch, opts.microbatches)

            def acc_body(carry, mb):
                gsum, lsum = carry
                (l, m), g = grad_fn(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (gsum, lsum), ms = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / opts.microbatches, gsum)
            loss = lsum / opts.microbatches
            metrics = jax.tree.map(lambda x: jnp.mean(x), ms)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        grads = _constrain_grads(grads)
        new_params, new_opt, om = adamw_update(
            opts.opt, grads, state["opt_state"], params)
        new_state = dict(state, params=new_params, opt_state=new_opt,
                         step=state["step"] + 1)
        return new_state, {"loss": loss, **metrics, **om}

    return train_step


def jit_train_step(model: Model, mesh: Mesh, opts: TrainOpts,
                   state_shape, batch_shape):
    """jit with explicit in/out shardings over the production mesh."""
    pspecs = param_specs(model.cfg, state_shape["params"], mesh)
    opt_specs = {
        "mu": pspecs, "nu": pspecs, "count": P()}
    state_specs = {"params": pspecs, "opt_state": opt_specs, "step": P()}
    if "residuals" in state_shape:
        state_specs["residuals"] = pspecs
    bspecs = batch_specs(model.cfg, batch_shape, mesh)
    step = make_train_step(model, opts)
    return jax.jit(
        step,
        in_shardings=(named(mesh, state_specs), named(mesh, bspecs)),
        out_shardings=(named(mesh, state_specs), None),
        donate_argnums=(0,)), state_specs, bspecs


# ---------------------------------------------------------------------------
# Explicit-DP path with compressed gradient exchange
# ---------------------------------------------------------------------------

def make_dp_train_step(model: Model, mesh: Mesh,
                       opts: Optional[TrainOpts] = None):
    """shard_map data-parallel step: grads all-reduced explicitly, optionally
    int8-compressed with error feedback. Params replicated across DP."""
    opts = opts if opts is not None else TrainOpts()
    loss_fn = make_loss_fn(model, opts)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    axis = "data"

    def shard_step(state, batch):
        (loss, metrics), grads = grad_fn(state["params"], batch)
        if opts.compress_grads:
            grads, new_res = compressed_psum(grads, state["residuals"], axis)
        else:
            grads = jax.tree.map(
                lambda g: jax.lax.pmean(g, axis), grads)
            new_res = state.get("residuals")
        loss = jax.lax.pmean(loss, axis)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, axis), metrics)
        new_params, new_opt, om = adamw_update(
            opts.opt, grads, state["opt_state"], state["params"])
        new_state = dict(state, params=new_params, opt_state=new_opt,
                         step=state["step"] + 1)
        if new_res is not None:
            new_state["residuals"] = new_res
        return new_state, {"loss": loss, **metrics, **om}

    rep = P()  # replicated state

    def step(state, batch):
        state_specs = jax.tree.map(lambda _: rep, state)
        batch_sp = jax.tree.map(lambda _: P(axis), batch)
        metric_specs = {k: rep for k in
                        ("loss", "xent", "aux", "grad_norm", "lr")}
        return shard_map(
            shard_step, mesh,
            in_specs=(state_specs, batch_sp),
            out_specs=(state_specs, metric_specs))(state, batch)

    return jax.jit(step)
