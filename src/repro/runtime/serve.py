"""Serving runtime: prefill + decode steps with sharded KV caches, a
continuous-batching request queue, and the BAaaS service wrapper.

``make_serve_step`` builds the jit'd one-token decode step the dry-run
lowers for decode_32k / long_500k cells.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.api import Model
from repro.runtime.sharding import (batch_specs, cache_specs, dp_axes, named,
                                    param_specs)


def make_serve_step(model: Model):
    """serve_step(params, caches, tokens, pos) -> (logits, caches)."""

    def serve_step(params, caches, tokens, pos):
        return model.decode(params, caches, tokens, pos)

    return serve_step


def make_prefill_step(model: Model, max_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)
    return prefill_step


def jit_serve_step(model: Model, mesh: Mesh, batch: int, cache_len: int,
                   params_shape, caches_shape):
    """jit with shardings; seq-sharding kicks in for batch=1 long-context."""
    cfg = model.cfg
    pspecs = param_specs(cfg, params_shape, mesh)
    dp_total = np.prod([mesh.shape[a] for a in mesh.axis_names
                        if a in ("pod", "data")])
    seq_shard = batch % int(dp_total) != 0
    cspecs = cache_specs(cfg, caches_shape, mesh, batch, seq_shard=seq_shard)
    dp = dp_axes(mesh)
    tok_spec = P(dp, None) if batch % int(dp_total) == 0 else P(None, None)
    pos_spec = P(dp) if batch % int(dp_total) == 0 else P(None)
    step = make_serve_step(model)
    jitted = jax.jit(
        step,
        in_shardings=(named(mesh, pspecs), named(mesh, cspecs),
                      jax.sharding.NamedSharding(mesh, tok_spec),
                      jax.sharding.NamedSharding(mesh, pos_spec)),
        out_shardings=None,
        donate_argnums=(1,))
    return jitted, {"params": pspecs, "caches": cspecs}


# ---------------------------------------------------------------------------
# Continuous batching engine (BAaaS dataplane)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _prefill_jit(model: Model, max_len: int):
    """One jitted prefill per (model, max_len), shared across engines —
    a fleet spinning an engine up on a freshly woken device must not pay a
    new trace/compile mid-hand-off. (Model is a frozen dataclass of config
    only, so the cache key is cheap and value-equal across engines.)

    Bounded: the engine is hypervisor-independent, so prefill programs
    live in this small LRU rather than the RC3E ProgramCache the gateway/
    fleet route the decode program through; 8 (model, max_len) pairs cover
    any realistic co-resident serving mix without pinning executables for
    every config a long-lived process ever touched."""
    step = make_prefill_step(model, max_len)
    return jax.jit(lambda p, toks: step(p, {"tokens": toks}))


@functools.partial(jax.jit, donate_argnums=(0,))
def _splice_slot(full, one, slot):
    """Write a batch-1 prefill cache into row ``slot`` of shared caches.
    The old cache tree is donated: only one slot row changes, and without
    donation every admission would copy the entire fleet of KV buffers."""
    return jax.tree.map(
        lambda f, o: f.at[:, slot].set(o[:, 0].astype(f.dtype)), full, one)


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    tenant: str = "default"
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    submitted_at: float = dataclasses.field(default_factory=time.monotonic)
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None


class BatchingEngine:
    """Slot-based continuous batching: up to ``n_slots`` concurrent requests
    share one decode program; prefill happens per-request into its slot.

    Requests are tenant-tagged: each tenant has its own FIFO queue, and
    admission round-robins across tenants so one tenant's backlog cannot
    starve the others. A tenant's *share* (max concurrent slots, set from
    its vSlice size by the serving gateway) caps how many engine slots it
    may occupy at once — slice-aware scheduling on a shared device.

    Greedy decoding (argmax) — deterministic, testable.
    """

    # contexts shorter than this prefill through the (already compiled)
    # decode program; longer ones get the batched prefill call
    PREFILL_MIN_TOKENS = 4

    def __init__(self, model: Model, params, n_slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None,
                 prefill_mode: str = "batched",
                 id_counter: Optional[Iterator[int]] = None):
        # Slot recycling relies on position-masked KV caches (stale entries
        # carry positions > current and are masked out). SSM state has no
        # such masking, so the engine serves attention-family models; SSM
        # serving uses jit_serve_step directly with per-batch state resets.
        if model.cfg.ssm is not None:
            raise ValueError("BatchingEngine supports attention-family "
                             "models; use jit_serve_step for SSM archs")
        if prefill_mode not in ("batched", "legacy"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.prefill_mode = prefill_mode
        self._queues: "Dict[str, queue.Queue[Request]]" = {}
        self._tenant_share: Dict[str, int] = {}      # max concurrent slots
        self._rr_offset = 0                          # round-robin cursor
        # request ids: a fleet passes one shared counter to every engine so
        # ids stay unique across devices (the hypervisor audit log and a
        # live hand-off both key on them)
        self._ids = id_counter if id_counter is not None \
            else itertools.count()
        self.caches = model.make_caches(n_slots, max_len)
        self._slots: List[Optional[Request]] = [None] * n_slots
        self._pos = np.zeros((n_slots,), np.int32)
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode(p, c, t, pos))
        # batched slot prefill: model.prefill over the prompt, spliced into
        # this slot's row of the shared caches. Padding a prefill past the
        # shortest layer cache (a local-attention window) would evict real
        # in-window history, so pad buckets are clamped to it.
        self._prefill = _prefill_jit(model, max_len)
        self._splice = _splice_slot
        lens = [l.shape[2] for l in jax.tree.leaves(self.caches)
                if getattr(l, "ndim", 0) >= 3]
        self._min_cache_len = min(lens) if lens else max_len
        self.steps = 0
        # hooks for the serving gateway: called after every decode step /
        # on every request completion
        self.on_step: Optional[Callable[[Dict[str, int], float], None]] = None
        self.on_finish: Optional[Callable[[Request], None]] = None

    def use_program(self, compiled: Callable) -> None:
        """Swap in an externally compiled decode executable — the serving
        gateway routes compilation through the hypervisor's Reconfigurator
        so the decode program lives in the RC3E program cache (and PR swaps
        bind it to each tenant's vSlice)."""
        self._decode = compiled

    def set_tenant_share(self, tenant: str, max_slots: Optional[int]) -> None:
        """Cap a tenant's concurrent engine slots (None removes the cap)."""
        if max_slots is None:
            self._tenant_share.pop(tenant, None)
        else:
            self._tenant_share[tenant] = max(1, int(max_slots))

    def submit(self, prompt, max_new_tokens: int = 16,
               tenant: str = "default") -> Request:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError("empty prompt: a request needs at least one "
                             "prompt token to seed decoding")
        req = Request(next(self._ids), prompt, max_new_tokens, tenant=tenant)
        self._queues.setdefault(tenant, queue.Queue()).put(req)
        return req

    def resume(self, req: Request) -> Request:
        """Requeue a request drained from another engine (live migration):
        its already-generated tokens are preserved and replayed as a prompt
        prefix when the request is re-admitted (see ``_admit``)."""
        self._queues.setdefault(req.tenant, queue.Queue()).put(req)
        return req

    # ---------------- tenant bookkeeping ----------------
    def _drain_queue(self, tenant: str) -> List[Request]:
        """Remove and return all of a tenant's queued requests."""
        q = self._queues.pop(tenant, None)
        drained: List[Request] = []
        while q is not None:
            try:
                drained.append(q.get_nowait())
            except queue.Empty:
                break
        return drained

    def cancel_queued(self, tenant: str) -> List[Request]:
        """Drop a tenant's not-yet-admitted requests (e.g. its serving
        session closed). Returns the cancelled requests, marked done."""
        dropped = self._drain_queue(tenant)
        for r in dropped:
            r.finished_at = time.monotonic()
            r.done.set()
        return dropped

    def drain_tenant(self, tenant: str) -> List[Request]:
        """Evict a tenant's in-flight and queued requests for live hand-off
        to another engine. In-flight requests keep their generated tokens
        (``resume`` on the target replays them as a prompt prefix); nothing
        is marked done. Freed slots' stale cache rows stay position-masked
        until recycled. Returns the requests, in-flight first."""
        moved: List[Request] = []
        for i, r in enumerate(self._slots):
            if r is not None and r.tenant == tenant:
                self._slots[i] = None
                self._pos[i] = 0
                moved.append(r)
        moved.extend(self._drain_queue(tenant))
        return moved

    def active_by_tenant(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for r in self._slots:
            if r is not None:
                counts[r.tenant] = counts.get(r.tenant, 0) + 1
        return counts

    def queued_by_tenant(self) -> Dict[str, int]:
        return {t: q.qsize() for t, q in self._queues.items()}

    def _pop_next_request(self) -> Optional[Request]:
        """Round-robin over tenants: next queued request from a tenant with
        spare share, starting after the last admitted tenant."""
        tenants = list(self._queues.keys())
        if not tenants:
            return None
        active = self.active_by_tenant()
        n = len(tenants)
        for k in range(n):
            t = tenants[(self._rr_offset + k) % n]
            share = self._tenant_share.get(t, self.n_slots)
            if active.get(t, 0) >= share:
                continue
            try:
                req = self._queues[t].get_nowait()
            except queue.Empty:
                continue
            self._rr_offset = (self._rr_offset + k + 1) % n
            return req
        return None

    # ---------------- engine loop ----------------
    def _admit(self):
        for slot in range(self.n_slots):
            if self._slots[slot] is not None:
                continue
            req = self._pop_next_request()
            if req is None:
                return
            self._slots[slot] = req
            # a request resumed after live migration replays prompt +
            # already-generated tokens so decode continues where it left off
            toks = req.prompt if not req.out_tokens else np.concatenate(
                [req.prompt, np.asarray(req.out_tokens, np.int32)])
            ctx = toks[:-1]
            if len(ctx) >= self.PREFILL_MIN_TOKENS \
                    and self.prefill_mode == "batched":
                self._prefill_slot(slot, ctx)
            else:
                # short context (or legacy mode): feed tokens through the
                # already-compiled decode program, slot-isolated
                for i, t in enumerate(ctx):
                    self._step_single(slot, int(t), i)
            self._pos[slot] = len(toks) - 1
            req._next_input = int(toks[-1])

    def _prefill_slot(self, slot: int, ctx: np.ndarray):
        """Prefill a slot's context with ONE batched call instead of one
        full-batch decode per prompt token (O(S·n_slots) -> O(S) work,
        O(1) dispatches). Lengths are padded to power-of-two buckets to
        bound recompiles; padded positions carry pos >= len(ctx), so they
        are causally masked during decode and overwritten in place when
        generation reaches them."""
        n = len(ctx)
        bucket = 8
        while bucket < n:
            bucket *= 2
        pad = max(n, min(bucket, self._min_cache_len))
        toks = np.zeros((1, pad), np.int32)
        toks[0, :n] = ctx
        _, slot_caches = self._prefill(self.params, jnp.asarray(toks))
        self.caches = self._splice(self.caches, slot_caches, slot)

    def _step_single(self, slot: int, token: int, pos: int):
        tokens = np.zeros((self.n_slots, 1), np.int32)
        tokens[slot, 0] = token
        posv = self._pos.copy()
        posv[slot] = pos
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(tokens),
            jnp.asarray(posv))
        return np.asarray(logits)

    def step(self) -> int:
        """One engine iteration: admit + one decode step for active slots.
        Returns number of active slots."""
        self._admit()
        active = [i for i, r in enumerate(self._slots) if r is not None]
        if not active:
            return 0
        tokens = np.zeros((self.n_slots, 1), np.int32)
        for i in active:
            tokens[i, 0] = self._slots[i]._next_input
        t0 = time.monotonic()
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(tokens),
            jnp.asarray(self._pos))
        logits = np.asarray(logits)
        step_ms = (time.monotonic() - t0) * 1e3
        self.steps += 1
        if self.on_step is not None:
            self.on_step(self.active_by_tenant(), step_ms)
        for i in active:
            req = self._slots[i]
            nxt = int(np.argmax(logits[i, 0]))
            if req.first_token_at is None:
                req.first_token_at = time.monotonic()
            req.out_tokens.append(nxt)
            req._next_input = nxt
            self._pos[i] += 1
            eos = self.eos_id is not None and nxt == self.eos_id
            if len(req.out_tokens) >= req.max_new_tokens or eos \
                    or self._pos[i] >= self.max_len - 1:
                req.finished_at = time.monotonic()
                req.done.set()
                self._slots[i] = None
                self._pos[i] = 0
                if self.on_finish is not None:
                    self.on_finish(req)
        return len(active)

    def idle(self) -> bool:
        return all(r is None for r in self._slots) and \
            all(q.empty() for q in self._queues.values())

    def run_until_idle(self, max_steps: int = 10000):
        for _ in range(max_steps):
            if self.step() == 0 and \
                    all(q.empty() for q in self._queues.values()):
                return
