"""Serving runtime: prefill + decode steps with sharded KV caches, a
continuous-batching request queue, and the BAaaS service wrapper.

``make_serve_step`` builds the jit'd one-token decode step the dry-run
lowers for decode_32k / long_500k cells.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import itertools
import threading
import time
from typing import Any, Callable, Deque, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.analysis.lifecycle import sanitizer
from repro.configs.base import ModelConfig
from repro.models.api import Model
from repro.runtime.paged import PagePoolManager, default_pool_pages
from repro.runtime.sharding import (batch_specs, cache_specs, dp_axes, named,
                                    param_specs)


def make_serve_step(model: Model):
    """serve_step(params, caches, tokens, pos) -> (logits, caches)."""

    def serve_step(params, caches, tokens, pos):
        return model.decode(params, caches, tokens, pos)

    return serve_step


def make_paged_serve_step(model: Model):
    """serve_step over the paged pool: extra (B, nb) block-table operand."""

    def serve_step(params, caches, tokens, pos, block_tables):
        return model.decode_paged(params, caches, tokens, pos, block_tables)

    return serve_step


def make_prefill_step(model: Model, max_len: int, clamp_window: bool = True):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len,
                             clamp_window=clamp_window)
    return prefill_step


def jit_serve_step(model: Model, mesh: Mesh, batch: int, cache_len: int,
                   params_shape, caches_shape):
    """jit with shardings; seq-sharding kicks in for batch=1 long-context."""
    cfg = model.cfg
    pspecs = param_specs(cfg, params_shape, mesh)
    dp_total = np.prod([mesh.shape[a] for a in mesh.axis_names
                        if a in ("pod", "data")])
    seq_shard = batch % int(dp_total) != 0
    cspecs = cache_specs(cfg, caches_shape, mesh, batch, seq_shard=seq_shard)
    dp = dp_axes(mesh)
    tok_spec = P(dp, None) if batch % int(dp_total) == 0 else P(None, None)
    pos_spec = P(dp) if batch % int(dp_total) == 0 else P(None)
    step = make_serve_step(model)
    jitted = jax.jit(
        step,
        in_shardings=(named(mesh, pspecs), named(mesh, cspecs),
                      jax.sharding.NamedSharding(mesh, tok_spec),
                      jax.sharding.NamedSharding(mesh, pos_spec)),
        out_shardings=None,
        donate_argnums=(1,))
    return jitted, {"params": pspecs, "caches": cspecs}


# ---------------------------------------------------------------------------
# Continuous batching engine (BAaaS dataplane)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _prefill_jit(model: Model, max_len: int, full_len: bool = False):
    """One jitted prefill per (model, max_len, layout), shared across
    engines — a fleet spinning an engine up on a freshly woken device must
    not pay a new trace/compile mid-hand-off. (Model is a frozen dataclass
    of config only, so the cache key is cheap and value-equal across
    engines.) ``full_len`` builds non-ring full-length caches for windowed
    sites — the layout the paged page-splice consumes.

    Bounded: the engine is hypervisor-independent, so prefill programs
    live in this small LRU rather than the RC3E ProgramCache the gateway/
    fleet route the decode program through; 8 (model, max_len) pairs cover
    any realistic co-resident serving mix without pinning executables for
    every config a long-lived process ever touched."""
    step = make_prefill_step(model, max_len, clamp_window=not full_len)
    return jax.jit(lambda p, toks: step(p, {"tokens": toks}))


@functools.partial(jax.jit, donate_argnums=(0,))
def _splice_slot(full, one, slot):
    """Write a batch-1 prefill cache into row ``slot`` of shared caches.
    The old cache tree is donated: only one slot row changes, and without
    donation every admission would copy the entire fleet of KV buffers."""
    return jax.tree.map(
        lambda f, o: f.at[:, slot].set(o[:, 0].astype(f.dtype)), full, one)


@functools.partial(jax.jit, donate_argnums=(0,), static_argnames=("start",))
def _splice_pages(pool, one, pages, start: int):
    """Scatter a batch-1 full-length prefill cache into pool pages: block
    ``start + i`` of the context lands in page ``pages[i]``. Pool leaves
    are (L, P, ps, ...), prefill leaves (L, 1, max_len, ...); the pool tree
    is donated (only the touched pages change)."""
    nb = pages.shape[0]

    def put(pl_leaf, d_leaf):
        ps = pl_leaf.shape[2]
        seg = jax.lax.dynamic_slice_in_dim(d_leaf[:, 0], start * ps, nb * ps,
                                           axis=1)
        seg = seg.reshape((d_leaf.shape[0], nb, ps) + d_leaf.shape[3:])
        return pl_leaf.at[:, pages].set(seg.astype(pl_leaf.dtype))

    return jax.tree.map(put, pool, one)


@functools.partial(jax.jit, donate_argnums=(0,))
def _invalidate_pool_pages(pool, pages):
    """Reset the ``pos`` metadata of ``pages`` to -1 across every layer's
    pool. A recycled page still carries its previous occupant's positions;
    for the new owner those can look like valid causal history (stale
    K/V leaking into attention), so every allocation that does not
    overwrite the whole page must invalidate it first. Only the position
    leaves change — k/v content is dead weight once pos is -1."""
    def inv(path, leaf):
        if getattr(path[-1], "key", None) == "pos":
            return leaf.at[:, pages].set(-1)
        return leaf
    return jax.tree_util.tree_map_with_path(inv, pool)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scrub_pool_pages(pool, pages):
    """Zero-on-free: restore ``pages`` to their init state across every
    layer's pool — k/v content to 0, ``pos`` to -1, quantization scales to
    1. ``_invalidate_pool_pages`` only resets pos, which hides stale K/V
    from *attention* (masked) but not from ``export_request_pages``, whose
    whole-page gather would hand a previous tenant's residual K/V values
    to whoever receives the migration snapshot. One batched call per
    engine flush, not one per page."""
    def scrub(path, leaf):
        key = getattr(path[-1], "key", None)
        if key == "pos":
            return leaf.at[:, pages].set(-1)
        if key in ("k_scale", "v_scale"):
            return leaf.at[:, pages].set(1)
        return leaf.at[:, pages].set(0)
    return jax.tree_util.tree_map_with_path(scrub, pool)


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_page(pool, src, dst):
    """Copy-on-write detach: duplicate page ``src`` into ``dst`` across
    every layer's pool (leaves are (L, P, ps, ...); axis 1 is the page)."""
    return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), pool)


@jax.jit
def _argmax_tokens(logits):
    """Greedy sampling ON DEVICE: reduce (n_slots, 1, vocab) logits to
    (n_slots,) int32 token ids before they cross to the host. The engine
    step loop used to pull the full logits tensor host-side and argmax in
    numpy — a vocab-sized D2H transfer per decode step (n_slots * vocab *
    4 bytes, ~0.5 MB at vocab 32k / 4 slots) for 4 bytes of answer per
    slot."""
    return jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, donate_argnums=(0,))
def _import_pages(pool, payload, pages):
    """Scatter a migrated request's page payload (leaves (L, nb, ps, ...))
    into freshly allocated pages of this engine's pool."""
    return jax.tree.map(
        lambda pl_leaf, seg: pl_leaf.at[:, pages].set(
            seg.astype(pl_leaf.dtype)), pool, payload)


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    tenant: str = "default"
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    submitted_at: float = dataclasses.field(default_factory=time.monotonic)
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    finish_reason: Optional[str] = None   # "eos" | "length" | "cancelled"


@dataclasses.dataclass
class _PendingPrefill:
    """A slot admitted by the event-driven loop whose prompt prefill has
    not yet been spliced into the shared caches. The batched prefill is
    COMPUTED once at admission (one compiled call — recomputing it per
    chunk would multiply the work the chunking is meant to hide) but the
    result is only BUFFERED here; the slot is accounted ``prefill_chunk``
    context tokens per engine event and joins decode when the accounted
    chunks cover the context. Until then the slot is excluded from decode
    and page-write preparation (paged slots sit at pos -1: their decode
    rows write the null page)."""
    chunks_left: int
    buf: Any                    # batch-1 prefill caches (None: nothing to splice)
    plan: Any                   # paged AdmitPlan (None on dense engines)
    ctx_len: int                # len(prompt + replayed tokens)
    last_token: int             # final context token -> first decode input


def _req_event(req: Request, event: str) -> None:
    """Drive the request lifecycle machine (RC3E_SANITIZE=1). Keyed by the
    per-request ``scope()`` token stamped at submit time — NOT request_id,
    which is only unique within one id_counter (standalone engines each
    start at 0) — so the key travels with the object across a live
    hand-off between engines."""
    tok = getattr(req, "_san", None)
    if tok is not None:
        sanitizer.emit("request", tok, event)


class BatchingEngine:
    """Slot-based continuous batching: up to ``n_slots`` concurrent requests
    share one decode program; prefill happens per-request into its slot.

    Requests are tenant-tagged: each tenant has its own FIFO queue, and
    admission runs weighted deficit round-robin across tenants (see
    ``_pop_next_request``) so one tenant's backlog — even a deliberate
    long-prompt flood — cannot starve the others or inflate their latency
    past the fairness bound. A tenant's *share* (max concurrent slots, set
    from its vSlice size by the serving gateway) caps how many engine
    slots it may occupy at once — slice-aware scheduling on a shared
    device.

    Two cache layouts:

    * dense (default): per-slot (n_slots, max_len) KV rows, capacity fixed
      at construction;
    * ``paged=True``: one shared page pool (``cache_pages`` pages of
      ``page_size`` positions) virtualized across slots by block tables.
      Admission allocates pages (and *defers* — queues — when the pool or
      the tenant's page budget is exhausted, instead of OOMing), slots
      grow page-by-page as decoding proceeds, and requests of one tenant
      with a common prompt prefix share refcounted pages copy-on-write.
      A slot that cannot grow is preempted back to the queue head (its
      generated tokens survive via prompt-prefix replay).

    Greedy decoding (argmax) — deterministic, testable.
    """

    # contexts shorter than this prefill through the (already compiled)
    # decode program; longer ones get the batched prefill call
    PREFILL_MIN_TOKENS = 4

    def __init__(self, model: Model, params, n_slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None,
                 prefill_mode: str = "batched",
                 id_counter: Optional[Iterator[int]] = None,
                 paged: bool = False, page_size: int = 16,
                 cache_pages: Optional[int] = None,
                 scrub_on_free: bool = True):
        # Slot recycling relies on position-masked KV caches (stale entries
        # carry positions > current and are masked out). SSM state has no
        # such masking, so the engine serves attention-family models; SSM
        # serving uses jit_serve_step directly with per-batch state resets.
        if model.cfg.ssm is not None:
            raise ValueError("BatchingEngine supports attention-family "
                             "models; use jit_serve_step for SSM archs")
        if prefill_mode not in ("batched", "legacy"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.prefill_mode = prefill_mode
        self.paged = paged
        self._queues: "Dict[str, Deque[Request]]" = {}
        self._qlock = threading.Lock()
        self._tenant_share: Dict[str, int] = {}      # max concurrent slots
        self._tenant_pages: Dict[str, int] = {}      # max pool pages held
        self._tenant_weight: Dict[str, float] = {}   # fair-share weight
        self._deficit: Dict[str, float] = {}         # DRR credit per tenant
        self._rr_offset = 0                          # DRR tie-break cursor
        # request ids: a fleet passes one shared counter to every engine so
        # ids stay unique across devices (the hypervisor audit log and a
        # live hand-off both key on them)
        self._ids = id_counter if id_counter is not None \
            else itertools.count()
        self._slots: List[Optional[Request]] = [None] * n_slots
        # slots admitted asynchronously whose prefill is still being
        # accounted chunk-by-chunk (event-driven loop only; the lockstep
        # path admits synchronously and never populates this)
        self._prefilling: Dict[int, _PendingPrefill] = {}
        self.steps = 0
        self.preemptions = 0
        self.scrub_ms = 0.0        # cumulative zero-on-free dispatch cost
        self._scope = sanitizer.scope()      # slot-machine key namespace
        # device block-table cache, keyed on the pool's version counter:
        # steady-state decode steps reuse it instead of re-uploading the
        # (n_slots, max_blocks) table every token
        self._bt_cache = None
        self._bt_version = -1
        if paged:
            if model.cfg.mla is not None:
                raise ValueError("paged KV caches support plain-attention "
                                 "models (MLA latents are not paged)")
            if max_len % page_size:
                raise ValueError(f"max_len {max_len} must be a multiple of "
                                 f"page_size {page_size}")
            self.page_size = page_size
            max_blocks = max_len // page_size
            if cache_pages is None:
                cache_pages = default_pool_pages(n_slots, max_blocks)
            self.cache_pages = cache_pages
            self.pool = PagePoolManager(cache_pages, page_size, n_slots,
                                        max_blocks,
                                        scrub_on_free=scrub_on_free)
            self.caches = model.make_paged_caches(cache_pages, page_size)
            self._pos = np.full((n_slots,), -1, np.int32)
            step = make_paged_serve_step(model)
            self._decode = jax.jit(step)
            self._prefill = _prefill_jit(model, max_len, full_len=True)
            self._min_cache_len = max_len      # full-length pools, no ring
        else:
            self.page_size = 0
            self.cache_pages = 0
            self.pool = None
            self.caches = model.make_caches(n_slots, max_len)
            self._pos = np.zeros((n_slots,), np.int32)
            self._decode = jax.jit(
                lambda p, c, t, pos: model.decode(p, c, t, pos))
            # batched slot prefill: model.prefill over the prompt, spliced
            # into this slot's row of the shared caches. Padding a prefill
            # past the shortest layer cache (a local-attention window)
            # would evict real in-window history, so pad buckets are
            # clamped to it.
            self._prefill = _prefill_jit(model, max_len)
            lens = [l.shape[2] for l in jax.tree.leaves(self.caches)
                    if getattr(l, "ndim", 0) >= 3]
            self._min_cache_len = min(lens) if lens else max_len
        self._splice = _splice_slot
        # hooks for the serving gateway: called after every decode step /
        # on every request completion
        self.on_step: Optional[Callable[[Dict[str, int], float], None]] = None
        self.on_finish: Optional[Callable[[Request], None]] = None

    def use_program(self, compiled: Callable) -> None:
        """Swap in an externally compiled decode executable — the serving
        gateway routes compilation through the hypervisor's Reconfigurator
        so the decode program lives in the RC3E program cache (and PR swaps
        bind it to each tenant's vSlice)."""
        self._decode = compiled

    def set_tenant_share(self, tenant: str, max_slots: Optional[int]) -> None:
        """Cap a tenant's concurrent engine slots (None removes the cap)."""
        if max_slots is None:
            self._tenant_share.pop(tenant, None)
        else:
            self._tenant_share[tenant] = max(1, int(max_slots))

    def set_tenant_weight(self, tenant: str,
                          weight: Optional[float]) -> None:
        """Fair-share weight for the deficit round-robin admission policy
        (None resets to the default 1.0). A tenant accrues credit in
        proportion to its weight and pays for every admission in
        proportion to the context it prefills — so a hostile tenant
        flooding long prompts buys *fewer* admissions per unit time, not
        more, and a co-tenant's latency stays bounded."""
        if weight is None:
            self._tenant_weight.pop(tenant, None)
        else:
            self._tenant_weight[tenant] = max(1e-3, float(weight))

    def set_tenant_pages(self, tenant: str,
                         max_pages: Optional[int]) -> None:
        """Cap a tenant's pool pages (paged mode; None removes the cap).
        The gateway/fleet set this from the tenant's vSlice ``cache_pages``
        grant and the service model's ``max_cache_pages_per_tenant`` quota;
        a tenant at its cap queues instead of allocating (no OOM)."""
        if max_pages is None:
            self._tenant_pages.pop(tenant, None)
        else:
            self._tenant_pages[tenant] = max(1, int(max_pages))

    def submit(self, prompt, max_new_tokens: int = 16,
               tenant: str = "default") -> Request:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError("empty prompt: a request needs at least one "
                             "prompt token to seed decoding")
        if self.paged:
            worst = (len(prompt) + max_new_tokens - 1) // self.page_size + 1
            if worst > self.pool.max_blocks:
                raise ValueError(
                    f"request may need {worst} blocks, block table has "
                    f"{self.pool.max_blocks} (max_len {self.max_len}) — "
                    "it could never be admitted")
            if worst > self.pool.total_pages:
                raise ValueError(
                    f"request may need {worst} pages, pool has only "
                    f"{self.pool.total_pages} — it could never be admitted")
        req = Request(next(self._ids), prompt, max_new_tokens, tenant=tenant)
        if sanitizer.enabled:
            req._san = sanitizer.scope()
            _req_event(req, "submit")
        with self._qlock:
            self._queues.setdefault(tenant,
                                    collections.deque()).append(req)
        return req

    def resume(self, req: Request, front: bool = False) -> Request:
        """Requeue a request drained from another engine (live migration)
        or preempted locally: its already-generated tokens are preserved
        and replayed as a prompt prefix when the request is re-admitted
        (see ``_admit``). ``front`` preserves FIFO order for preemption.

        A request cancelled while in transit between engines (drained for
        a hand-off but not yet resumed, or orphaned by a dead device) is
        already settled — requeuing it would decode a finished request and
        settle its quota twice, so it is dropped here."""
        if req.done.is_set():
            return req
        _req_event(req, "requeue")
        with self._qlock:
            q = self._queues.setdefault(req.tenant, collections.deque())
            if front:
                q.appendleft(req)
            else:
                q.append(req)
        return req

    # ---------------- tenant bookkeeping ----------------
    def _drain_queue(self, tenant: str) -> List[Request]:
        """Remove and return all of a tenant's queued requests."""
        with self._qlock:
            q = self._queues.pop(tenant, None)
        return list(q) if q is not None else []

    def cancel_queued(self, tenant: str) -> List[Request]:
        """Drop a tenant's not-yet-admitted requests (e.g. its serving
        session closed). Returns the cancelled requests, marked done."""
        dropped = self._drain_queue(tenant)
        for r in dropped:
            _req_event(r, "cancel")
            r.finish_reason = "cancelled"
            r.finished_at = time.monotonic()
            r.done.set()
        return dropped

    def cancel(self, req: Request) -> bool:
        """Cancel ONE request wherever it is: still queued (dropped from
        its tenant queue) or in flight (its slot — and, in paged mode, its
        pool pages — are freed immediately instead of burning until
        ``max_new_tokens``). Fires ``on_finish`` so the gateway settles the
        quota. Returns False when the request already finished."""
        if req.done.is_set():
            return False
        dequeued = False
        with self._qlock:
            q = self._queues.get(req.tenant)
            if q is not None and req in q:
                q.remove(req)
                if not q:
                    del self._queues[req.tenant]
                dequeued = True
        if dequeued:
            self._finish(req, "cancelled")
            return True
        for i, r in enumerate(self._slots):
            if r is req:
                self._release_slot(i)
                self._finish(req, "cancelled")
                return True
        return False

    def _finish(self, req: Request, reason: str):
        _req_event(req, "cancel" if reason == "cancelled" else "finish")
        req.finish_reason = reason
        req.finished_at = time.monotonic()
        req.done.set()
        if self.on_finish is not None:
            self.on_finish(req)

    def _release_slot(self, slot: int):
        """Free a slot (and its pool pages) without touching the request."""
        sanitizer.emit("slot", (self._scope, slot), "release")
        self._slots[slot] = None
        self._prefilling.pop(slot, None)   # buffered prefill dies with it
        self._pos[slot] = -1 if self.paged else 0
        if self.paged:
            self.pool.release_slot(slot)

    def drain_tenant(self, tenant: str) -> List[Request]:
        """Evict a tenant's in-flight and queued requests for live hand-off
        to another engine. In-flight requests keep their generated tokens
        (``resume`` on the target replays them as a prompt prefix; a paged
        fleet copies their pages instead — export BEFORE draining); nothing
        is marked done. Freed slots' stale cache rows stay position-masked
        until recycled. Returns the requests, in-flight first."""
        moved: List[Request] = []
        for i, r in enumerate(self._slots):
            if r is not None and r.tenant == tenant:
                _req_event(r, "drain")
                self._release_slot(i)
                moved.append(r)
        moved.extend(self._drain_queue(tenant))
        return moved

    def inflight(self, tenant: Optional[str] = None) -> List[Request]:
        """Requests currently holding a slot (optionally one tenant's)."""
        return [r for r in self._slots
                if r is not None and (tenant is None or r.tenant == tenant)]

    def holds(self, req: Request) -> bool:
        """Is this request physically on this engine (slotted or queued)?
        The failover sweep consults it: an overlapped hand-off's source
        keeps decoding a migrating tenant's requests while the page copy
        is in flight, and if the tenant's TARGET device dies in that
        window, recovery must not replay requests a live engine still
        owns (double-decode)."""
        if any(r is req for r in self._slots):
            return True
        with self._qlock:
            q = self._queues.get(req.tenant)
            return q is not None and any(r is req for r in q)

    def active_by_tenant(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for r in self._slots:
            if r is not None:
                counts[r.tenant] = counts.get(r.tenant, 0) + 1
        return counts

    def queued_by_tenant(self) -> Dict[str, int]:
        """Queue depth per tenant. Tenant keys live only while a queue is
        non-empty (emptied queues are pruned at pop/drain time), so tenant
        churn cannot grow this map — or the admission round-robin —
        unboundedly."""
        with self._qlock:
            return {t: len(q) for t, q in self._queues.items() if q}

    def _ctx_tokens(self, req: Request) -> np.ndarray:
        """Prompt + already-generated tokens: the context a (re-)admission
        must cover (the final token seeds the next decode step)."""
        if not req.out_tokens:
            return req.prompt
        # admission-time list->array conversion, not per-decode-step
        return np.concatenate(
            [req.prompt,
             np.asarray(req.out_tokens, np.int32)])  # rc3e: allow-host-sync

    def _invalidate_pages(self, pages) -> None:
        """Scrub recycled pages' stale ``pos`` metadata before first use.
        Callers that overwrite a whole page (batched splice, page import,
        COW copy) skip this; token-at-a-time writers (legacy prefill,
        decode into a freshly grown page) must not leave the previous
        occupant's positions masquerading as their own history."""
        if not self.paged or not pages:
            return
        self.caches = _invalidate_pool_pages(
            self.caches,
            jnp.asarray(np.asarray(sorted(pages),    # rc3e: allow-host-sync
                                   np.int32)))

    def _flush_scrub(self) -> int:
        """Drain the pool's zero-on-free queue with ONE batched jitted
        zeroing. Called at the top of every step and again immediately
        before any page allocation (grow/COW/admit/import) — a freed page
        must be scrubbed before it can be handed to the next tenant, and
        ``PagePoolManager._alloc_one`` asserts we never miss a site.
        No-op (one int compare) when nothing is pending."""
        if not self.paged or not self.pool.scrub_pending:
            return 0
        pids = self.pool.take_scrub()
        t0 = time.monotonic()
        self.caches = _scrub_pool_pages(
            self.caches,
            jnp.asarray(np.asarray(sorted(pids),     # rc3e: allow-host-sync
                                   np.int32)))
        self.scrub_ms += (time.monotonic() - t0) * 1e3
        return len(pids)

    def _page_budget_ok(self, tenant: str, extra: int) -> bool:
        budget = self._tenant_pages.get(tenant)
        return budget is None or \
            self.pool.tenant_pages(tenant) + extra <= budget

    def _can_admit(self, req: Request) -> bool:
        """Paged admission gate: queue-on-exhaustion. A request stays at
        its tenant's queue head until the pool has pages for it AND the
        tenant is under its page budget."""
        if not self.paged:
            return True
        needed = self.pool.pages_needed(
            req.tenant, self._ctx_tokens(req),
            share=self.prefill_mode == "batched")
        return needed <= self.pool.free_pages and \
            self._page_budget_ok(req.tenant, needed)

    def _admit_cost(self, req: Request) -> float:
        """What one admission debits from its tenant's fair-share credit:
        one decode slot plus the prefill work, in page-sized chunks. A
        4-page prompt costs ~5x a one-token resubmit, which is exactly the
        asymmetry a prompt-flood attack exploits under plain round-robin
        (every admission costs 1 there, regardless of prefill length)."""
        unit = self.page_size if self.paged else 16
        return 1.0 + (len(self._ctx_tokens(req)) - 1) / max(1, unit)

    def _pop_next_request(self) -> Optional[Request]:
        """Weighted deficit round-robin over tenants (the per-tenant
        fair-share policy): every *eligible* tenant — spare slot share
        and, in paged mode, an admissible head request — accrues credit
        proportional to its weight each time a slot is offered, the
        highest-credit tenant is served, and the admission debits its
        credit by ``_admit_cost`` (slot + prefill chunks). Ties break in
        rotation order after the last served tenant, so equal-weight
        tenants degenerate to the old round-robin. Blocked tenants accrue
        nothing (a page-starved head must not bank unbounded priority),
        and credit is pruned with the tenant's last queued request so
        tenant churn cannot grow the map. Emptied queues are pruned here
        so long-gone tenants don't linger in the rotation."""
        with self._qlock:
            active = self.active_by_tenant()
            # prune credit/debt only once a tenant is fully gone (no queue,
            # no slots): clearing debt while it still holds slots would let
            # a one-request-at-a-time flood dodge its admission debits
            for t in list(self._deficit):
                if t not in self._queues and not active.get(t):
                    del self._deficit[t]
            tenants = [t for t, q in self._queues.items() if q]
            if not tenants:
                return None
            n = len(tenants)
            order = [tenants[(self._rr_offset + k) % n] for k in range(n)]
            eligible = []
            for t in order:
                share = self._tenant_share.get(t, self.n_slots)
                if active.get(t, 0) >= share:
                    continue
                if not self._can_admit(self._queues[t][0]):
                    continue        # per-tenant FIFO: head blocks the rest
                eligible.append(t)
            if not eligible:
                return None
            best = None
            for t in eligible:
                self._deficit[t] = self._deficit.get(t, 0.0) + \
                    self._tenant_weight.get(t, 1.0)
                if best is None or self._deficit[t] > self._deficit[best]:
                    best = t        # strict >: first-in-order wins ties
            req = self._queues[best].popleft()
            if not self._queues[best]:
                del self._queues[best]
            self._deficit[best] = self._deficit.get(best, 0.0) - \
                self._admit_cost(req)
            self._rr_offset = (tenants.index(best) + 1) % n
            return req

    # ---------------- engine loop ----------------
    def _admit(self, async_chunk: Optional[int] = None):
        for slot in range(self.n_slots):
            if self._slots[slot] is not None:
                continue
            req = self._pop_next_request()
            if req is None:
                return
            self._slots[slot] = req
            sanitizer.emit("slot", (self._scope, slot), "occupy")
            _req_event(req, "admit")
            if async_chunk is not None:
                # event-driven admission: buffer the prefill and account
                # it async_chunk tokens per engine event (see step_async)
                self._start_prefill_async(slot, req, async_chunk)
                continue
            # a request resumed after live migration replays prompt +
            # already-generated tokens so decode continues where it left off
            toks = self._ctx_tokens(req)
            if self.paged:
                self._admit_paged(slot, req, toks)
            else:
                ctx = toks[:-1]
                if len(ctx) >= self.PREFILL_MIN_TOKENS \
                        and self.prefill_mode == "batched":
                    self._prefill_slot(slot, ctx)
                else:
                    # short context (or legacy mode): feed tokens through
                    # the already-compiled decode program, slot-isolated
                    for i, t in enumerate(ctx):
                        self._step_single(slot, int(t), i)
                self._pos[slot] = len(toks) - 1
            req._next_input = int(toks[-1])
            _req_event(req, "ready")   # lockstep: prefill completed inline

    def _start_prefill_async(self, slot: int, req: Request, chunk: int):
        """Admit ``req`` into ``slot`` without blocking the engine event:
        compute the batched prefill once, buffer the result, and hand the
        slot to ``step_async`` to account one ``chunk`` of context tokens
        per event before it joins decode. Contexts the lockstep path
        already handles synchronously (short, legacy-mode, or fully
        prefix-matched paged admissions) stay synchronous — they are
        O(chunk) work anyway — and become ready within this event."""
        toks = self._ctx_tokens(req)
        ctx = toks[:-1]
        plan = None
        if self.paged:
            self._flush_scrub()
            plan = self.pool.admit(slot, req.tenant, toks,
                                   share=self.prefill_mode == "batched")
        buf = None
        chunks = 0
        if plan is not None and plan.skip_prefill:
            pass                        # every context page prefix-matched
        elif len(ctx) >= self.PREFILL_MIN_TOKENS \
                and self.prefill_mode == "batched":
            _, buf = self._prefill(self.params, self._pad_ctx(ctx))
            chunks = -(-len(ctx) // max(1, int(chunk)))   # ceil
        else:
            if plan is not None:
                self._invalidate_pages(plan.write_pages)
            for i, t in enumerate(ctx):
                self._step_single(slot, int(t), i)
        if self.paged:
            # masked until ready: decode rows at -1 write the null page,
            # and _prepare_writes skips the slot entirely
            self._pos[slot] = -1
        pending = _PendingPrefill(chunks, buf, plan, len(toks),
                                  int(toks[-1]))
        if chunks <= 0:
            self._finish_prefill(slot, pending)
        else:
            self._prefilling[slot] = pending

    def _finish_prefill(self, slot: int, pending: _PendingPrefill):
        """Splice the buffered prefill and open the slot for decode."""
        req = self._slots[slot]
        if pending.buf is not None:
            if self.paged:
                plan = pending.plan
                pages = jnp.asarray(                 # rc3e: allow-host-sync
                    np.asarray(plan.write_pages,     # rc3e: allow-host-sync
                               np.int32))
                self.caches = _splice_pages(self.caches, pending.buf, pages,
                                            start=plan.write_start)
            else:
                self.caches = self._splice(self.caches, pending.buf, slot)
        self._pos[slot] = pending.ctx_len - 1
        req._next_input = pending.last_token
        _req_event(req, "ready")

    def _admit_paged(self, slot: int, req: Request, toks: np.ndarray):
        """Page-granular admission: prefix-matched pages are adopted by
        refcount; only the unshared suffix blocks are prefilled + spliced.
        Legacy prefill steps every context token through the decode program
        (writes at every position), so it must not adopt shared pages."""
        self._flush_scrub()
        plan = self.pool.admit(slot, req.tenant, toks,
                               share=self.prefill_mode == "batched")
        ctx = toks[:-1]
        if not plan.skip_prefill:
            if len(ctx) >= self.PREFILL_MIN_TOKENS \
                    and self.prefill_mode == "batched":
                self._prefill_slot_paged(slot, ctx, plan)
            else:
                self._invalidate_pages(plan.write_pages)
                for i, t in enumerate(ctx):
                    self._step_single(slot, int(t), i)
        self._pos[slot] = len(toks) - 1

    def _prefill_slot(self, slot: int, ctx: np.ndarray):
        """Prefill a slot's context with ONE batched call instead of one
        full-batch decode per prompt token (O(S·n_slots) -> O(S) work,
        O(1) dispatches). Lengths are padded to power-of-two buckets to
        bound recompiles; padded positions carry pos >= len(ctx), so they
        are causally masked during decode and overwritten in place when
        generation reaches them."""
        _, slot_caches = self._prefill(self.params,
                                       self._pad_ctx(ctx))
        self.caches = self._splice(self.caches, slot_caches, slot)

    def _prefill_slot_paged(self, slot: int, ctx: np.ndarray, plan):
        """Prefill, then scatter ONLY the unshared suffix blocks into this
        slot's pool pages (shared prefix pages already hold identical
        content — that's the point of sharing them)."""
        _, slot_caches = self._prefill(self.params, self._pad_ctx(ctx))
        # admission-time upload of the write-page index vector
        pages = jnp.asarray(                         # rc3e: allow-host-sync
            np.asarray(plan.write_pages,             # rc3e: allow-host-sync
                       np.int32))
        self.caches = _splice_pages(self.caches, slot_caches, pages,
                                    start=plan.write_start)

    def _pad_ctx(self, ctx: np.ndarray):
        n = len(ctx)
        bucket = 8
        while bucket < n:
            bucket *= 2
        pad = max(n, min(bucket, self._min_cache_len))
        toks = np.zeros((1, pad), np.int32)
        toks[0, :n] = ctx
        # prefill prompt upload: once per admission, not per step
        return jnp.asarray(toks)                     # rc3e: allow-host-sync

    def _block_tables_dev(self):
        """Device copy of the pool block tables, re-uploaded only when the
        pool's ``version`` counter moved (bumped on every admit/grow/cow/
        release). Steady-state decode steps — no admission, no growth —
        reuse the cached array instead of paying an H2D transfer of the
        whole (n_slots, max_blocks) table per generated token."""
        if self._bt_version != self.pool.version:
            self._bt_cache = jnp.asarray(            # rc3e: allow-host-sync
                self.pool.block_tables)
            self._bt_version = self.pool.version
        return self._bt_cache

    def _step_single(self, slot: int, token: int, pos: int):
        """Replay ONE context token through the decode program (short or
        legacy-mode prefill). The logits are deliberately dropped on
        device — only the cache writes matter here."""
        tokens = np.zeros((self.n_slots, 1), np.int32)
        tokens[slot, 0] = token
        if self.paged:
            # other rows stay inactive (-1): their k/v writes land in the
            # null page instead of garbling a possibly-shared write page
            posv = np.full((self.n_slots,), -1, np.int32)
            posv[slot] = pos
            _, self.caches = self._decode(
                self.params, self.caches,
                jnp.asarray(tokens),                 # rc3e: allow-host-sync
                jnp.asarray(posv),                   # rc3e: allow-host-sync
                self._block_tables_dev())
        else:
            posv = self._pos.copy()
            posv[slot] = pos
            _, self.caches = self._decode(
                self.params, self.caches,
                jnp.asarray(tokens),                 # rc3e: allow-host-sync
                jnp.asarray(posv))                   # rc3e: allow-host-sync

    def _prepare_writes(self):
        """Before a paged decode step: every active slot's write position
        must land in a privately owned page. Crossing a page boundary
        grows the slot by one page; a shared (prefix) page is detached
        copy-on-write; exhaustion preempts the slot back to its queue head
        (generated tokens survive via prefix replay)."""
        ps = self.page_size
        for i, req in enumerate(self._slots):
            if req is None or i in self._prefilling:
                continue            # mid-prefill: pos is -1, nothing writes
            wpos = int(self._pos[i])
            block = wpos // ps
            if block >= len(self.pool.slot_blocks(i)):
                if self.pool.free_pages >= 1 and \
                        self._page_budget_ok(req.tenant, 1):
                    # an earlier slot in this same sweep may have been
                    # preempted — its pages must be scrubbed before they
                    # can be regrown here
                    self._flush_scrub()
                    self._invalidate_pages([self.pool.grow(i, req.tenant)])
                else:
                    self._preempt(i)
                continue
            if self.pool.is_shared(i, block):
                if self.pool.free_pages >= 1 and \
                        self._page_budget_ok(req.tenant, 1):
                    self._flush_scrub()
                    src, dst = self.pool.cow(i, block, req.tenant)
                    self.caches = _copy_page(self.caches, jnp.int32(src),
                                             jnp.int32(dst))
                else:
                    self._preempt(i)
                continue
            self.pool.touch_write(i, block)

    def _preempt(self, slot: int):
        req = self._slots[slot]
        _req_event(req, "preempt")
        self._release_slot(slot)
        self.resume(req, front=True)
        self.preemptions += 1

    def step(self) -> int:
        """One engine iteration: admit + one decode step for active slots.
        Returns number of active slots."""
        self._flush_scrub()       # pages freed since the last step
        self._admit()
        return self._decode_once()

    def step_async(self, prefill_chunk: int = 4) -> int:
        """One EVENT-DRIVEN engine iteration: admit without blocking
        (prefills are buffered and accounted ``prefill_chunk`` context
        tokens per event), advance pending prefills one chunk, then decode
        the slots whose prefill already completed. Prefill no longer
        stalls co-resident tenants' decode — the overlap the lockstep
        ``step()`` cannot express. Token streams are bit-identical to the
        lockstep path: the same prefill result is spliced (just later) and
        greedy per-slot decoding is schedule-independent."""
        self._flush_scrub()       # pages freed since the last event
        self._admit(async_chunk=prefill_chunk)
        for slot in sorted(self._prefilling):
            pending = self._prefilling[slot]
            pending.chunks_left -= 1
            _req_event(self._slots[slot], "chunk")
            if pending.chunks_left <= 0:
                del self._prefilling[slot]
                self._finish_prefill(slot, pending)
        return self._decode_once()

    def _decode_once(self) -> int:
        """One decode step over every ready slot (mid-prefill slots are
        excluded). Returns the number of slots decoded."""
        if self.paged:
            self._prepare_writes()
        active = [i for i, r in enumerate(self._slots)
                  if r is not None and i not in self._prefilling]
        if not active:
            return 0
        tokens = np.zeros((self.n_slots, 1), np.int32)
        for i in active:
            tokens[i, 0] = self._slots[i]._next_input
        t0 = time.monotonic()
        # the two small per-step uploads ((n_slots, 1) tokens and
        # (n_slots,) positions) are the step's inputs — unavoidable and
        # tiny; the block tables are served from the version-keyed cache
        if self.paged:
            logits, self.caches = self._decode(
                self.params, self.caches,
                jnp.asarray(tokens),                 # rc3e: allow-host-sync
                jnp.asarray(self._pos),              # rc3e: allow-host-sync
                self._block_tables_dev())
        else:
            logits, self.caches = self._decode(
                self.params, self.caches,
                jnp.asarray(tokens),                 # rc3e: allow-host-sync
                jnp.asarray(self._pos))              # rc3e: allow-host-sync
        # argmax on device: fetch (n_slots,) int32 ids, not the full
        # (n_slots, 1, vocab) logits tensor
        next_ids = np.asarray(                       # rc3e: allow-host-sync
            _argmax_tokens(logits))
        step_ms = (time.monotonic() - t0) * 1e3
        self.steps += 1
        if self.on_step is not None:
            self.on_step(self.active_by_tenant(), step_ms)
        for i in active:
            req = self._slots[i]
            nxt = int(next_ids[i])
            if req.first_token_at is None:
                req.first_token_at = time.monotonic()
            req.out_tokens.append(nxt)
            req._next_input = nxt
            self._pos[i] += 1
            eos = self.eos_id is not None and nxt == self.eos_id
            if len(req.out_tokens) >= req.max_new_tokens or eos \
                    or self._pos[i] >= self.max_len - 1:
                self._release_slot(i)
                self._finish(req, "eos" if eos else "length")
        return len(active)

    def idle(self) -> bool:
        with self._qlock:
            queued = any(self._queues.values())
        return all(r is None for r in self._slots) and not queued

    def run_until_idle(self, max_steps: int = 10000) -> bool:
        """Run until no work remains. Returns True when fully drained,
        False when ``max_steps`` expired with work still pending OR queued
        work can make no progress (e.g. page-budget starvation with
        nothing in flight) — callers must not mistake a stall for
        completion."""
        for _ in range(max_steps):
            n = self.step()
            if self.idle():
                return True
            if n == 0:
                return False        # nothing active, nothing admittable
        return self.idle()

    # ---------------- paged introspection / hand-off ----------------
    def page_stats(self) -> dict:
        """Pool occupancy for the monitor (empty dict in dense mode)."""
        if not self.paged:
            return {}
        s = self.pool.stats()
        s["preemptions"] = self.preemptions
        s["scrub_ms"] = round(self.scrub_ms, 3)
        return s

    def export_request_pages(self, req: Request):
        """Gather an in-flight request's pool pages to host memory for a
        live hand-off (leaves (L, nb, ps, ...)). Call BEFORE draining —
        released pages may be recycled by the next admission. Returns None
        when the request holds no slot or the engine is dense."""
        if not self.paged:
            return None
        for i, r in enumerate(self._slots):
            if r is req:
                pages = self.pool.slot_blocks(i)
                if not pages:
                    return None
                idx = np.asarray(pages, np.int32)
                return jax.tree.map(lambda a: np.asarray(a[:, idx]),
                                    self.caches)
        return None

    def import_request_pages(self, req: Request, payload,
                             ctx_len: Optional[int] = None) -> bool:
        """Adopt a migrated request by copying its pages into this pool —
        decode continues WITHOUT prefix replay. Returns False (caller
        falls back to replay) when no slot, pages or budget are free.

        ``ctx_len`` is the request's context length AT EXPORT TIME. The
        overlapped hand-off keeps decoding on the source while the page
        copy is in flight, so by adoption time the request may hold a few
        tokens the snapshot doesn't cover; those positions
        (``ctx_len-1 .. now-2``) are caught up by replaying just the delta
        through the decode program — pages grown as needed — instead of
        replaying the whole prefix. ``None`` means the snapshot is
        current (the lockstep hand-off exports and drains atomically)."""
        if not self.paged:
            return False
        # geometry guard: a cross-class hand-off can land a snapshot cut
        # at the SOURCE pool's page size on a pool tuned to a different
        # one — the pages cannot be adopted page-for-page, so decline and
        # let the caller fall back to prefix replay (bit-exact greedy)
        if jax.tree.leaves(payload)[0].shape[2] != self.page_size:
            return False
        slot = next((i for i, r in enumerate(self._slots) if r is None),
                    None)
        if slot is None:
            return False
        nb = jax.tree.leaves(payload)[0].shape[1]
        if nb > self.pool.free_pages or \
                not self._page_budget_ok(req.tenant, nb):
            return False
        self._flush_scrub()
        pages = [self.pool.grow(slot, req.tenant) for _ in range(nb)]
        self.caches = _import_pages(
            self.caches, jax.tree.map(jnp.asarray, payload),
            jnp.asarray(np.asarray(pages, np.int32)))
        toks = self._ctx_tokens(req)
        base = len(toks) if ctx_len is None else int(ctx_len)
        # catch-up: KV for positions 0..base-2 arrived with the snapshot;
        # anything the source generated after the export is replayed here
        for off, t in enumerate(toks[base - 1:len(toks) - 1]):
            pos = base - 1 + off
            if pos // self.page_size >= len(self.pool.slot_blocks(slot)):
                if self.pool.free_pages >= 1 and \
                        self._page_budget_ok(req.tenant, 1):
                    self._flush_scrub()
                    self._invalidate_pages(
                        [self.pool.grow(slot, req.tenant)])
                else:
                    # can't cover the delta — roll the adoption back and
                    # let the caller fall back to prefix replay
                    self.pool.release_slot(slot)
                    return False
            self._step_single(slot, int(t), pos)
        self._slots[slot] = req
        sanitizer.emit("slot", (self._scope, slot), "occupy")
        _req_event(req, "adopt")
        self._pos[slot] = len(toks) - 1
        req._next_input = int(toks[-1])
        return True
