from repro.runtime.adversary import (BEHAVIORS, CancelChurn, PageSquat,
                                     PrefixProbe, PromptFlood,
                                     ScenarioReport, run_scenario)
from repro.runtime.events import Event, EventLoop, EventQueue
from repro.runtime.faults import FakeClock, FaultEvent, FaultInjector
from repro.runtime.fleet import GatewayFleet, JournalEntry
from repro.runtime.gateway import ServingGateway, TenantSession
from repro.runtime.loadgen import (Arrival, FleetSpec, SoakMatrix,
                                   TraceSpec, replay_trace, synthesize,
                                   tenant_shares)
from repro.runtime.losses import chunked_xent, full_xent
from repro.runtime.paged import PagePoolManager
from repro.runtime.serve import (BatchingEngine, Request, jit_serve_step,
                                 make_paged_serve_step, make_prefill_step,
                                 make_serve_step)
from repro.runtime.sharding import (batch_specs, cache_specs, dp_axes, named,
                                    param_specs)
from repro.runtime.train import (TrainOpts, init_train_state, jit_train_step,
                                 make_dp_train_step, make_train_step)
