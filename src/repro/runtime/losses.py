"""Losses. The vocab projection is fused into a sequence-chunked scan so the
full (B, S, V) logits tensor never materializes — with V up to 262k
(gemma3) and 1M train tokens, unchunked logits would be ~1 TB in fp32."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.norms import softcap


def _vocab_weight(cfg: ModelConfig, params):
    return params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]


def chunked_xent(cfg: ModelConfig, params, h, labels, *,
                 chunk: int = 512):
    """Mean next-token cross-entropy. h (B,S,d), labels (B,S) (already
    shifted by the caller). Scans over S in ``chunk`` slices."""
    B, S, d = h.shape
    w = _vocab_weight(cfg, params)
    c = min(chunk, S)
    if S % c:
        c = S  # fall back to single chunk for ragged small seqs
    n_chunks = S // c

    @jax.checkpoint
    def body(acc, i):
        # rematted: without this, backward stores every chunk's logits
        # (B, c, V) — tens of GB at 262k vocab
        hc = jax.lax.dynamic_slice_in_dim(h, i * c, c, axis=1)
        lc = jax.lax.dynamic_slice_in_dim(labels, i * c, c, axis=1)
        logits = jnp.einsum("bsd,dv->bsv", hc, w.astype(hc.dtype))
        logits = softcap(logits, cfg.final_softcap).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)                 # (B, c)
        gold = jnp.take_along_axis(logits, lc[..., None],
                                   axis=-1)[..., 0]             # (B, c)
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            jnp.arange(n_chunks))
    return total / (B * S)


def full_xent(cfg: ModelConfig, params, h, labels):
    """Unchunked reference (oracle for tests)."""
    w = _vocab_weight(cfg, params)
    logits = jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype))
    logits = softcap(logits, cfg.final_softcap).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
