"""Sharding rules: map every parameter / input / cache tensor to a
PartitionSpec over the production mesh axes ("pod", "data", "model").

Strategy (baseline; the perf pass iterates on this):
  * DP: batch dims over ("pod","data") — "pod" composes with "data".
  * TP: attention (kv-)heads, ffn hidden, vocab over "model", with
    divisibility fallbacks (small-head archs replicate attention and still
    shard mlp+vocab).
  * EP: MoE expert dim over "model".
  * SP: for batch=1 long-context cells the cache sequence dim is sharded
    over "data".

Rules are name+rank based and tolerate leading stack dims inserted by the
stage planner (run/pattern stacking), by right-aligning the spec.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def shard_map(f, mesh: Mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across jax versions: older releases only ship
    ``jax.experimental.shard_map`` and spell the check flag ``check_rep``
    instead of ``check_vma``."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


def dp_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _div(n: int, mesh: Mesh, axis: str = "model") -> bool:
    return _axis_size(mesh, axis) > 1 and n % _axis_size(mesh, axis) == 0


def _right_align(spec: Tuple, rank: int) -> P:
    """Pad spec with None on the left to match leading stack dims."""
    pad = rank - len(spec)
    return P(*([None] * pad + list(spec)))


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _param_rule(cfg: ModelConfig, name: str, shape: Tuple[int, ...],
                path_names: Tuple[str, ...], mesh: Mesh) -> P:
    ms = _axis_size(mesh, "model")
    r = len(shape)

    def right(*spec):
        return _right_align(tuple(spec), r)

    if name == "tok":                      # (V, d)
        return right("model" if _div(shape[-2], mesh) else None, None)
    if name == "head":                     # (d, V)
        return right(None, "model" if _div(shape[-1], mesh) else None)

    in_moe = "moe" in path_names and name in ("wg", "wu", "wd")
    if in_moe:                             # (E, d, f) / (E, f, d)
        return right("model" if _div(shape[-3], mesh) else None, None, None)
    if name == "router":                   # (d, E) replicated (cheap, avoids
        return right(None, None)           # gathers around top_k)

    def prefer(pref_idx: int, fallback_idx: int, rank: int) -> P:
        """Shard dim ``pref_idx`` (negative) over model; if indivisible fall
        back to ``fallback_idx`` (usually the d_model dim) — never replicate
        multi-GB weights just because heads don't divide the axis."""
        spec = [None] * rank
        if _div(shape[pref_idx], mesh):
            spec[pref_idx] = "model"
        elif _div(shape[fallback_idx], mesh):
            spec[fallback_idx] = "model"
        return right(*spec)

    if name in ("wg", "wu"):               # (d, f)
        return prefer(-1, -2, 2)
    if name == "wd":                       # (f, d)
        return prefer(-2, -1, 2)

    if name == "wq":
        if "attn" in path_names and cfg.mla is not None and r >= 3:
            return prefer(-2, -3, 3)       # MLA q proj (d, h, qd)
        return prefer(-3, -4, 4)           # GQA (d, h, g, hd)
    if name in ("wk", "wv"):               # (d, h, hd)
        return prefer(-2, -3, 3)
    if name == "wo":
        if cfg.mla is not None and r >= 3 and "attn" in path_names:
            return prefer(-3, -1, 3)       # (h, v, d)
        return prefer(-4, -1, 4)           # (h, g, hd, d)
    if name in ("w_uk", "w_uv"):           # (r, h, n)
        return prefer(-2, -3, 3)
    if name == "w_dkv":                    # (d, r+rope)
        return prefer(-2, -2, 2)

    if name == "in_proj":                  # ssm (d, e)
        return prefer(-1, -2, 2)
    if name == "out_proj":                 # ssm (e, d)
        return prefer(-2, -1, 2)
    if name == "conv_w":                   # (K, C) channel-sharded
        return right(None, "model" if _div(shape[-1], mesh) else None)
    if name == "conv_b":                   # (C,)
        return right("model" if _div(shape[-1], mesh) else None)

    # norms, biases, A_log, dt_bias, D, scales: replicate
    return P(*([None] * r))


def param_specs(cfg: ModelConfig, params_shape, mesh: Mesh):
    """PartitionSpec pytree matching an ``eval_shape`` of init."""
    if cfg.tp_mode == "pure_dp":
        return jax.tree.map(lambda l: P(*([None] * l.ndim)), params_shape)
    if cfg.tp_mode == "fsdp":
        return jax.tree.map(lambda l: _fsdp_spec(l.shape, mesh), params_shape)

    def visit(path, leaf):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path)
        return _param_rule(cfg, names[-1], tuple(leaf.shape), names, mesh)
    return jax.tree_util.tree_map_with_path(visit, params_shape)


def _fsdp_spec(shape, mesh: Mesh) -> P:
    """Fully-sharded weights: shard the largest dim over the biggest axis
    combination that divides it (data×model ≫ data ≫ model), skipping the
    leading stack dim. XLA inserts the per-layer all-gather (fwd/bwd) and
    reduce-scatter (grads) — classic ZeRO-3."""
    combos = [("data", "model"), ("data",), ("model",)]
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for axes in combos:
        if not all(a in mesh.axis_names for a in axes):
            continue
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        for i in order:
            if shape[i] % n == 0 and shape[i] >= n:
                spec = [None] * len(shape)
                spec[i] = axes if len(axes) > 1 else axes[0]
                return P(*spec)
    return P(*([None] * len(shape)))


def pure_dp_axes(mesh: Mesh, batch: int):
    """Largest combination of mesh axes (data, model, pod order) whose
    product divides the batch — pure-DP mode spreads batch over all of it."""
    axes = []
    prod = 1
    for a in ("data", "model", "pod"):
        if a in mesh.axis_names and batch % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    return tuple(axes) or None


# ---------------------------------------------------------------------------
# Input / activation / cache specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, batch_shape, mesh: Mesh,
                batch_sharded: bool = True):
    """Inputs: shard the leading (global batch) dim over DP axes (all mesh
    axes in pure_dp mode)."""
    pure_dp = cfg.tp_mode in ("pure_dp", "fsdp")

    def visit(path, leaf):
        if leaf.ndim == 0:
            return P()
        b = leaf.shape[0]
        if not batch_sharded:
            return P(*([None] * leaf.ndim))
        if pure_dp:
            axes = pure_dp_axes(mesh, b)
            if axes is None:
                return P(*([None] * leaf.ndim))
            return P(*([axes] + [None] * (leaf.ndim - 1)))
        dp = dp_axes(mesh)
        if dp is None or b % _dp_size(mesh) != 0:
            return P(*([None] * leaf.ndim))
        return P(*([dp] + [None] * (leaf.ndim - 1)))
    return jax.tree_util.tree_map_with_path(visit, batch_shape)


def _dp_size(mesh: Mesh) -> int:
    return _axis_size(mesh, "pod") * _axis_size(mesh, "data")


def cache_specs(cfg: ModelConfig, cache_shape, mesh: Mesh,
                batch: int, seq_shard: bool = False):
    """Decode caches. Layout (stack..., B, L, heads, hd) for kv caches,
    (stack..., B, H, P, N) for ssm state. Shard B over DP when divisible;
    for batch=1 long-context, shard the cache length dim over "data"
    (sequence parallelism) and kv-heads over "model" when divisible."""
    dp = dp_axes(mesh)
    dp_ok = batch % _dp_size(mesh) == 0

    def visit(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        r = leaf.ndim
        shp = leaf.shape
        leaf_name = names[-1]
        spec = [None] * r
        # find the batch dim: first dim equal to `batch` after stack dims
        try:
            bdim = next(i for i, s in enumerate(shp) if s == batch)
        except StopIteration:
            return P(*spec)
        if dp_ok and dp is not None:
            spec[bdim] = dp
        if leaf_name in ("k", "v", "c_kv", "k_rope", "pos", "cross_k",
                         "cross_v", "k_scale", "v_scale"):
            ldim = bdim + 1                     # cache length dim
            if ldim < r:
                if seq_shard and not dp_ok and _div(shp[ldim], mesh, "data"):
                    spec[ldim] = "data"
                # kv heads dim (k/v only): (B, L, h, hd); when heads don't
                # divide the model axis, shard the cache LENGTH over model
                # instead — a replicated 32k cache is tens of GB/device
                if leaf_name in ("k", "v", "cross_k", "cross_v", "k_scale",
                                 "v_scale") \
                        and ldim + 1 < r and _div(shp[ldim + 1], mesh):
                    spec[ldim + 1] = "model"
                elif spec[ldim] is None and _div(shp[ldim], mesh):
                    spec[ldim] = "model"
        if leaf_name == "state":                 # ssm (B, H, P, N)
            if bdim + 1 < r and _div(shp[bdim + 1], mesh):
                spec[bdim + 1] = "model"
        if leaf_name == "conv":                  # (B, K, C)
            if bdim + 2 < r and _div(shp[bdim + 2], mesh):
                spec[bdim + 2] = "model"
        return P(*spec)
    return jax.tree_util.tree_map_with_path(visit, cache_shape)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def zero1_specs(cfg: ModelConfig, pspecs, params_shape, mesh: Mesh):
    """Optimizer-state sharding (ZeRO-1): take each param's spec and
    additionally shard the first unsharded, data-divisible dim over "data".
    XLA inserts the reduce-scatter/all-gather pair around the update."""
    ds = _axis_size(mesh, "data")

    def one(spec: P, shape):
        if ds <= 1:
            return spec
        parts = list(spec) + [None] * (len(shape.shape) - len(spec))
        used = set()
        for p in parts:
            for a in (p if isinstance(p, tuple) else (p,)):
                if a:
                    used.add(a)
        if "data" in used:        # already data-sharded (e.g. FSDP specs)
            return P(*parts)
        for i, (dim, p) in enumerate(zip(shape.shape, parts)):
            if p is None and dim % ds == 0 and dim >= ds:
                parts[i] = "data"
                break
        return P(*parts)

    return jax.tree.map(one, pspecs, params_shape,
                        is_leaf=lambda x: isinstance(x, P))
