"""Deterministic fault injection for the serving fleet + hypervisor.

The paper's hypervisor "monitors the status of the physical FPGAs" so that
virtual user designs survive device-level events; this module supplies the
adversarial half of that contract. A ``FaultInjector`` owns a seeded RNG
and an injectable ``FakeClock`` and can, at any *step boundary* of
``GatewayFleet.step()``:

  * **kill** a node or a single device (the dataplane freezes instantly;
    a node kill is detected only when the heartbeat deadline expires, a
    device kill is reported immediately — the gcs status-read-error
    analogue);
  * **partition** a node (heartbeats stop, the device keeps decoding) and
    later **heal** it — a partition shorter than the deadline must be
    survivable without any recovery;
  * **fail individual hand-off page copies**, forcing the fleet's
    migration path down its prefix-replay fallback.

Everything is derived from the seed and the schedule: two runs with the
same seed, schedule and workload are bit-identical, which is what lets
``tests/test_chaos.py`` assert token-stream exactness against a
fault-free run instead of merely "it didn't crash".
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Optional, Sequence, Set


def seeded_rng(seed: int) -> random.Random:
    """The one sanctioned constructor for runtime randomness.

    Every RNG in the serving stack must come from here with an explicit
    seed — the determinism pass (``python -m repro.analysis``) flags any
    ``random.Random``/``random.*`` use outside this function, so replay
    guarantees ("same seed, same run") survive refactors. Centralizing
    construction also gives one place to later swap the generator or log
    seed derivations.
    """
    return random.Random(int(seed))


class FakeClock:
    """Injectable monotonic clock. Hand the SAME instance to the
    ``Hypervisor`` (heartbeat deadlines) and the ``FaultInjector`` (which
    advances it one ``tick_s`` per fleet step), so failure detection
    latency is measured in decode steps, not wall time."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


@dataclasses.dataclass
class FaultEvent:
    """One scheduled fault: fires the first tick whose step >= ``step``."""
    step: int
    kind: str           # kill_node | kill_device | partition_node | heal_node
    target: str
    fired: bool = False


class FaultInjector:
    """Seeded, schedule-driven chaos for one hypervisor + fleet.

    The fleet calls ``tick(hv)`` at the top of every ``step()``: the clock
    advances, due events fire, and every alive, non-silenced node
    heartbeats. The fleet also consults ``is_dead(node, device)`` before
    stepping an engine (a killed device must stop decoding the instant it
    dies, not when the monitor notices) and ``fail_page_copy()`` per
    exported request during a live hand-off.
    """

    def __init__(self, seed: int = 0, clock: Optional[FakeClock] = None,
                 tick_s: float = 1.0, page_copy_fail_rate: float = 0.0):
        self.seed = seed
        self.rng = seeded_rng(seed)
        self.clock = clock if clock is not None else FakeClock()
        self.tick_s = tick_s
        self.page_copy_fail_rate = page_copy_fail_rate
        self.events: List[FaultEvent] = []
        self.steps = 0
        self._silenced: Set[str] = set()       # nodes not heartbeating
        self._killed_nodes: Set[str] = set()   # crashed: dataplane frozen
        self._killed_devices: Set[str] = set()
        self.log: List[dict] = []

    # ---------------- schedule ----------------
    def _schedule(self, step: int, kind: str, target: str) -> FaultEvent:
        ev = FaultEvent(int(step), kind, target)
        self.events.append(ev)
        return ev

    def kill_node_at(self, step: int, node_id: str) -> FaultEvent:
        """Crash a whole node: its engines freeze immediately, heartbeats
        stop, and the monitor declares it dead one deadline later."""
        return self._schedule(step, "kill_node", node_id)

    def kill_device_at(self, step: int, device_id: str) -> FaultEvent:
        """Kill one device. Detection is immediate (status-read error)."""
        return self._schedule(step, "kill_device", device_id)

    def partition_node_at(self, step: int, node_id: str) -> FaultEvent:
        """Silence a node's heartbeats WITHOUT stopping its dataplane."""
        return self._schedule(step, "partition_node", node_id)

    def heal_node_at(self, step: int, node_id: str) -> FaultEvent:
        return self._schedule(step, "heal_node", node_id)

    def plan_device_kill(self, device_ids: Sequence[str], lo: int,
                         hi: int) -> FaultEvent:
        """Seeded adversarial schedule: kill one of ``device_ids`` at a
        step drawn from [lo, hi). Sorted first so the draw depends only on
        the seed and the id set, never on dict/iteration order."""
        step = self.rng.randrange(lo, hi)
        target = self.rng.choice(sorted(device_ids))
        return self.kill_device_at(step, target)

    def plan_node_kill(self, node_ids: Sequence[str], lo: int,
                       hi: int) -> FaultEvent:
        step = self.rng.randrange(lo, hi)
        target = self.rng.choice(sorted(node_ids))
        return self.kill_node_at(step, target)

    def plan_soak(self, device_ids: Sequence[str], node_ids: Sequence[str],
                  lo: int, hi: int, kills: int = 1,
                  partitions: int = 1,
                  partition_len: int = 2) -> List[FaultEvent]:
        """Seeded mixed-fault schedule for one soak-matrix cell: ``kills``
        device kills plus ``partitions`` transient node partitions (each
        healed ``partition_len`` steps later), all targets and steps drawn
        from the seed inside [lo, hi). Targets are drawn from sorted id
        lists so the schedule depends only on (seed, id sets) — never on
        iteration order. Returns the scheduled events."""
        planned: List[FaultEvent] = []
        for _ in range(kills):
            if device_ids:
                planned.append(self.plan_device_kill(device_ids, lo, hi))
        for _ in range(partitions):
            if node_ids:
                step = self.rng.randrange(lo, hi)
                target = self.rng.choice(sorted(node_ids))
                planned.append(self.partition_node_at(step, target))
                planned.append(self.heal_node_at(step + partition_len,
                                                 target))
        return planned

    # ---------------- runtime hooks ----------------
    def tick(self, hv, advance_clock: bool = True) -> List[FaultEvent]:
        """One step boundary: advance the clock, fire due events, then
        heartbeat every alive, non-silenced node. Returns the events that
        fired this tick.

        ``advance_clock=False`` is the event-driven mode: the
        ``EventQueue`` owns the shared clock and has already set event
        time when the tick event fires, so advancing here would
        double-count. The fault SCHEDULE stays step-indexed either way —
        chaos timing is a pure function of the seed, not of who owns the
        clock."""
        step = self.steps
        self.steps += 1
        if advance_clock:
            self.clock.advance(self.tick_s)
        fired = []
        for ev in self.events:
            if not ev.fired and ev.step <= step:
                ev.fired = True
                self._fire(hv, ev, step)
                fired.append(ev)
        for node_id, node in hv.db.nodes.items():
            if node.alive and node_id not in self._silenced:
                hv.monitor.heartbeat(node_id)
        return fired

    def _fire(self, hv, ev: FaultEvent, step: int):
        if ev.kind == "kill_node":
            self._silenced.add(ev.target)
            self._killed_nodes.add(ev.target)
        elif ev.kind == "kill_device":
            self._killed_devices.add(ev.target)
            hv.mark_device_failed(ev.target, reason="fault_injector")
        elif ev.kind == "partition_node":
            self._silenced.add(ev.target)
        elif ev.kind == "heal_node":
            self._silenced.discard(ev.target)
        else:
            raise ValueError(f"unknown fault kind {ev.kind!r}")
        self.log.append({"t": self.clock(), "step": step, "kind": ev.kind,
                         "target": ev.target})

    def is_dead(self, node_id: str, device_id: str) -> bool:
        """Has this (node, device) crashed — whether or not the control
        plane has noticed yet? The fleet must not step a dead engine
        during the heartbeat detection window."""
        return node_id in self._killed_nodes \
            or device_id in self._killed_devices

    def fail_page_copy(self) -> bool:
        """Seeded per-request arbitration of hand-off page-copy failures
        (interconnect loss mid-migration). The fleet falls back to
        prompt-prefix replay for that request."""
        if self.page_copy_fail_rate <= 0.0:
            return False
        failed = self.rng.random() < self.page_copy_fail_rate
        if failed:
            self.log.append({"t": self.clock(), "step": self.steps,
                             "kind": "page_copy_fail"})
        return failed
