"""Deterministic event-driven serving loop (the async dataplane).

The lockstep ``GatewayFleet.step()`` is a fleet-wide barrier: every round
waits for the slowest engine, prefill stalls the whole batch, journal
syncs sit on the critical path, and a live hand-off drains its source
before the page copy even starts. This module replaces the barrier with
an **event queue** on the fleet's injected ``FakeClock``:

  * each engine advances on its OWN cadence — ``tick_s / device.speed``
    event-seconds per step — so a slow device class stops gating the
    fleet;
  * prompt prefill is chunked (``BatchingEngine.step_async``): an
    admitted request spends ``ceil(prompt / prefill_chunk)`` engine
    events in PREFILLING while the other slots keep decoding;
  * journal token-log syncs are batched: engines only MARK entries dirty
    and the loop flushes every ``flush_every`` control ticks, with the
    machine-enforced flush barrier (journal DIRTY cannot retire) forcing
    a per-request flush in front of every quota settle and hand-off
    export;
  * live migrations overlap the page copy with continued decode on the
    source: the export snapshot is taken immediately, the source keeps
    decoding for ``copy_ticks`` ticks, and adoption catches up the few
    tokens generated mid-copy (or falls back to prefix replay when the
    snapshot went stale / the copy was lost).

Everything is DETERMINISTIC: the queue orders events by ``(time, seq)``
where ``seq`` is a monotonic schedule counter, so equal-time events fire
in the order they were scheduled — two runs with the same seed, schedule
and workload are bit-identical, and ``tests/test_chaos.py`` asserts
token-stream exactness of the event loop against the lockstep loop.

Determinism rule (enforced by ``python -m repro.analysis``): code in this
module must not read the fleet-wide round counter (``.steps``) — event
code paced by a round counter silently re-introduces the lockstep
barrier. The loop keeps its own ``ticks`` count.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Dict, List, Optional

from repro.analysis.lifecycle import sanitizer
from repro.runtime.faults import FakeClock
from repro.runtime.serve import Request, _req_event


class Event:
    """One scheduled callback. ``cancel`` is lazy: the queue skips
    cancelled entries at pop time (cheaper than heap surgery, and the
    skip cannot perturb ordering of live events)."""

    __slots__ = ("time", "seq", "fn", "kind", "cancelled")

    def __init__(self, time: float, seq: int, fn: Callable[[], None],
                 kind: str):
        self.time = float(time)
        self.seq = seq
        self.fn = fn
        self.kind = kind
        self.cancelled = False

    def __repr__(self):
        return f"Event(t={self.time}, seq={self.seq}, kind={self.kind!r}" \
            + (", cancelled" if self.cancelled else "") + ")"


class EventQueue:
    """Seeded-clock discrete-event queue with stable tie-breaking.

    The heap is keyed ``(time, seq)``: events at the same instant fire
    strictly in schedule order, so firing order is a pure function of the
    schedule — never of hash order, id(), or heap internals. The queue
    OWNS advancing the shared clock: popping an event sets the clock to
    that event's time (monotonically), which is how "event time" reaches
    the monitor's traffic samples and the fault injector's log."""

    def __init__(self, clock: Optional[FakeClock] = None):
        self.clock = clock if clock is not None else FakeClock()
        self._heap: List[Event] = []
        self._seq = itertools.count()
        self.fired = 0

    def at(self, t: float, fn: Callable[[], None],
           kind: str = "event") -> Event:
        """Schedule ``fn`` at absolute event time ``t`` (clamped to now —
        the past is not schedulable)."""
        ev = Event(max(float(t), self.clock()), next(self._seq), fn, kind)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        return ev

    def after(self, dt: float, fn: Callable[[], None],
              kind: str = "event") -> Event:
        return self.at(self.clock() + float(dt), fn, kind)

    def cancel(self, ev: Event) -> None:
        ev.cancelled = True

    def __len__(self) -> int:
        return sum(1 for (_, _, ev) in self._heap if not ev.cancelled)

    def peek(self) -> Optional[Event]:
        """Next live event without popping (cancelled ones are dropped)."""
        while self._heap:
            ev = self._heap[0][2]
            if ev.cancelled:
                heapq.heappop(self._heap)
                continue
            return ev
        return None

    def step(self) -> Optional[Event]:
        """Pop and dispatch the next live event: advance the clock to its
        time, run its callback, return it. None when the queue is empty."""
        ev = self.peek()
        if ev is None:
            return None
        heapq.heappop(self._heap)
        self.clock.t = max(self.clock.t, ev.time)
        self.fired += 1
        ev.fn()
        return ev

    def run(self, until: Optional[float] = None,
            max_events: int = 1_000_000) -> int:
        """Dispatch events up to (and including) time ``until`` (every
        event when None). Leaves the clock at ``until`` even if the last
        event fired earlier. Returns the number of events dispatched."""
        n = 0
        for _ in range(max_events):
            ev = self.peek()
            if ev is None or (until is not None and ev.time > until):
                break
            self.step()
            n += 1
        if until is not None:
            self.clock.t = max(self.clock.t, float(until))
        return n


class EventLoop:
    """The async serving loop: drives one ``GatewayFleet`` from an
    ``EventQueue`` instead of the lockstep round barrier.

    Wiring (done in the constructor):

      * the fleet's journal goes lazy (``journal_lazy``) — engine steps
        mark entries dirty, ``flush_journal`` runs every ``flush_every``
        control ticks;
      * the fleet's migration listener delegates overlapped hand-offs to
        ``_begin_handoff`` (export now, drain+adopt ``copy_ticks`` ticks
        later);
      * the fault injector's clock (when present) becomes the queue's
        clock, and ``begin_round`` stops advancing it — the queue owns
        event time.

    One CONTROL TICK per ``tick_s``: fault injection + heartbeats +
    failover sweep (``begin_round``), engine-cadence reconciliation, the
    periodic journal flush; a settlement event at the end of each tick
    window feeds the monitor's traffic sample and the autoscale/migrate
    cadences (``finish_round``). Engine events self-reschedule every
    ``tick_s / device.speed`` — a speed-0.25 device simply fires four
    times less often while the rest of the fleet decodes at full rate.
    """

    def __init__(self, fleet, tick_s: Optional[float] = None,
                 prefill_chunk: int = 4, flush_every: int = 4,
                 copy_ticks: int = 2, handoff_stale_after: int = 8):
        inj = fleet.faults
        self.fleet = fleet
        self.tick_s = float(tick_s) if tick_s is not None \
            else (inj.tick_s if inj is not None else 1.0)
        self.prefill_chunk = int(prefill_chunk)
        self.flush_every = int(flush_every)
        self.copy_ticks = int(copy_ticks)
        self.handoff_stale_after = int(handoff_stale_after)
        self.queue = EventQueue(inj.clock if inj is not None else None)
        self.ticks = 0
        self._engine_events: Dict[str, Event] = {}
        fleet.journal_lazy = True
        fleet._event_driven = True
        fleet._handoff_hook = self._begin_handoff
        # the first control tick fires at t=now, BEFORE any engine event:
        # fault injection and the failover sweep must see a round boundary
        # before any dataplane advances
        self.queue.at(self.queue.clock(), self._on_tick, kind="tick")

    # ------------------------------------------------------------------
    # Control ticks
    # ------------------------------------------------------------------
    def _on_tick(self) -> None:
        self.ticks += 1
        self.fleet.begin_round()
        self.fleet.last_round_ms = {}
        self._reconcile_engines()
        if self.flush_every and self.ticks % self.flush_every == 0:
            self.fleet.flush_journal()
        # settle BEFORE the next tick at the same instant: scheduled
        # first => lower seq => finish_round(window N) always precedes
        # begin_round(window N+1)
        self.queue.after(self.tick_s, self._finish_tick, kind="settle")
        self.queue.after(self.tick_s, self._on_tick, kind="tick")

    def _finish_tick(self) -> None:
        self.fleet.finish_round()

    def _period(self, dev: str) -> float:
        speed = getattr(self.fleet.hv.db.devices[dev], "speed", 1.0)
        return self.tick_s / max(float(speed), 1e-6)

    def _reconcile_engines(self) -> None:
        """Keep one self-rescheduling step event per live engine. Sorted
        device order makes first-schedule order (and therefore all later
        same-time tie-breaks) a pure function of the device set."""
        live = self.fleet._engines
        for dev in sorted(live):
            if dev not in self._engine_events:
                self._engine_events[dev] = self.queue.at(
                    self.queue.clock(),
                    lambda d=dev: self._on_engine(d),
                    kind=f"engine:{dev}")
        for dev in list(self._engine_events):
            if dev not in live:
                self.queue.cancel(self._engine_events.pop(dev))

    def _on_engine(self, dev: str) -> None:
        """One engine's cadence event: a guarded async step (chunked
        prefill + decode), then reschedule after this device's period.
        An engine that vanished (parked, or recovered off a dead device)
        drops its event; the next control tick re-reconciles."""
        if dev not in self.fleet._engines:
            self._engine_events.pop(dev, None)
            return
        # chunk length follows the device's class: an autotuned fleet may
        # admit prompts in bigger (fast class) or smaller (slow class)
        # prefill chunks than the loop-wide default
        self.fleet.step_engine(
            dev, prefill_chunk=self.fleet.prefill_chunk_for(
                dev, self.prefill_chunk))
        self._engine_events[dev] = self.queue.after(
            self._period(dev), lambda d=dev: self._on_engine(d),
            kind=f"engine:{dev}")

    # ------------------------------------------------------------------
    # Overlapped live hand-off (installed as fleet._handoff_hook)
    # ------------------------------------------------------------------
    def _begin_handoff(self, sess, old_dev: str, new_dev: str) -> None:
        """Phase 1, at migration time: snapshot the tenant's in-flight
        pages (behind the per-request flush barrier) WITHOUT draining —
        the source keeps decoding for the whole copy window. Remembers
        each request's generation count at export so adoption can catch
        up exactly the tokens the snapshot misses."""
        fleet = self.fleet
        source = fleet._engines.get(old_dev)
        if source is None:
            return
        payloads: Dict[int, object] = {}
        gens: Dict[int, int] = {}
        for r in source.inflight(sess.tenant):
            # flush barrier: the journal must cover everything the
            # snapshot covers before the entry can leave this engine
            fleet.flush_journal(r.request_id)
            if fleet.faults is not None and fleet.faults.fail_page_copy():
                continue            # copy lost mid-flight: replay fallback
            p = source.export_request_pages(r)
            if p is not None:
                payloads[id(r)] = p
                gens[id(r)] = len(r.out_tokens)
        fleet._handoff_begun(old_dev)
        self.queue.after(
            self.copy_ticks * self.tick_s,
            lambda: self._complete_handoff(sess, old_dev, new_dev,
                                           payloads, gens),
            kind="handoff")

    def _complete_handoff(self, sess, old_dev: str, new_dev: str,
                          payloads: Dict[int, object],
                          gens: Dict[int, int]) -> None:
        """Phase 2, ``copy_ticks`` later: drain the source and adopt on
        the tenant's CURRENT engine (which may have moved again — even to
        a recovery placement — since phase 1). Fresh snapshots import
        with a catch-up of the tokens decoded mid-copy; stale ones
        (source out-ran ``handoff_stale_after``) and lost copies fall
        back to prompt-prefix replay."""
        fleet = self.fleet
        tenant = sess.tenant
        tdev = fleet._device_of.get(tenant)
        target = None
        if tdev is not None and fleet._device_alive(tdev):
            target = fleet._engines.get(tdev)
            if target is None:
                target = fleet._ensure_engine(tdev)
        if tdev is not None and target is None:
            # the tenant's device died mid-copy and the failover sweep has
            # not re-placed it yet: retry after the next control tick
            self.queue.after(self.tick_s,
                             lambda: self._complete_handoff(
                                 sess, old_dev, new_dev, payloads, gens),
                             kind="handoff")
            return
        fleet._handoff_done(old_dev)
        source = fleet._engines.get(old_dev)
        moved: List[Request] = []
        if source is not None:
            for r in source.inflight(tenant):
                fleet.flush_journal(r.request_id)
            moved = source.drain_tenant(tenant)
            source.set_tenant_share(tenant, None)
            source.set_tenant_pages(tenant, None)
        elif target is not None:
            # the SOURCE died during the copy window: its engine (and the
            # requests' slots) are gone, and recovery skipped this tenant
            # because it was already mapped to the target device. Resume
            # from the journal, exactly like recover_device.
            for entry in list(fleet.journal.values()):
                if entry.tenant != tenant or entry.req.done.is_set() \
                        or fleet._held_elsewhere(entry.req):
                    continue
                _req_event(entry.req, "orphan")
                entry.req.out_tokens = list(entry.tokens)
                sanitizer.emit("journal",
                               (fleet._san, entry.req.request_id), "replay")
                target.resume(entry.req)
        page_copied = replayed = stale = 0
        for r in moved:
            if r.done.is_set():
                continue        # cancelled mid-copy: already settled
            if target is None:
                # session closed mid-copy: nobody will ever decode these
                from repro.runtime.fleet import _mark_cancelled
                fleet._retire_entry(r.request_id)
                _mark_cancelled(r)
                continue
            payload = payloads.get(id(r))
            g = gens.get(id(r), 0)
            fresh = payload is not None \
                and len(r.out_tokens) - g <= self.handoff_stale_after
            if fresh and target.import_request_pages(
                    r, payload, ctx_len=len(r.prompt) + g):
                page_copied += 1
            else:
                if payload is not None and not fresh:
                    stale += 1
                target.resume(r)
                if payload is not None:
                    replayed += 1
        event = {"tenant": tenant, "old_device": old_dev,
                 "new_device": new_dev, "moved_requests": len(moved),
                 "page_copied": page_copied, "replayed_inflight": replayed,
                 "stale_snapshots": stale, "overlapped": True}
        fleet.handoffs.append(event)
        fleet.hv._log("handoff", **event)

    # ------------------------------------------------------------------
    # Driving
    # ------------------------------------------------------------------
    def run_ticks(self, n: int = 1) -> None:
        """Advance ``n`` control-tick windows: dispatch every event up to
        (and including) window settlement, stopping just before the
        (n+1)-th pending control tick fires."""
        target = self.ticks + int(n)
        while True:
            ev = self.queue.peek()
            if ev is None:
                return
            if ev.kind == "tick" and self.ticks >= target:
                return
            self.queue.step()

    def run_until_idle(self, max_ticks: int = 10000) -> bool:
        """Tick until every engine drained and no hand-off copy is in
        flight. Mirrors ``GatewayFleet.run_until_idle`` for the event
        path; a frozen (killed-but-undetected) engine is not a stall —
        the failover sweep recovers it once the monitor notices."""
        for _ in range(max_ticks):
            self.run_ticks(1)
            if self._idle():
                return True
        return self._idle()

    def _idle(self) -> bool:
        return not self.fleet._inflight_handoffs and \
            all(e.idle() for e in self.fleet._engines.values())
