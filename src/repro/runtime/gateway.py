"""Multi-tenant serving gateway: the hypervisor as the single entry point
for inference traffic (paper §IV + RC2F §III shared-shell multi-tenancy).

Before this layer existed, the continuous-batching engine ran *beside* the
RC3E control plane — requests never touched vSlice allocation, admission or
the straggler monitor. The gateway closes that gap:

  * every tenant opens a *session*: quota-checked by the RC2F admission
    controller, bound to a hypervisor-allocated vSlice, and its decode
    program is PR-swapped onto that slice from the program cache;
  * every request is admitted against the tenant's service-model quota and
    dynamically batched ACROSS tenants on the shared device (the engine's
    tenant-tagged queues + slice-aware slot shares);
  * every decode step is attributed to the active tenants' slices,
    share-weighted, so a tenant hogging the device shows up as a straggler
    and gets migrated by the existing ``Hypervisor.migrate_stragglers``;
  * every completed request is logged against its vSlice in
    ``Hypervisor.log`` — the audit trail the paper's middleware keeps.

One gateway owns ONE engine (one shared device). For serving across the
whole device fleet — placement that follows the DeviceDB, live hand-off of
in-flight requests on migration, elastic scale-out/park — use
``repro.runtime.fleet.GatewayFleet``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.hypervisor import Hypervisor
from repro.models.api import Model
from repro.rc2f.admission import AdmissionError
from repro.runtime.serve import (BatchingEngine, Request,
                                 make_paged_serve_step, make_serve_step)


@dataclasses.dataclass
class TenantSession:
    """A tenant's binding to the shared serving device."""
    tenant: str
    slice_id: str
    slots: int                      # vSlice size -> engine slot share
    service_model: str = "baas"
    submitted: int = 0
    served: int = 0
    tokens_out: int = 0


def validate_submit(prompt, max_new_tokens: int, max_len: int) -> None:
    """Shared structural request checks (gateway AND fleet), applied BEFORE
    any quota is consumed so a rejection never leaks in-flight count."""
    if len(prompt) == 0:
        raise AdmissionError("empty prompt: a request needs at least one "
                             "prompt token to seed decoding")
    if len(prompt) + max_new_tokens > max_len:
        raise AdmissionError(
            f"request needs {len(prompt) + max_new_tokens} cache "
            f"positions, engine max_len is {max_len}")


def settle_finished_request(hv: Hypervisor,
                            sessions: Dict[str, TenantSession],
                            req: Request) -> None:
    """Account a completed request to its session and the hypervisor audit
    log — unless the submitting session closed while it decoded (possibly
    a new session reopened under the same tenant name), in which case its
    quota was already settled by close_session."""
    sess = sessions.get(req.tenant)
    if sess is None or sess is not getattr(req, "_session", None):
        return
    sess.served += 1
    sess.tokens_out += len(req.out_tokens)
    latency_ms = ((req.finished_at or time.monotonic())
                  - req.submitted_at) * 1e3
    hv.record_served_request(sess.slice_id, req.tenant, req.request_id,
                             len(req.prompt), len(req.out_tokens),
                             latency_ms)


class ServingGateway:
    """Routes all serving traffic for one model through the hypervisor.

    One gateway owns one BatchingEngine (one shared device in the paper's
    terms); tenants co-reside on it exactly like vFPGAs on a physical FPGA.
    """

    def __init__(self, hv: Hypervisor, model: Model, params,
                 n_slots: int = 4, max_len: int = 256,
                 eos_id: Optional[int] = None, migrate_every: int = 0,
                 paged: bool = False, page_size: int = 16,
                 cache_pages: Optional[int] = None):
        self.hv = hv
        self.model = model
        self.paged = paged
        self.engine = BatchingEngine(model, params, n_slots=n_slots,
                                     max_len=max_len, eos_id=eos_id,
                                     paged=paged, page_size=page_size,
                                     cache_pages=cache_pages)
        self.engine.on_step = self._on_step
        self.engine.on_finish = self._on_finish
        self.migrate_every = migrate_every   # steps between straggler sweeps
        self._sessions: Dict[str, TenantSession] = {}
        self.migrations: List[Tuple[str, str]] = []
        # the gateway owns ONE engine = one shared device; page occupancy
        # is reported against the inventory's first device (the fleet
        # reports per real device)
        self._device_key = next(iter(hv.db.devices), "device-0")
        # rebind at the source: ANY migrate_stragglers() call (ours or an
        # external ops sweep) immediately repoints affected sessions
        hv.migration_listeners.append(self._on_migration)

        # Compile the decode step THROUGH the hypervisor's reconfigurator:
        # the executable lands in the RC3E program cache (full configuration
        # once), and each tenant session PR-swaps it onto its own vSlice.
        self._decode_fn = make_paged_serve_step(model) if paged \
            else make_serve_step(model)
        # avals only: pinning the real params/cache arrays here would keep
        # a duplicate KV-cache set alive for the gateway's lifetime
        example = [params, self.engine.caches,
                   jnp.zeros((n_slots, 1), jnp.int32),
                   jnp.zeros((n_slots,), jnp.int32)]
        if paged:
            example.append(jnp.zeros(self.engine.pool.block_tables.shape,
                                     jnp.int32))
        self._example = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype),
            tuple(example))
        self._desc = f"serve:{model.cfg.name}:slots{n_slots}:len{max_len}" \
            + (f":paged{page_size}" if paged else "")
        entry, dt, hit = hv.reconfig.partial_reconfigure(
            self._decode_fn, self._example, static_desc=self._desc)
        self.engine.use_program(entry.compiled)
        self.program_fingerprint = entry.fingerprint
        hv._log("gateway_up", model=model.cfg.name, n_slots=n_slots,
                fingerprint=entry.fingerprint, compile_s=dt, cache_hit=hit,
                paged=paged)

    # ------------------------------------------------------------------
    # Tenant sessions
    # ------------------------------------------------------------------
    def _session_page_grant(self, slots: int) -> int:
        """A k-slot session's share of the engine's page pool (its vSlice
        memory dimension): proportional to its compute share."""
        if not self.paged:
            return 0
        return max(1, self.engine.pool.total_pages * slots
                   // self.engine.n_slots)

    def open_session(self, tenant: str, slots: int = 1,
                     service_model: str = "baas") -> TenantSession:
        if tenant in self._sessions:
            raise ValueError(f"tenant {tenant!r} already has a session")
        vs = self.hv.open_serving_session(
            tenant, slots, service_model,
            cache_pages=self._session_page_grant(slots))
        try:
            # bind the shared decode program to this tenant's slice (PR
            # swap — cache hit, microseconds; ALLOCATED -> CONFIGURED)
            self.hv.program_slice(vs.slice_id, self._decode_fn,
                                  self._example, static_desc=self._desc)
            # slice-aware scheduling: a k-slot vSlice holds k engine slots,
            # and its fair-share weight in the deficit round-robin is
            # proportional to the compute share it paid for
            self.engine.set_tenant_share(tenant, slots)
            self.engine.set_tenant_weight(tenant, slots)
            if self.paged:
                # memory-aware scheduling: the engine's admission gate
                # queues the tenant once it holds its vSlice page grant
                # (hv already clamped it to the service model's quota)
                self.engine.set_tenant_pages(tenant, vs.cache_pages or None)
        except Exception:
            # a failed bind must hand back the slice AND the tenant's
            # admission charge, or the tenant is stranded admitted against
            # a slice it can never decode on
            self.hv.close_serving_session(vs.slice_id)
            raise
        sess = TenantSession(tenant, vs.slice_id, slots, service_model)
        self._sessions[tenant] = sess
        return sess

    def close_session(self, tenant: str):
        sess = self._sessions.pop(tenant)
        # drop queued requests and settle ALL outstanding in-flight quota
        # now (requests still decoding finish as orphans — see _on_finish)
        self.engine.cancel_queued(tenant)
        for _ in range(max(0, sess.submitted - sess.served)):
            self.hv.admission.finish_request(tenant, sess.service_model)
        self.engine.set_tenant_share(tenant, None)
        self.engine.set_tenant_weight(tenant, None)
        self.engine.set_tenant_pages(tenant, None)
        self.hv.close_serving_session(sess.slice_id)

    def close(self):
        for tenant in list(self._sessions):
            self.close_session(tenant)
        try:
            self.hv.migration_listeners.remove(self._on_migration)
        except ValueError:
            pass    # already deregistered (close called twice)

    def session(self, tenant: str) -> TenantSession:
        return self._sessions[tenant]

    # ------------------------------------------------------------------
    # Request path
    # ------------------------------------------------------------------
    def submit(self, tenant: str, prompt, max_new_tokens: int = 16) -> Request:
        try:
            sess = self._sessions[tenant]
        except KeyError:
            raise KeyError(f"tenant {tenant!r} has no serving session "
                           "(call open_session first)") from None
        validate_submit(prompt, max_new_tokens, self.engine.max_len)
        self.hv.admit_serving_request(sess.slice_id, len(prompt),
                                      max_new_tokens)
        sess.submitted += 1
        try:
            req = self.engine.submit(prompt, max_new_tokens, tenant=tenant)
        except Exception:
            # an engine rejection (oversized request, paged worst-case
            # check) must hand back the quota charged two lines up
            sess.submitted -= 1
            self.hv.admission.finish_request(tenant, sess.service_model)
            raise
        # stamp the session identity: if the session is closed and reopened
        # while this request still decodes, the orphan must not be
        # attributed (or quota-settled) against the new session
        req._session = sess
        return req

    def cancel(self, req: Request) -> bool:
        """Cancel one request (queued or in flight — a timed-out client
        must not burn a slot until max_new_tokens). The engine fires
        ``on_finish``, so the quota settles like a completion."""
        return self.engine.cancel(req)

    def step(self) -> int:
        """One shared decode step across all tenants; periodically sweeps
        for straggling (hot) tenants and rebinds migrated sessions."""
        n = self.engine.step()
        if self.paged:
            self.hv.monitor.record_pages(self._device_key,
                                         self.engine.pool.used_pages,
                                         self.engine.pool.total_pages)
            self.hv.monitor.record_scrub(self._device_key,
                                         self.engine.pool.pages_scrubbed,
                                         self.engine.scrub_ms)
        if self.migrate_every and self.engine.steps \
                and self.engine.steps % self.migrate_every == 0:
            self.rebalance()
        return n

    def step_async(self, prefill_chunk: int = 4) -> int:
        """The chunked-prefill engine path (``BatchingEngine.step_async``)
        behind the same telemetry/rebalance plumbing as ``step`` — newly
        admitted prompts spend a few steps PREFILLING while the resident
        slots keep decoding, instead of stalling the whole batch."""
        n = self.engine.step_async(prefill_chunk)
        if self.paged:
            self.hv.monitor.record_pages(self._device_key,
                                         self.engine.pool.used_pages,
                                         self.engine.pool.total_pages)
            self.hv.monitor.record_scrub(self._device_key,
                                         self.engine.pool.pages_scrubbed,
                                         self.engine.scrub_ms)
        if self.migrate_every and self.engine.steps \
                and self.engine.steps % self.migrate_every == 0:
            self.rebalance()
        return n

    def run_until_idle(self, max_steps: int = 10000) -> bool:
        """Returns True when fully drained; False on a stall (max_steps
        expired, or queued work that can make no progress)."""
        for _ in range(max_steps):
            n = self.step()
            if self.engine.idle():
                return True
            if n == 0:
                return False
        return self.engine.idle()

    # ------------------------------------------------------------------
    # Telemetry -> control plane
    # ------------------------------------------------------------------
    def _on_step(self, active_by_tenant: Dict[str, int], step_ms: float):
        total = sum(active_by_tenant.values()) or 1
        for tenant, n in active_by_tenant.items():
            sess = self._sessions.get(tenant)
            if sess is None:
                continue
            # per-entitled-slot attribution: tenants using exactly their
            # share record equal times (no churn from mere size
            # differences); a slice on a slow/overloaded device records
            # consistently higher and is what the straggler policy catches
            self.hv.record_serving_step(
                sess.slice_id, step_ms * n / (total * sess.slots))

    def _on_finish(self, req: Request):
        settle_finished_request(self.hv, self._sessions, req)

    def _on_migration(self, old: str, new: str):
        for sess in self._sessions.values():
            if sess.slice_id == old:
                sess.slice_id = new
                self.migrations.append((old, new))

    def rebalance(self) -> List[Tuple[str, str]]:
        """Run the hypervisor's straggler sweep; migrated sessions are
        rebound by the migration listener."""
        self.hv.migrate_stragglers()
        return self.hv.last_migrations

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """OPERATOR view: every session's counters and quota. Anything a
        tenant can call must go through ``tenant_status`` instead."""
        return {t: {"slice": s.slice_id, "slots": s.slots,
                    "submitted": s.submitted, "served": s.served,
                    "tokens_out": s.tokens_out,
                    "quota": self.hv.admission.usage(t)}
                for t, s in self._sessions.items()}

    def tenant_status(self, tenant: str) -> dict:
        """Tenant-facing status: ONLY ``tenant``'s own session counters,
        quota usage, page holdings and slices. Notably absent: co-tenant
        names, shared-pool occupancy, fleet step medians — each is a
        side channel a hostile tenant could poll to profile co-residents
        (see ARCHITECTURE.md, tenant isolation & threat model)."""
        out = dict(self.hv.monitor.tenant_status(tenant))
        sess = self._sessions.get(tenant)
        if sess is not None:
            out["session"] = {"slice": sess.slice_id, "slots": sess.slots,
                              "submitted": sess.submitted,
                              "served": sess.served,
                              "tokens_out": sess.tokens_out}
        out["quota"] = self.hv.admission.usage(tenant)
        if self.paged:
            out["pages_held"] = self.engine.pool.tenant_pages(tenant)
        return out

    def page_stats(self) -> dict:
        return self.engine.page_stats()
