"""Host-side page accounting for the paged KV-cache pool.

The device holds one shared page pool per layer (``models.stages.
init_paged_cache``); this module owns everything the pool needs a host
brain for: the free list, per-slot block tables, page refcounts,
copy-on-write arbitration, the tenant-scoped prefix cache, and per-tenant
page accounting (the enforcement point for the vSlice/admission
``max_cache_pages_per_tenant`` quota).

Page 0 is reserved as the null/scratch page: unused block-table entries
point at it and inactive batch rows write their discarded k/v there with
pos -1, so a gather through any block table never sees a valid-looking
stale position.

Prefix sharing is content-addressed and strictly intra-tenant: block j of
a context is keyed by a keyed-BLAKE2b hash chain over its token values,
seeded with a per-tenant salt, so two concurrent requests of one tenant
with a common prompt prefix share physical pages by refcount — while two
*different* tenants' identical prompts produce unrelated keys (no
cross-tenant hash-collision probe; Python's builtin ``hash`` is neither
collision-resistant nor stable across processes). A partially filled
tail page is shared on an exact-content match and copy-on-written the
moment a branch writes into it; registrations die with their pages
(sharing is among temporally overlapping requests — there is no retained
cache to evict).

Zero-on-free: with ``scrub_on_free`` (the default) every page whose
refcount drops to zero is queued for a device-side scrub. The pool is
host-only, so it never touches device memory itself — the engine drains
``take_scrub()`` and runs one batched, jitted zeroing kernel before its
next allocation point. ``_alloc_one`` refuses to hand out a page whose
scrub is still pending: a missed flush fails loudly instead of leaking
the previous tenant's KV values (or, worse, scrubbing the new tenant's).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Hashable, List, Optional, Tuple

import numpy as np

from repro.analysis.lifecycle import sanitizer


class NoPagesError(RuntimeError):
    """Internal guard: the engine must pre-check ``pages_needed`` /
    ``free_pages`` before allocating, so user traffic queues instead of
    ever seeing this."""


def default_pool_pages(n_slots: int, max_blocks: int) -> int:
    """Default pool size: dense-equivalent capacity (one full-length row
    per slot) plus the reserved null page. The single source for every
    layer that sizes or grants against the default pool (engine, fleet)."""
    return n_slots * max_blocks + 1


@dataclasses.dataclass
class AdmitPlan:
    """What the engine must still do after pages were assigned to a slot."""
    blocks: List[int]          # full page-id list for the slot's block table
    write_start: int           # first block index this request must write
    skip_prefill: bool         # every written position was prefix-shared
    matched_pages: int         # pages reused from the prefix cache

    @property
    def write_pages(self) -> List[int]:
        return self.blocks[self.write_start:]


class PagePoolManager:
    """Free list + block tables + refcounts + prefix cache for one engine."""

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 max_blocks: int, scrub_on_free: bool = True):
        if n_pages < 2:
            raise ValueError("pool needs >= 2 pages (page 0 is reserved)")
        self.n_pages = n_pages
        self.page_size = page_size
        self.max_blocks = max_blocks
        # LIFO free list: recently freed pages are re-used first (their
        # content is hottest in any cache hierarchy)
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._ref = np.zeros((n_pages,), np.int32)
        self._ref[0] = 1                       # null page: never allocated
        self._owner: Dict[int, str] = {}       # page -> charging tenant
        self._tenant_pages: Dict[str, int] = {}
        self.block_tables = np.zeros((n_slots, max_blocks), np.int32)
        self._slot_pages: List[List[int]] = [[] for _ in range(n_slots)]
        self._prefix: Dict[Hashable, int] = {}       # content key -> page
        self._page_key: Dict[int, Hashable] = {}     # page -> its key
        self.prefix_hits = 0
        self.cow_copies = 0
        # zero-on-free policy: freed pages queue here until the engine
        # drains take_scrub() into one batched device-side zeroing
        self.scrub_on_free = scrub_on_free
        self._pending_scrub: List[int] = []
        self.pages_scrubbed = 0
        # bumped on every block-table mutation: the engine keys its cached
        # device copy of the tables on this, so steady-state decode skips
        # the per-step host->device re-upload
        self.version = 0
        self._san = sanitizer.scope()   # namespaces this pool's page keys

    # ---------------- occupancy ----------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def total_pages(self) -> int:
        """Allocatable pages (page 0 excluded)."""
        return self.n_pages - 1

    @property
    def used_pages(self) -> int:
        return self.total_pages - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.used_pages / max(1, self.total_pages)

    def tenant_pages(self, tenant: str) -> int:
        return self._tenant_pages.get(tenant, 0)

    def pages_by_tenant(self) -> Dict[str, int]:
        return {t: n for t, n in self._tenant_pages.items() if n}

    def slot_blocks(self, slot: int) -> List[int]:
        return self._slot_pages[slot]

    # ---------------- page lifecycle ----------------
    def _alloc_one(self, tenant: str) -> int:
        if not self._free:
            raise NoPagesError("page pool exhausted")
        pid = self._free.pop()
        assert pid not in self._pending_scrub, \
            f"page {pid} reallocated before its zero-on-free scrub was " \
            f"flushed — the caller must drain take_scrub() before allocating"
        sanitizer.emit("page", (self._san, pid), "alloc")
        self._ref[pid] = 1
        self._owner[pid] = tenant
        self._tenant_pages[tenant] = self._tenant_pages.get(tenant, 0) + 1
        return pid

    def _decref(self, pid: int):
        sanitizer.emit("page", (self._san, pid),
                       "free" if self._ref[pid] == 1 else "unshare")
        self._ref[pid] -= 1
        if self._ref[pid] == 0:
            key = self._page_key.pop(pid, None)
            if key is not None:
                self._prefix.pop(key, None)
            tenant = self._owner.pop(pid)
            self._tenant_pages[tenant] -= 1
            if not self._tenant_pages[tenant]:
                del self._tenant_pages[tenant]
            self._free.append(pid)
            if self.scrub_on_free:
                self._pending_scrub.append(pid)

    def _register(self, key: Hashable, pid: int):
        # first writer wins; identical content by construction
        if key not in self._prefix and pid not in self._page_key:
            self._prefix[key] = pid
            self._page_key[pid] = key

    # ---------------- zero-on-free ----------------
    @property
    def scrub_pending(self) -> int:
        return len(self._pending_scrub)

    def take_scrub(self) -> List[int]:
        """Drain the zero-on-free queue. The caller (the engine) owns the
        actual device-side zeroing — it must scrub exactly these pages
        before its next allocation, and every queued page is still on the
        free list when this returns (``_alloc_one`` enforces it)."""
        pids, self._pending_scrub = self._pending_scrub, []
        for pid in pids:
            sanitizer.emit("page", (self._san, pid), "scrub")
        self.pages_scrubbed += len(pids)
        return pids

    # ---------------- prefix matching ----------------
    @staticmethod
    def _chain_seed(tenant: str) -> int:
        """Per-tenant salt for the content-hash chain: keyed BLAKE2b, so
        identical prompts from different tenants map to unrelated key
        chains and no tenant can probe another's cache by hash collision
        (``hash()`` would be forgeable and PYTHONHASHSEED-unstable)."""
        d = hashlib.blake2b(repr(tenant).encode("utf-8"),
                            key=b"rc3e-kvpfx", digest_size=16).digest()
        return int.from_bytes(d, "big")

    @staticmethod
    def _chain_step(h: int, toks) -> int:
        data = h.to_bytes(16, "big") + b"".join(
            int(t).to_bytes(8, "big", signed=True) for t in toks)
        d = hashlib.blake2b(data, key=b"rc3e-kvpfx", digest_size=16).digest()
        return int.from_bytes(d, "big")

    def _block_keys(self, tenant: str, toks) -> List[Hashable]:
        """Hash chain over full, content-complete blocks of a context.
        Block j is content-complete once prefill has written all of its
        positions, i.e. (j+1)*ps <= len(toks) - 1 (position len-1 is
        written by the first decode step, not prefill)."""
        ps = self.page_size
        full = (len(toks) - 1) // ps
        keys, h = [], self._chain_seed(tenant)
        for j in range(full):
            h = self._chain_step(h, toks[j * ps:(j + 1) * ps])
            keys.append(h)
        return keys

    def _tail_key(self, tenant: str, toks) -> Optional[Hashable]:
        """Exact-content key for the partially filled tail page (positions
        full*ps .. len(toks)-2), or None when the tail is empty."""
        ps = self.page_size
        n = len(toks)
        full = (n - 1) // ps
        if (n - 1) % ps == 0:
            return None
        keys = self._block_keys(tenant, toks)
        h = keys[-1] if keys else self._chain_seed(tenant)
        return ("tail", h, tuple(int(t) for t in toks[full * ps:n - 1]))

    def _match(self, tenant: str, toks) -> Tuple[List[int], int]:
        """(shared page ids, total blocks) for a context, read-only."""
        n = len(toks)
        total = (n - 1) // self.page_size + 1
        shared: List[int] = []
        keys = self._block_keys(tenant, toks)
        for key in keys:
            pid = self._prefix.get(key)
            if pid is None:
                break
            shared.append(pid)
        if len(shared) == len(keys):
            tkey = self._tail_key(tenant, toks)
            if tkey is not None:
                pid = self._prefix.get(tkey)
                if pid is not None:
                    shared.append(pid)
        return shared, total

    def pages_needed(self, tenant: str, toks, share: bool = True) -> int:
        """Fresh pages a context would allocate at admission (read-only —
        the engine's queue-on-exhaustion check)."""
        if not share:
            return (len(toks) - 1) // self.page_size + 1
        shared, total = self._match(tenant, toks)
        return total - len(shared)

    # ---------------- slot admission / growth ----------------
    def admit(self, slot: int, tenant: str, toks,
              share: bool = True) -> AdmitPlan:
        """Assign pages for context ``toks`` (prompt + generated so far,
        including the token the first decode step consumes): prefix-matched
        pages by refcount, the rest freshly allocated. Builds the slot's
        block-table row and registers this context's content keys.
        ``share=False`` (legacy prefill, which writes every position)
        allocates everything fresh and registers nothing."""
        n = len(toks)
        total = (n - 1) // self.page_size + 1
        if total > self.max_blocks:
            raise ValueError(f"context of {n} tokens needs {total} blocks, "
                             f"table has {self.max_blocks}")
        shared = self._match(tenant, toks)[0] if share else []
        for pid in shared:
            sanitizer.emit("page", (self._san, pid), "share")
            self._ref[pid] += 1
            self.prefix_hits += 1
        fresh: List[int] = []
        try:
            for _ in range(total - len(shared)):
                fresh.append(self._alloc_one(tenant))
        except NoPagesError:
            # roll back BOTH halves: pages allocated before the exhaustion
            # point and the shared-page increfs
            for pid in fresh:
                self._decref(pid)
            for pid in shared:
                self._decref(pid)
            raise
        blocks = shared + fresh
        self.block_tables[slot, :] = 0
        self.block_tables[slot, :total] = blocks
        self._slot_pages[slot] = list(blocks)
        self.version += 1
        if share:
            # register what this request will write: content-complete full
            # blocks, plus its tail page (exact content) if it owns one
            keys = self._block_keys(tenant, toks)
            for j in range(len(shared), len(keys)):
                self._register(keys[j], blocks[j])
            full = len(keys)
            if len(shared) <= full:  # tail page not among the shared ones
                tkey = self._tail_key(tenant, toks)
                if tkey is not None:
                    self._register(tkey, blocks[full])
        return AdmitPlan(blocks=blocks, write_start=len(shared),
                         skip_prefill=len(shared) == total,
                         matched_pages=len(shared))

    def grow(self, slot: int, tenant: str) -> int:
        """Append one fresh page to a slot (decode crossed a page
        boundary). Caller pre-checks ``free_pages`` and tenant budget."""
        pid = self._alloc_one(tenant)
        bi = len(self._slot_pages[slot])
        self.block_tables[slot, bi] = pid
        self._slot_pages[slot].append(pid)
        self.version += 1
        return pid

    # ---------------- copy-on-write ----------------
    def is_shared(self, slot: int, block: int) -> bool:
        return self._ref[self._slot_pages[slot][block]] > 1

    def cow(self, slot: int, block: int, tenant: str) -> Tuple[int, int]:
        """Detach a shared page before this slot writes it: allocate a
        private copy target and repoint the block table. Returns
        (src, dst); the engine performs the actual device copy."""
        src = self._slot_pages[slot][block]
        dst = self._alloc_one(tenant)
        # route through _decref, never a bare ref decrement: if the other
        # holder released between the is_shared check and here, src must
        # take the full free path (prefix-key retirement, tenant
        # accounting, scrub queue) — a bare decrement would strand a
        # dangling _page_key entry on a free page
        self._decref(src)
        self._slot_pages[slot][block] = dst
        self.block_tables[slot, block] = dst
        self.cow_copies += 1
        self.version += 1
        return src, dst

    def touch_write(self, slot: int, block: int):
        """A privately held page is about to be mutated: retire its tail
        registration (its content will no longer match the key). Full-block
        registrations are immutable — decode never writes into a
        content-complete block."""
        pid = self._slot_pages[slot][block]
        key = self._page_key.get(pid)
        if key is not None and isinstance(key, tuple) and key[0] == "tail":
            del self._page_key[pid]
            self._prefix.pop(key, None)

    # ---------------- release ----------------
    def release_slot(self, slot: int):
        for pid in self._slot_pages[slot]:
            self._decref(pid)
        self._slot_pages[slot] = []
        self.block_tables[slot, :] = 0
        self.version += 1

    # ---------------- invariants ----------------
    def verify(self) -> None:
        """Machine-checked conservation invariants — the chaos harness and
        the property suite call this after every event:

          * ``free + referenced == total`` (no page leaked, none lost);
          * the free list holds no duplicates and only ref==0 pages;
          * every referenced page's refcount equals the number of slots
            holding it (registrations never outlive their pages);
          * per-tenant accounting sums exactly to the referenced pages;
          * block tables mirror the slot page lists (tail zeroed);
          * the prefix cache and its reverse map are a bijection onto
            live pages;
          * no free page retains a dangling prefix key or owner entry;
          * the zero-on-free queue is a duplicate-free subset of the
            free list (a scrub can never hit a reallocated page).

        Raises AssertionError on the first violation.
        """
        assert self._ref[0] == 1, "null page refcount must stay pinned at 1"
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "free-list duplicate " \
            "(double-free)"
        assert 0 not in free_set, "null page on the free list"
        # iterate the free LIST, not the set: set order is salted per
        # process and would make any failure message non-reproducible
        for pid in self._free:
            assert self._ref[pid] == 0, f"free page {pid} has refcount " \
                f"{self._ref[pid]}"
            assert pid not in self._page_key, \
                f"free page {pid} retains a dangling prefix key " \
                f"{self._page_key[pid]!r}"
            assert pid not in self._owner, \
                f"free page {pid} retains an owner entry"
        pending = set(self._pending_scrub)
        assert len(pending) == len(self._pending_scrub), \
            "page queued for scrub twice"
        assert pending <= free_set, \
            f"scrub queue holds non-free pages {sorted(pending - free_set)}"
        referenced = [p for p in range(1, self.n_pages) if self._ref[p] > 0]
        assert len(referenced) + len(self._free) == self.total_pages, \
            f"page conservation broken: {len(referenced)} referenced + " \
            f"{len(self._free)} free != {self.total_pages} total"
        holders: Dict[int, int] = {}
        for slot, pages in enumerate(self._slot_pages):
            for bi, pid in enumerate(pages):
                assert self._ref[pid] > 0, \
                    f"slot {slot} holds freed page {pid}"
                assert self.block_tables[slot, bi] == pid, \
                    f"block table desync at slot {slot} block {bi}"
                holders[pid] = holders.get(pid, 0) + 1
            assert not self.block_tables[slot, len(pages):].any(), \
                f"slot {slot} block-table tail not zeroed"
        for pid in referenced:
            assert self._ref[pid] == holders.get(pid, 0), \
                f"page {pid} refcount {self._ref[pid]} != " \
                f"{holders.get(pid, 0)} slot holders"
        assert sum(self._tenant_pages.values()) == len(referenced), \
            "tenant page accounting != referenced pages"
        assert set(self._owner) == set(referenced), \
            "owner map out of sync with referenced pages"
        for key, pid in self._prefix.items():
            assert self._page_key.get(pid) == key, \
                f"prefix entry for page {pid} lost its reverse mapping"
            assert self._ref[pid] > 0, f"prefix cache points at freed " \
                f"page {pid}"
        for pid, key in self._page_key.items():
            assert self._prefix.get(key) == pid, \
                f"reverse prefix mapping for page {pid} dangling"

    # ---------------- introspection ----------------
    def stats(self) -> dict:
        return {
            "page_size": self.page_size,
            "pages_total": self.total_pages,
            "pages_used": self.used_pages,
            "pages_free": self.free_pages,
            "occupancy": round(self.occupancy, 4),
            "by_tenant": self.pages_by_tenant(),
            "prefix_hits": self.prefix_hits,
            "cow_copies": self.cow_copies,
            "scrub_on_free": self.scrub_on_free,
            "pages_scrubbed": self.pages_scrubbed,
            "scrub_pending": self.scrub_pending,
        }
