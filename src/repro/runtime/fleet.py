"""Multi-device serving fleet: the hypervisor's placement decisions made
real at the dataplane (paper §IV load-distribution role + the outlook's
"migration of user designs between vFPGAs and physical FPGAs").

``ServingGateway`` binds every tenant to a hypervisor vSlice but decodes
everyone on ONE engine, so a migration only moved bookkeeping. The
``GatewayFleet`` closes that gap:

  * one ``BatchingEngine`` per ACTIVE physical device — the engine IS the
    device's dataplane, its KV caches are that device's memory;
  * ``open_session`` places a tenant on the engine backing its vSlice's
    device, so the DeviceDB's pack-first energy policy decides where
    decoding actually happens;
  * ``migrate_stragglers`` (or a directed ``Hypervisor.migrate_slice``)
    triggers a LIVE hand-off: the tenant's queued + in-flight requests are
    drained from the source engine and resumed on the target's, with
    already-generated tokens preserved via prompt-prefix replay; the shared
    decode program is PR-swapped from the ``ProgramCache`` (a hit,
    microseconds — the paper's partial-reconfiguration argument);
  * elastic scaling wired to ``ElasticController`` and the energy policy:
    a deep aggregate backlog wakes a PARKED device and moves the hottest
    tenant onto it; empty idle devices drain back to PARKED;
  * crash-consistent failover (paper §IV: the hypervisor monitors the
    physical devices so user designs survive device events): a recovery
    journal records every unfinished request's prompt + generated-token
    log, and ``recover_device`` re-places a dead device's sessions on
    surviving/woken engines, resuming in-flight requests by prefix replay
    — no live source engine needed, quota and pages settled exactly once.
    ``runtime/faults.py``'s seeded ``FaultInjector`` drives it all under
    test (``tests/test_chaos.py``).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.lifecycle import sanitizer
from repro.configs.base import GeometryConfig
from repro.core.device_db import DeviceState, SliceState
from repro.core.elastic import ElasticController
from repro.core.hypervisor import Hypervisor
from repro.models.api import Model
from repro.runtime.faults import FaultInjector
from repro.runtime.gateway import (TenantSession, settle_finished_request,
                                   validate_submit)
from repro.runtime.paged import default_pool_pages
from repro.runtime.serve import (BatchingEngine, Request, _req_event,
                                 make_paged_serve_step, make_serve_step)
from repro.tuning import TunedConfig, device_class, resolve_tuned


def _mark_cancelled(req: Request) -> None:
    """Stamp a request cancelled outside any engine (caught in transit
    between engines, or torn down with an evicted session)."""
    _req_event(req, "cancel")
    req.finish_reason = "cancelled"
    req.finished_at = time.monotonic()
    req.done.set()


@dataclasses.dataclass
class _ProgramBundle:
    """One kernel/pool geometry's compile-ready serving program: the
    geometry-carrying model, its serve-step fn, the abstract example the
    reconfigurator keys on, and the pool dimensions the engines built for
    this geometry must use. ``tuned is None`` is the fleet's default
    (constructor args, hand-picked kernel blocks); autotuned fleets hold
    one bundle per device class. ``fingerprint`` is stamped at first
    compile (``_ensure_engine``) so failover can re-mark slices with the
    program they actually run."""
    tuned: Optional[TunedConfig]
    model: Model
    decode_fn: object
    example: tuple
    desc: str
    geometry: str
    n_slots: int
    page_size: int
    fingerprint: Optional[str] = None


@dataclasses.dataclass
class JournalEntry:
    """One unfinished request's durable record in the fleet's recovery
    journal: everything failover needs to resume it on another engine
    WITHOUT a live source — the prompt lives on the request, the
    generated-token log is this entry's own copy (synced after every
    fleet step), and quota state is implied by the entry's existence
    (journaled == admitted and not yet settled)."""
    req: Request
    tenant: str
    tokens: List[int] = dataclasses.field(default_factory=list)


class GatewayFleet:
    """Routes serving traffic for one model across every active device.

    One engine per physical device; tenants land on the engine backing
    their vSlice and FOLLOW their vSlice when the hypervisor re-places it.
    """

    def __init__(self, hv: Hypervisor, model: Model, params,
                 n_slots: int = 4, max_len: int = 256,
                 eos_id: Optional[int] = None, migrate_every: int = 0,
                 autoscale_every: int = 0, scale_up_queue_depth: int = 8,
                 paged: bool = False, page_size: int = 16,
                 cache_pages: Optional[int] = None,
                 page_pressure: float = 0.85,
                 slo_p95_steps: Optional[float] = None,
                 slo_horizon: int = 16,
                 scale_in_margin: float = 0.5,
                 faults: Optional[FaultInjector] = None,
                 autotune: bool = False):
        # fail fast, before any session can allocate: lazy engine creation
        # must never be the first place this surfaces (it would strand an
        # admitted tenant and its vSlice)
        if model.cfg.ssm is not None:
            raise ValueError("GatewayFleet serves attention-family models; "
                             "use jit_serve_step for SSM archs")
        if paged and model.cfg.mla is not None:
            raise ValueError("paged KV caches support plain-attention "
                             "models (MLA latents are not paged)")
        self.hv = hv
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.paged = paged
        self.page_size = page_size
        self.cache_pages = cache_pages
        self.page_pressure = page_pressure       # occupancy scale-out trigger
        self.migrate_every = migrate_every       # steps between sweeps
        self.autoscale_every = autoscale_every   # steps between autoscale
        self.scale_up_queue_depth = scale_up_queue_depth
        # SLO-driven elasticity (opt-in): when a p95 target (in fleet
        # steps) is set, autoscale additionally wakes devices on a
        # PROJECTED p95 breach from the monitor's arrival/service-rate
        # trend, and consolidates (parks highest-draw devices first) when
        # the projection sits under scale_in_margin * slo with no backlog.
        self.slo_p95_steps = slo_p95_steps
        self.slo_horizon = slo_horizon
        self.scale_in_margin = scale_in_margin
        self.autoscale_log: List[dict] = []
        # open-loop traffic counters, drained into the monitor every step
        self._arrivals_since_step = 0
        self._completions_since_step = 0
        self._dev_completions: Dict[str, int] = {}   # per-device, same window
        # energy integral: sum over steps of the un-parked fleet's class
        # draw (device-steps x draw; PARKED/DEAD devices are free)
        self.energy = 0.0
        self.elastic = ElasticController(hv)
        # deterministic chaos: when an injector is attached, every step()
        # ticks it (clock + heartbeats + scheduled faults) and runs the
        # heartbeat/failover sweep. Without one, the sweep stays off so a
        # slow wall-clock test run can never spuriously declare nodes dead.
        self.faults = faults
        # recovery journal: request_id -> JournalEntry for every admitted,
        # not-yet-settled request. THE source of truth for failover — a
        # dead device's engine (queues, slots, KV pages) is gone, but the
        # journal re-creates its traffic by prefix replay elsewhere.
        self.journal: Dict[int, JournalEntry] = {}
        # Event-driven journal mode (set by runtime.events.EventLoop):
        # instead of copying every inflight request's token log after
        # every engine step, step_engine only MARKS entries dirty and the
        # event loop batches the copies off the critical path
        # (flush_journal on its own cadence). The hard flush barrier:
        # _retire_entry (quota settle) and the hand-off export path flush
        # per-request first — machine-enforced, since the journal machine
        # rejects retire from DIRTY.
        self.journal_lazy = False
        self._dirty: Dict[int, bool] = {}        # insertion-ordered rids
        # Overlapped hand-off (event mode): the EventLoop installs a hook
        # that exports pages WITHOUT draining and schedules the completion
        # a few ticks later, letting the source keep decoding during the
        # copy. Sources mid-copy (and scale-in drain targets) sit in
        # _draining so autoscale's backlog sample skips them.
        self._handoff_hook = None
        self._event_driven = False               # EventQueue owns the clock
        self._draining: set = set()
        self._inflight_handoffs: Dict[str, int] = {}
        self._san = sanitizer.scope()    # journal-machine key namespace
        self.recoveries: List[dict] = []
        # one id stream for the whole fleet: request ids must stay unique
        # across engines (audit log + hand-off both key on them)
        self._req_ids = itertools.count()
        self._engines: Dict[str, BatchingEngine] = {}    # device_id -> engine
        self._sessions: Dict[str, TenantSession] = {}
        self._device_of: Dict[str, str] = {}             # tenant -> device_id
        self.migrations: List[Tuple[str, str]] = []
        self.handoffs: List[dict] = []
        self.steps = 0
        self.last_round_ms: Dict[str, float] = {}        # per-device step wall

        # Per-device-class auto-tuning (opt-in): when set, each engine
        # binds the geometry the design-space tuner picked for ITS
        # device's class — kernel block sizes, slot count, KV page size —
        # resolved through the ProgramCache's tuned-config store. Off by
        # default so every engine shares ONE program (one fingerprint,
        # PR cache hits fleet-wide — the paper's shared-bitstream case).
        self.autotune = autotune
        self._bundles: Dict[str, _ProgramBundle] = {}   # device class -> b

        # Compile the decode step ONCE through the hypervisor's
        # reconfigurator (full configuration); every engine spun up after
        # that binds the same executable — a PR cache hit per device.
        # (Autotuned fleets still compile this default bundle: it is the
        # failover fallback and the geometry control arm.)
        if paged and max_len % page_size:
            raise ValueError(f"max_len {max_len} must be a multiple of "
                             f"page_size {page_size}")
        bundle = self._make_bundle(None)
        self._default_bundle = bundle
        self._decode_fn = bundle.decode_fn
        self._example = bundle.example
        self._desc = bundle.desc
        entry, dt, hit = hv.reconfig.partial_reconfigure(
            self._decode_fn, self._example, static_desc=self._desc)
        self.program_fingerprint = bundle.fingerprint = entry.fingerprint
        hv._log("fleet_up", model=model.cfg.name, n_slots=n_slots,
                fingerprint=entry.fingerprint, compile_s=dt, cache_hit=hit,
                paged=paged, autotune=autotune)
        # register LAST: a constructor failure above must not leave a
        # dead fleet's listener on the shared hypervisor
        hv.migration_listeners.append(self._on_migration)

    # ------------------------------------------------------------------
    # Program bundles (one geometry = one executable)
    # ------------------------------------------------------------------
    def _make_bundle(self, tuned: Optional[TunedConfig]) -> _ProgramBundle:
        """Build the compile-ready program for one geometry. ``None`` is
        the fleet default (constructor args); a ``TunedConfig`` threads
        the tuner's kernel block sizes through the model config and sizes
        the serve-step example with the tuned slot count / page size, so
        each geometry traces (and caches) as its own executable."""
        if tuned is None:
            model = self.model
            n_slots, page_size, geometry = self.n_slots, self.page_size, ""
        else:
            geom = GeometryConfig(
                decode_block_k=tuned.decode_block_k,
                flash_block_q=tuned.flash_block_q,
                flash_block_k=tuned.flash_block_k,
                mm_block_m=tuned.mm_block_m,
                mm_block_n=tuned.mm_block_n,
                mm_block_k=tuned.mm_block_k,
                kernel_force=self.model.cfg.geometry.kernel_force)
            model = Model(self.model.cfg.replace(geometry=geom))
            n_slots, page_size = tuned.n_slots, tuned.page_size
            geometry = tuned.geometry_key()
        example = [self.params, None,
                   jnp.zeros((n_slots, 1), jnp.int32),
                   jnp.zeros((n_slots,), jnp.int32)]
        if self.paged:
            max_blocks = self.max_len // page_size
            pages = self.cache_pages if self.cache_pages is not None \
                else default_pool_pages(n_slots, max_blocks)
            decode_fn = make_paged_serve_step(model)
            example[1] = jax.eval_shape(
                lambda: model.make_paged_caches(pages, page_size))
            example.append(jnp.zeros((n_slots, max_blocks), jnp.int32))
        else:
            decode_fn = make_serve_step(model)
            example[1] = jax.eval_shape(
                lambda: model.make_caches(n_slots, self.max_len))
        example = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype),
            tuple(example))
        desc = f"serve:{model.cfg.name}:slots{n_slots}:len{self.max_len}" \
            + (f":paged{page_size}" if self.paged else "") \
            + (f":geom{geometry}" if geometry else "")
        return _ProgramBundle(tuned, model, decode_fn, example, desc,
                              geometry, n_slots, page_size)

    def _bundle_for(self, device_id: str) -> _ProgramBundle:
        """The program bundle a device binds: the tuned geometry of its
        device class when autotuning, the shared default otherwise. Tuned
        configs persist in the ProgramCache keyed (model fp, class), so a
        class's sweep runs once per cache lifetime — every later bind
        (including cross-class hand-off destinations) is a lookup."""
        if not self.autotune:
            return self._default_bundle
        speed = self.hv.db.devices[device_id].speed
        cls = device_class(speed)
        bundle = self._bundles.get(cls)
        if bundle is None:
            tuned = resolve_tuned(self.hv.reconfig.cache, self.model.cfg,
                                  speed, max_len=self.max_len,
                                  paged=self.paged)
            bundle = self._make_bundle(tuned)
            self._bundles[cls] = bundle
            self.hv._log("autotune_bind", device_class=cls,
                         geometry=bundle.geometry, n_slots=bundle.n_slots,
                         page_size=bundle.page_size)
        return bundle

    def prefill_chunk_for(self, device_id: str,
                          default: Optional[int]) -> Optional[int]:
        """Tuned prefill chunk length for a device's class (the event
        loop's chunked-prefill cadence); the caller's default when
        autotuning is off or the caller runs lockstep (``None``)."""
        if default is None or not self.autotune:
            return default
        bundle = self._bundle_for(device_id)
        return bundle.tuned.prefill_chunk if bundle.tuned is not None \
            else default

    # ------------------------------------------------------------------
    # Engine lifecycle (one per active device)
    # ------------------------------------------------------------------
    def _ensure_engine(self, device_id: str) -> BatchingEngine:
        eng = self._engines.get(device_id)
        if eng is not None:
            return eng
        bundle = self._bundle_for(device_id)
        eng = BatchingEngine(bundle.model, self.params,
                             n_slots=bundle.n_slots,
                             max_len=self.max_len, eos_id=self.eos_id,
                             id_counter=self._req_ids, paged=self.paged,
                             page_size=bundle.page_size,
                             cache_pages=self.cache_pages)
        entry, dt, hit = self.hv.reconfig.partial_reconfigure(
            bundle.decode_fn, bundle.example, static_desc=bundle.desc,
            geometry=bundle.geometry)
        bundle.fingerprint = entry.fingerprint
        eng.use_program(entry.compiled)
        eng.on_step = lambda active, ms, dev=device_id: \
            self._on_step(dev, active, ms)
        eng.on_finish = self._on_finish
        self._engines[device_id] = eng
        self.hv._log("engine_up", device=device_id,
                     fingerprint=entry.fingerprint, swap_s=dt, cache_hit=hit,
                     geometry=bundle.geometry or "default")
        return eng

    def park_idle_engines(self) -> List[str]:
        """Drop engines whose device hosts no slices and whose queues/slots
        are empty — the device itself is already PARKED (energy policy);
        this releases its dataplane (KV caches) too."""
        parked = []
        for dev, eng in list(self._engines.items()):
            if eng.idle() and not self.hv.db.device(dev).slices:
                del self._engines[dev]
                self.hv.monitor.clear_pages(dev)
                self.hv.monitor.clear_traffic(dev)
                parked.append(dev)
                self.hv._log("engine_park", device=dev)
        return parked

    def engine_for(self, tenant: str) -> BatchingEngine:
        return self._engines[self._device_of[tenant]]

    def device_of(self, tenant: str) -> str:
        return self._device_of[tenant]

    # ------------------------------------------------------------------
    # Tenant sessions
    # ------------------------------------------------------------------
    def _session_page_grant(self, slots: int) -> int:
        """A k-slot session's share of one engine's page pool (the vSlice
        memory dimension)."""
        if not self.paged:
            return 0
        pages = self.cache_pages if self.cache_pages is not None \
            else default_pool_pages(self.n_slots,
                                    self.max_len // self.page_size)
        return max(1, (pages - 1) * slots // self.n_slots)

    def open_session(self, tenant: str, slots: int = 1,
                     service_model: str = "baas") -> TenantSession:
        if tenant in self._sessions:
            raise ValueError(f"tenant {tenant!r} already has a session")
        vs = self.hv.open_serving_session(
            tenant, slots, service_model,
            cache_pages=self._session_page_grant(slots))
        try:
            engine = self._ensure_engine(vs.device_id)
            # PR-swap the decode program onto this tenant's slice — the
            # bundle of the device's class, so an autotuned fleet binds
            # tuned geometry with zero operator input
            bundle = self._bundle_for(vs.device_id)
            self.hv.program_slice(vs.slice_id, bundle.decode_fn,
                                  bundle.example, static_desc=bundle.desc,
                                  geometry=bundle.geometry)
            engine.set_tenant_share(tenant, slots)
            engine.set_tenant_weight(tenant, slots)
            if self.paged:
                engine.set_tenant_pages(tenant, vs.cache_pages or None)
        except Exception:
            # undo the allocation + quota: a failed open must not strand
            # the tenant admitted against a slice it can never use
            self.hv.close_serving_session(vs.slice_id)
            raise
        sess = TenantSession(tenant, vs.slice_id, slots, service_model)
        self._sessions[tenant] = sess
        self._device_of[tenant] = vs.device_id
        return sess

    def close_session(self, tenant: str):
        sess = self._sessions.pop(tenant)
        dev = self._device_of.pop(tenant)
        engine = self._engines.get(dev)
        if engine is not None:
            for r in engine.cancel_queued(tenant):
                self._retire_entry(r.request_id)
            engine.set_tenant_share(tenant, None)
            engine.set_tenant_weight(tenant, None)
            engine.set_tenant_pages(tenant, None)
        self._settle_outstanding(sess)
        self.hv.close_serving_session(sess.slice_id)

    def _settle_outstanding(self, sess: TenantSession):
        """Return a closing session's unfinished in-flight quota (requests
        still decoding finish as orphans and are not re-settled — see
        ``settle_finished_request``'s session-identity guard)."""
        for _ in range(max(0, sess.submitted - sess.served)):
            self.hv.admission.finish_request(sess.tenant, sess.service_model)

    def close(self):
        for tenant in list(self._sessions):
            self.close_session(tenant)
        self.park_idle_engines()
        try:
            self.hv.migration_listeners.remove(self._on_migration)
        except ValueError:
            pass    # already deregistered (close called twice)

    def session(self, tenant: str) -> TenantSession:
        return self._sessions[tenant]

    # ------------------------------------------------------------------
    # Request path
    # ------------------------------------------------------------------
    def submit(self, tenant: str, prompt, max_new_tokens: int = 16) -> Request:
        try:
            sess = self._sessions[tenant]
        except KeyError:
            raise KeyError(f"tenant {tenant!r} has no serving session "
                           "(call open_session first)") from None
        validate_submit(prompt, max_new_tokens, self.max_len)
        self.hv.admit_serving_request(sess.slice_id, len(prompt),
                                      max_new_tokens)
        sess.submitted += 1
        try:
            req = self.engine_for(tenant).submit(prompt, max_new_tokens,
                                                 tenant=tenant)
        except Exception:
            # an engine rejection (oversized request, paged worst-case
            # check) must hand back the quota charged two lines up, or the
            # tenant's in-flight count leaks one slot per failed submit
            sess.submitted -= 1
            self.hv.admission.finish_request(tenant, sess.service_model)
            raise
        req._session = sess
        sanitizer.emit("journal", (self._san, req.request_id), "append")
        self.journal[req.request_id] = JournalEntry(req, tenant)
        self._arrivals_since_step += 1
        return req

    # ------------------------------------------------------------------
    # Recovery journal (lazy sync + the flush barrier)
    # ------------------------------------------------------------------
    def _retire_entry(self, request_id: int, crashed: bool = False) -> bool:
        """Pop a journal entry THROUGH the flush barrier: a DIRTY entry is
        flushed first (live paths — the copy itself is moot since the
        entry is discarded, but the transition is what the journal machine
        checks) or rolled back (crash paths abandon unflushed tokens).
        Retiring from DIRTY directly is illegal under RC3E_SANITIZE=1."""
        entry = self.journal.pop(request_id, None)
        if entry is None:
            return False
        if self._dirty.pop(request_id, None):
            sanitizer.emit("journal", (self._san, request_id),
                           "rollback" if crashed else "flush")
        sanitizer.emit("journal", (self._san, request_id), "retire")
        return True

    def flush_journal(self, request_id: Optional[int] = None) -> int:
        """Copy generated-token logs into their journal entries
        (DIRTY -> OPEN). The event loop calls the batched form on its own
        cadence — journal durability off the per-token critical path; the
        per-request form is the flush barrier in front of quota settles
        and hand-off exports. Returns the number of entries flushed."""
        rids = [request_id] if request_id is not None else list(self._dirty)
        flushed = 0
        for rid in rids:
            if self._dirty.pop(rid, None) is None:
                continue
            entry = self.journal.get(rid)
            if entry is None:
                continue
            entry.tokens = list(entry.req.out_tokens)
            sanitizer.emit("journal", (self._san, rid), "flush")
            flushed += 1
        return flushed

    def _sync_journal(self, eng: BatchingEngine) -> None:
        """Post-step journal sync for one engine: eager mode copies every
        inflight token log now (lockstep PR 5 behavior); lazy mode only
        marks entries dirty for a later batched flush."""
        for r in eng.inflight():
            entry = self.journal.get(r.request_id)
            if entry is None:
                continue
            if self.journal_lazy:
                if r.request_id not in self._dirty:
                    self._dirty[r.request_id] = True
                    sanitizer.emit("journal",
                                   (self._san, r.request_id), "dirty")
            else:
                entry.tokens = list(r.out_tokens)

    def cancel(self, req: Request) -> bool:
        """Cancel one request on whichever engine holds it (queued or in
        flight; an in-flight cancel frees the slot and its pool pages).

        A request can also be caught BETWEEN engines: drained for a live
        hand-off (after its pages were exported, before ``resume``) or
        orphaned by a dead device awaiting recovery. No engine holds a
        slot or pages for it then — its pages were already freed by the
        drain / died with the device — so only the bookkeeping settles
        here, exactly once; the done-flag guard in ``resume`` keeps the
        in-flight hand-off from re-queuing it afterwards."""
        # recover first: cancelling on an engine whose device was marked
        # dead between steps would settle against a slice that died with
        # the device (and leak the in-flight quota on the KeyError)
        self._recover_dead_engines()
        for eng in self._engines.values():
            if eng.cancel(req):
                return True
        if req.request_id in self.journal and not req.done.is_set():
            _mark_cancelled(req)
            self._on_finish(req)
            return True
        return False

    def begin_round(self) -> None:
        """Control-plane half of a round boundary: tick the fault injector
        (scheduled kills + heartbeats; the clock too, unless the event
        queue owns it), run the heartbeat/failover sweep, and recover any
        engine stranded on a dead device."""
        if self.faults is not None:
            self.faults.tick(self.hv,
                             advance_clock=not self._event_driven)
            self.hv.handle_failures()
        self._recover_dead_engines()

    def step_engine(self, dev: str,
                    prefill_chunk: Optional[int] = None) -> int:
        """One guarded step of ONE engine — the unit the event loop
        schedules per-device (each engine advances on its own cadence).
        ``prefill_chunk`` selects the async engine path (chunked prefill
        interleaved with decode); None keeps the lockstep ``step()``.
        Skips engines that vanished (parked by a hand-off mid-round) or
        froze (crashed mid-detection-window). Returns slots decoded."""
        eng = self._engines.get(dev)
        if eng is None or not self._device_alive(dev):
            return 0
        t0 = time.monotonic()
        n = eng.step() if prefill_chunk is None \
            else eng.step_async(prefill_chunk)
        if n:
            self.last_round_ms[dev] = (time.monotonic() - t0) * 1e3
        self._sync_journal(eng)
        if eng.paged:
            self.hv.monitor.record_pages(dev, eng.pool.used_pages,
                                         eng.pool.total_pages)
            self.hv.monitor.record_scrub(dev, eng.pool.pages_scrubbed,
                                         eng.scrub_ms)
        return n

    def finish_round(self) -> None:
        """Round settlement: one traffic sample (fleet-wide and per-device
        completions) feeds the SLO-projection autoscaler, the energy
        integral charges every un-parked device its class draw, and the
        straggler / autoscale cadences run."""
        self.steps += 1
        self.hv.monitor.record_traffic(self._arrivals_since_step,
                                       self._completions_since_step,
                                       len(self._engines),
                                       by_device=self._dev_completions)
        self._arrivals_since_step = 0
        self._completions_since_step = 0
        self._dev_completions = {}
        self.energy += self.hv.db.active_draw()
        if self.migrate_every and self.steps % self.migrate_every == 0:
            self.rebalance()
        if self.autoscale_every and self.steps % self.autoscale_every == 0:
            self.autoscale()

    def step(self) -> int:
        """One LOCKSTEP round: a decode step on every active engine
        (devices run concurrently in hardware; ``last_round_ms`` records
        each device's wall time so callers can account device-parallel
        time), bracketed by ``begin_round``/``finish_round``. The
        event-driven loop (``runtime.events.EventLoop``) composes the same
        three pieces but schedules ``step_engine`` per device on its own
        event-time cadence — no fleet-wide barrier."""
        self.begin_round()
        total = 0
        self.last_round_ms = {}
        for dev in list(self._engines):
            total += self.step_engine(dev)
        self.finish_round()
        return total

    def run_until_idle(self, max_steps: int = 10000) -> bool:
        """Returns True when every engine drained; False on a stall
        (max_steps expired, or queued work that can make no progress).
        With a fault injector attached, a zero-progress round is NOT a
        stall: a killed-but-undetected node freezes its engine for the
        length of the heartbeat deadline, and recovery resumes the work
        a few steps later."""
        for _ in range(max_steps):
            n = self.step()
            if all(e.idle() for e in self._engines.values()):
                return True
            if n == 0 and self.faults is None:
                return False
        return all(e.idle() for e in self._engines.values())

    # ------------------------------------------------------------------
    # Telemetry -> control plane (same attribution as the single gateway,
    # but totals are per engine: each device's step is its own event)
    # ------------------------------------------------------------------
    def _on_step(self, device_id: str, active_by_tenant: Dict[str, int],
                 step_ms: float):
        total = sum(active_by_tenant.values()) or 1
        for tenant, n in active_by_tenant.items():
            sess = self._sessions.get(tenant)
            if sess is None:
                continue
            self.hv.record_serving_step(
                sess.slice_id, step_ms * n / (total * sess.slots))

    def _on_finish(self, req: Request):
        # retire the journal entry FIRST (through the flush barrier): a
        # settled request must never be replayed by a later recovery
        # (exactly-once accounting), and quota must never settle while
        # the entry is dirty
        self._retire_entry(req.request_id)
        if req.finish_reason != "cancelled":
            self._completions_since_step += 1
            dev = self._device_of.get(req.tenant)
            if dev is not None:
                self._dev_completions[dev] = \
                    self._dev_completions.get(dev, 0) + 1
        settle_finished_request(self.hv, self._sessions, req)

    # ------------------------------------------------------------------
    # Live migration hand-off
    # ------------------------------------------------------------------
    def _on_migration(self, old: str, new: str):
        """Hypervisor re-placed a slice: rebind the session AND move its
        traffic. Queued + in-flight requests are drained from the source
        engine and carried to the target. On a paged fleet an in-flight
        request's pool pages are COPIED device-to-device (exported before
        the drain frees them), so decode continues without recompute;
        prompt-prefix replay remains the fallback whenever the target
        cannot take the pages (slot/page exhaustion, dense engines)."""
        sess = next((s for s in self._sessions.values()
                     if s.slice_id == old), None)
        if sess is None:
            return
        sess.slice_id = new
        self.migrations.append((old, new))
        new_dev = self.hv.db.find_slice(new).device_id
        old_dev = self._device_of.get(sess.tenant)
        if new_dev == old_dev:
            return
        self._device_of[sess.tenant] = new_dev
        target = self._ensure_engine(new_dev)
        source = self._engines.get(old_dev)
        if (self._handoff_hook is not None and source is not None
                and source.paged and target.paged):
            # event-driven fleet: overlap the page copy with continued
            # decode on the source. New traffic routes to the target now
            # (shares set below); the hook exports snapshots, marks the
            # source draining, and schedules the drain + adoption a few
            # ticks out (export-generation check / replay fallback there).
            target.set_tenant_share(sess.tenant, sess.slots)
            target.set_tenant_weight(sess.tenant, sess.slots)
            if target.paged:
                vs = self.hv.db.find_slice(new)
                target.set_tenant_pages(sess.tenant, vs.cache_pages or None)
            self._handoff_hook(sess, old_dev, new_dev)
            return
        moved: List[Request] = []
        payloads: Dict[int, object] = {}
        if source is not None:
            # export pages BEFORE draining: released pages may be recycled
            # by the source's next admission
            if source.paged and target.paged:
                for r in source.inflight(sess.tenant):
                    # flush barrier: the journal must cover everything the
                    # snapshot covers before the entry leaves this engine
                    self.flush_journal(r.request_id)
                    if self.faults is not None \
                            and self.faults.fail_page_copy():
                        continue         # copy lost: replay fallback
                    p = source.export_request_pages(r)
                    if p is not None:
                        payloads[id(r)] = p
            moved = source.drain_tenant(sess.tenant)
            source.set_tenant_share(sess.tenant, None)
            source.set_tenant_weight(sess.tenant, None)
            source.set_tenant_pages(sess.tenant, None)
        target.set_tenant_share(sess.tenant, sess.slots)
        target.set_tenant_weight(sess.tenant, sess.slots)
        if target.paged:
            vs = self.hv.db.find_slice(new)
            target.set_tenant_pages(sess.tenant, vs.cache_pages or None)
        page_copied = replayed = 0
        for r in moved:
            if r.done.is_set():
                continue    # cancelled mid-hand-off: already settled
            payload = payloads.get(id(r))
            if payload is not None and target.import_request_pages(r, payload):
                page_copied += 1
            else:
                target.resume(r)
                if id(r) in payloads:
                    replayed += 1
        event = {"tenant": sess.tenant, "old": old, "new": new,
                 "old_device": old_dev, "new_device": new_dev,
                 "moved_requests": len(moved), "page_copied": page_copied,
                 "replayed_inflight": replayed}
        if self.autotune:
            # cross-class hand-off: geometry was re-resolved for the
            # DESTINATION class when its engine came up; record both ends
            event["dst_geometry"] = self._bundle_for(new_dev).geometry
            event["src_geometry"] = ("" if old_dev is None
                                     else self._bundle_for(old_dev).geometry)
        self.handoffs.append(event)
        self.hv._log("handoff", **event)

    def rebalance(self) -> List[Tuple[str, str]]:
        """Straggler sweep; hand-offs happen in the migration listener."""
        self.hv.migrate_stragglers()
        return self.hv.last_migrations

    # ------------------------------------------------------------------
    # Crash-consistent failover (no live source engine)
    # ------------------------------------------------------------------
    def _device_alive(self, device_id: str) -> bool:
        dev = self.hv.db.devices[device_id]
        if dev.state == DeviceState.DEAD \
                or not self.hv.db.nodes[dev.node_id].alive:
            return False
        # a killed-but-undetected device must freeze NOW, not when the
        # heartbeat deadline expires
        return self.faults is None \
            or not self.faults.is_dead(dev.node_id, device_id)

    def _recover_dead_engines(self) -> List[str]:
        """Failover sweep: any engine whose device the control plane has
        declared dead gets its sessions re-placed and its requests resumed
        from the journal. (Engines on killed-but-undetected nodes keep
        their state and simply skip stepping until the monitor notices.)"""
        recovered = []
        for dev in list(self._engines):
            d = self.hv.db.devices[dev]
            if d.state == DeviceState.DEAD \
                    or not self.hv.db.nodes[d.node_id].alive:
                self.recover_device(dev)
                recovered.append(dev)
        return recovered

    def recover_device(self, device_id: str) -> dict:
        """Re-place every session stranded on a dead device and resume its
        unfinished requests by prefix replay from the recovery journal.

        Contrast ``_on_migration``: a live hand-off drains a RUNNING
        source engine (and can copy pages). Here the source is gone —
        engine, queues, slots and KV pages died with the device — so the
        journal is the only truth: each orphaned request's generated-token
        log is restored onto the request and replayed as a prompt prefix
        on a surviving (or woken) engine. Page accounting needs no
        settling (the dead pool took its refcounts with it and the
        monitor's occupancy entry is cleared); admission quota stays held
        by each request until it finishes on its new engine — settled
        exactly once, by the normal ``_on_finish`` path.

        A tenant that fits NOWHERE (even degraded to 1 slot, even after
        waking every PARKED device) is evicted: its unfinished requests
        are cancelled and its quota settled, exactly once.
        """
        self._engines.pop(device_id, None)      # dataplane died with device
        self.hv.monitor.clear_pages(device_id)
        self.hv.monitor.clear_traffic(device_id)
        tenants = [t for t, d in self._device_of.items() if d == device_id]
        event = {"device": device_id, "tenants": tenants, "resumed": 0,
                 "evicted": []}
        for tenant in tenants:
            sess = self._sessions[tenant]
            # every unfinished request of this tenant was stranded by the
            # crash — queued or mid-decode, it is now an orphan awaiting
            # either replay (below) or eviction. Dirty entries roll back:
            # unflushed tokens died with the device, and replay from the
            # last durable flush regenerates them bit-exact (greedy)
            for entry in self.journal.values():
                if entry.tenant == tenant and not entry.req.done.is_set() \
                        and not self._held_elsewhere(entry.req):
                    rid = entry.req.request_id
                    if self._dirty.pop(rid, None):
                        sanitizer.emit("journal", (self._san, rid),
                                       "rollback")
                    _req_event(entry.req, "orphan")
            # the grant formula rides along so each degrade step asks for
            # the page grant matching ITS slot count, not the original's
            vs = self.elastic.place_failover(
                tenant, sess.slots, sess.service_model,
                cache_pages_of=self._session_page_grant)
            if vs is None:
                self._evict_session(tenant, sess)
                event["evicted"].append(tenant)
                continue
            if vs.slots < sess.slots:
                # elastic degrade: hand back the slot quota difference so
                # admission matches what the tenant actually holds now
                self.hv.admission.release_tenant(
                    tenant, sess.service_model, sess.slots - vs.slots)
                sess.slots = vs.slots
            sess.slice_id = vs.slice_id
            self._device_of[tenant] = vs.device_id
            target = self._ensure_engine(vs.device_id)
            # the surviving device may be a different class: mark the
            # slice with the program fingerprint its class actually runs
            # (stamped by _ensure_engine's compile just above)
            self.hv.db.set_slice_state(
                vs.slice_id, SliceState.CONFIGURED,
                program=self._bundle_for(vs.device_id).fingerprint
                or self.program_fingerprint)
            target.set_tenant_share(tenant, vs.slots)
            target.set_tenant_weight(tenant, vs.slots)
            if self.paged:
                target.set_tenant_pages(tenant, vs.cache_pages or None)
            # journal replay in submission order (dict preserves it): the
            # tenant's FIFO survives the crash
            for entry in list(self.journal.values()):
                if entry.tenant != tenant or entry.req.done.is_set() \
                        or self._held_elsewhere(entry.req):
                    # a surviving engine still owns it: the overlapped
                    # hand-off source keeps decoding while its copy is in
                    # flight — replaying here would double-decode
                    continue
                # crash consistency: roll the request back to its durably
                # journaled token log (tokens past it regenerate bit-exact
                # under greedy decoding — the chaos suite proves it)
                entry.req.out_tokens = list(entry.tokens)
                sanitizer.emit("journal",
                               (self._san, entry.req.request_id), "replay")
                target.resume(entry.req)
                event["resumed"] += 1
        self.recoveries.append(event)
        self.hv._log("device_recovered", **event)
        return event

    def _held_elsewhere(self, req: Request) -> bool:
        """Does any surviving engine physically own this request (slot or
        queue)? Recovery skips such requests — they are mid-overlapped-
        hand-off on a live source and the completion event will move
        them."""
        return any(eng.holds(req) for eng in self._engines.values())

    def _evict_session(self, tenant: str, sess: TenantSession):
        """Tear down a session whose vSlice died with its device and that
        no surviving capacity can host: cancel its unfinished requests and
        settle every outstanding quota exactly once. (There is no slice to
        release — ``mark_node_dead``/``mark_device_dead`` already dropped
        it — but the admission controller's slot + in-flight counts are
        fleet-side state and must not leak.)"""
        cancelled = 0
        for rid, entry in list(self.journal.items()):
            if entry.tenant != tenant or entry.req.done.is_set():
                continue
            self._retire_entry(rid, crashed=True)
            _mark_cancelled(entry.req)
            cancelled += 1
        self._settle_outstanding(sess)
        self.hv.admission.release_tenant(tenant, sess.service_model,
                                         sess.slots)
        self._sessions.pop(tenant, None)
        self._device_of.pop(tenant, None)
        self.hv._log("failover_evict", tenant=tenant, cancelled=cancelled)

    def verify_invariants(self) -> None:
        """Machine-checked fleet-wide conservation — the chaos harness
        calls this after every step:

          * every paged engine's pool passes ``PagePoolManager.verify()``
            (free + referenced == total, no refcount leaks);
          * per-tenant admission in-flight count equals that tenant's
            unfinished journaled requests (quota conservation: nothing
            settled twice, nothing leaked across kills/hand-offs);
          * sessions map onto live devices with live engines.
        """
        for dev, eng in self._engines.items():
            if eng.paged:
                eng.pool.verify()
        unfinished: Dict[str, int] = {}
        for entry in self.journal.values():
            if not entry.req.done.is_set():
                unfinished[entry.tenant] = unfinished.get(entry.tenant, 0) + 1
        for tenant, sess in self._sessions.items():
            inflight = self.hv.admission.usage(
                tenant, sess.service_model)["inflight"]
            assert inflight == unfinished.get(tenant, 0), \
                f"quota drift for {tenant!r}: admission holds {inflight} " \
                f"in flight, journal has {unfinished.get(tenant, 0)} " \
                "unfinished"
            dev = self._device_of[tenant]
            assert self.hv.db.devices[dev].state != DeviceState.DEAD, \
                f"session {tenant!r} bound to dead device {dev}"
            assert dev in self._engines, \
                f"session {tenant!r} on {dev} has no engine"

    # ------------------------------------------------------------------
    # Elastic scaling (queue depth <-> energy policy)
    # ------------------------------------------------------------------
    def queued_by_device(self) -> Dict[str, int]:
        return {dev: sum(e.queued_by_tenant().values())
                for dev, e in self._engines.items()}

    def autoscale(self) -> Optional[str]:
        """Single-action autoscale arbitration: evaluate every scaling
        signal, act on AT MOST ONE per invocation, in priority order —

          1. queue depth  (aggregate backlog outgrew the active fleet),
          2. SLO projection (projected p95 breach from the arrival-rate /
             service-rate trend; only when ``slo_p95_steps`` is set),
          3. page pressure (a device's KV pool runs hot; paged fleets),

        each waking one PARKED device and moving the deepest-queued (or
        page-hungriest) tenant onto it via a live hand-off. A burst wave
        routinely trips queue depth AND page pressure on the same tick;
        acting on both would wake two devices for one overload and
        oscillate against the energy policy, so later signals are only
        consulted when every earlier one declined to act. When NO
        scale-out fired, the backlog is empty and the projection sits
        under ``scale_in_margin`` of the SLO, the diurnal down-ramp half
        runs instead: drain the highest-draw drainable device
        (``pick_scale_in_device``) so the power-hungry classes park first.
        Always parks empty idle engines on the way out. Returns the woken
        device id, if any."""
        queued = self.queued_by_device()
        # requests on a draining device (a scale-in target mid-drain, or
        # an overlapped hand-off source mid-copy) are already on their way
        # elsewhere; counting them as backlog double-counts the demand and
        # wakes a device for traffic that is about to move — the wake/park
        # flap across a diurnal trough
        backlog = sum(n for dev, n in queued.items()
                      if dev not in self._draining)
        n_active = max(1, len(self._engines))
        woken: Optional[str] = None
        signal: Optional[str] = None
        if backlog >= self.scale_up_queue_depth * n_active:
            tenant = self._deepest_queued_tenant()
            if tenant is not None:
                new = self.elastic.scale_out(self._sessions[tenant].slice_id)
                if new is not None:
                    woken, signal = new.device_id, "queue_depth"
        if woken is None and self.slo_p95_steps is not None:
            tenant = self._deepest_queued_tenant()
            if tenant is not None:
                new = self.elastic.scale_out_on_slo(
                    self._sessions[tenant].slice_id, self.slo_p95_steps,
                    backlog, self.slo_horizon)
                if new is not None:
                    woken, signal = new.device_id, "slo_projection"
        if woken is None and self.paged:
            # memory pressure is a scale-out signal of its own: a device
            # can stall on pages with a near-empty queue (long contexts)
            new = self.elastic.scale_out_on_page_pressure(
                self._page_hungriest_slices(), self.page_pressure)
            if new is not None:
                woken, signal = new.device_id, "page_pressure"
        if woken is None and self.slo_p95_steps is not None and backlog == 0:
            self._maybe_scale_in()
        self.park_idle_engines()
        if woken is not None:
            self.autoscale_log.append({"step": self.steps, "action":
                                       "scale_out", "signal": signal,
                                       "device": woken})
        return woken

    def _maybe_scale_in(self) -> Optional[str]:
        """Down-ramp consolidation: when the fleet is comfortably under
        SLO (projection below ``scale_in_margin * slo_p95_steps``, or no
        trend at all — a dead-quiet trough has no completions to measure a
        service rate from), drain the highest-draw drainable device so it
        parks. At most one drain per autoscale tick; ``consolidate``
        dry-runs the re-packing first, so an infeasible drain is a no-op.
        """
        projected = self.elastic.projected_p95_steps(0, self.slo_horizon)
        if (projected is not None
                and projected > self.scale_in_margin * self.slo_p95_steps):
            return None
        dev = self.elastic.pick_scale_in_device(min_active=1)
        if dev is None:
            return None
        # mark the drain target BEFORE consolidating so autoscale's
        # backlog sample never counts its departing queue; overlapped
        # hand-offs keep it marked until their copy completes
        self._draining.add(dev)
        ok = self.elastic.consolidate(dev)
        if not ok or self._inflight_handoffs.get(dev, 0) == 0:
            self._draining.discard(dev)
        if not ok:
            return None
        self.autoscale_log.append({"step": self.steps, "action": "scale_in",
                                   "device": dev})
        return dev

    def _handoff_begun(self, device_id: str) -> None:
        """An overlapped hand-off started copying off ``device_id``."""
        self._draining.add(device_id)
        self._inflight_handoffs[device_id] = \
            self._inflight_handoffs.get(device_id, 0) + 1

    def _handoff_done(self, device_id: str) -> None:
        n = self._inflight_handoffs.get(device_id, 0) - 1
        if n <= 0:
            self._inflight_handoffs.pop(device_id, None)
            self._draining.discard(device_id)
        else:
            self._inflight_handoffs[device_id] = n

    def _page_hungriest_slices(self) -> Dict[str, str]:
        """device_id -> slice_id of the tenant holding the most pool pages
        there (the best candidate to move off a page-pressured device)."""
        out: Dict[str, str] = {}
        for dev, eng in self._engines.items():
            if not eng.paged:
                continue
            by_tenant = eng.pool.pages_by_tenant()
            for tenant in sorted(by_tenant, key=by_tenant.get,
                                 reverse=True):
                sess = self._sessions.get(tenant)
                if sess is not None:
                    out[dev] = sess.slice_id
                    break
        return out

    def _deepest_queued_tenant(self) -> Optional[str]:
        best, depth = None, 0
        for eng in self._engines.values():
            for tenant, n in eng.queued_by_tenant().items():
                if n > depth and tenant in self._sessions:
                    best, depth = tenant, n
        return best

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """OPERATOR view: every session's counters and quota. Anything a
        tenant can call must go through ``tenant_status`` instead."""
        return {t: {"slice": s.slice_id, "device": self._device_of.get(t),
                    "slots": s.slots, "submitted": s.submitted,
                    "served": s.served, "tokens_out": s.tokens_out,
                    "quota": self.hv.admission.usage(t)}
                for t, s in self._sessions.items()}

    def tenant_status(self, tenant: str) -> dict:
        """Tenant-facing status: ONLY ``tenant``'s own session, quota and
        page holdings, on whatever device currently hosts it. No
        co-tenant names, pool occupancy, or fleet telemetry — the
        cross-tenant observability ``stats()``/``fleet_stats()`` expose
        is operator-only (see ARCHITECTURE.md, threat model)."""
        out = dict(self.hv.monitor.tenant_status(tenant))
        sess = self._sessions.get(tenant)
        if sess is not None:
            out["session"] = {"slice": sess.slice_id, "slots": sess.slots,
                              "submitted": sess.submitted,
                              "served": sess.served,
                              "tokens_out": sess.tokens_out}
            eng = self._engines.get(self._device_of.get(tenant))
            if eng is not None and eng.paged:
                out["pages_held"] = eng.pool.tenant_pages(tenant)
        out["quota"] = self.hv.admission.usage(tenant)
        return out

    def fleet_stats(self) -> dict:
        return {dev: {"active": sum(e.active_by_tenant().values()),
                      "queued": sum(e.queued_by_tenant().values()),
                      "steps": e.steps,
                      **({"pages": e.page_stats()} if e.paged else {})}
                for dev, e in self._engines.items()}
