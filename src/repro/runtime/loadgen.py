"""Open-loop trace-replay load generation + the standing soak matrix.

The paper evaluates RC3E with a handful of hand-driven allocations; a
cloud provider's real question is what the hypervisor + serving fleet do
under *traffic* — burst waves arriving on a diurnal cycle, heavy-tailed
request sizes, a few hot tenants dominating the load — sustained across
device failures. This module synthesizes that traffic and replays it:

  * ``TraceSpec`` — a fully serializable description of a workload:
    Poisson arrivals with burst-wave and diurnal modulation, lognormal
    prompt/output lengths, Zipf tenant skew. Same spec + seed ⇒
    bit-identical trace (property-tested in ``tests/test_loadgen.py``).
  * ``synthesize`` — spec → an explicit arrival list. The trace is
    OPEN-LOOP: arrivals land on schedule whether or not the fleet keeps
    up, so overload shows up as backlog/latency/rejections instead of the
    closed-loop trap of the generator politely slowing down.
  * ``replay_trace`` — drive one ``GatewayFleet`` through a trace on the
    injected ``FakeClock``, measuring goodput, per-tenant p50/p95/p99
    latency (in fleet rounds — deterministic), preemption/eviction
    counts, load-shed rejections and the energy integral (device-steps ×
    class draw). The record it returns contains NO wall-clock values, so
    two replays of the same cell are bit-identical (tested under
    ``RC3E_SANITIZE=1``).
  * ``SoakMatrix`` — the standing grid: chaos seeds × trace specs ×
    fleet sizes, each cell replayed with a seeded mixed-fault schedule
    (``FaultInjector.plan_soak``) and invariant-checked at the end.

All randomness flows through ``seeded_rng`` (the determinism pass
enforces this) and latency is measured in fleet rounds, never wall time,
so ``BENCH_scale.json`` is stable across hosts and suitable for CI
regression checks.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import zlib
from typing import Dict, List, Optional, Tuple

from repro.core import ClusterSpec, Hypervisor
from repro.core.monitor import MonitorConfig
from repro.rc2f import AdmissionError
from repro.runtime.events import EventLoop
from repro.runtime.faults import FaultInjector, seeded_rng
from repro.runtime.fleet import GatewayFleet


def _mix(seed: int, tag: str) -> int:
    """Derive a sub-seed from (seed, tag) without Python's salted
    ``hash``: crc32 is stable across processes and platforms."""
    return (int(seed) * 0x9E3779B1 + zlib.crc32(tag.encode())) % (2 ** 31)


def _poisson(rng, lam: float) -> int:
    """Poisson draw via Knuth's product method, chunked so exp(-lam)
    never underflows for large rates (sums of independent Poissons are
    Poisson)."""
    n = 0
    while lam > 10.0:
        n += _poisson_knuth(rng, 10.0)
        lam -= 10.0
    return n + _poisson_knuth(rng, lam)


def _poisson_knuth(rng, lam: float) -> int:
    if lam <= 0.0:
        return 0
    limit = math.exp(-lam)
    k, p = 0, 1.0
    while True:
        p *= rng.random()
        if p <= limit:
            return k
        k += 1


def _lognormal_len(rng, mu: float, sigma: float, lo: int, hi: int) -> int:
    return max(lo, min(hi, int(rng.lognormvariate(mu, sigma))))


def percentile(xs, q: float) -> Optional[float]:
    """Nearest-rank percentile (deterministic, no interpolation)."""
    if not xs:
        return None
    s = sorted(xs)
    idx = min(len(s) - 1, max(0, math.ceil(q / 100.0 * len(s)) - 1))
    return float(s[idx])


# ---------------------------------------------------------------------------
# Trace synthesis
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Serializable description of one open-loop workload.

    Arrival process per round ``t``:
      rate(t) = base_rate
                × (1 + diurnal_amp · sin(2π t / diurnal_period))
                × (burst_rate_mult if the burst wave is ON else 1)
    with the burst wave a two-state Markov chain (geometric sojourns of
    mean ``burst_on_mean`` / ``burst_off_mean`` rounds) and the count
    drawn Poisson(rate). Each arrival gets a tenant from a Zipf(zipf_s)
    over ``tenants`` hot-first, a prompt length and an output budget from
    clamped lognormals.
    """
    name: str
    horizon: int = 64                 # rounds of arrivals
    base_rate: float = 0.5            # mean arrivals/round at baseline
    burst_rate_mult: float = 1.0      # rate multiplier while bursting
    burst_on_mean: float = 4.0        # mean burst length (rounds)
    burst_off_mean: float = 12.0      # mean gap between bursts
    diurnal_period: int = 0           # 0 disables the diurnal sinusoid
    diurnal_amp: float = 0.0          # fraction of base_rate (|amp| <= 1)
    tenants: int = 4
    zipf_s: float = 1.1               # tenant-popularity skew exponent
    prompt_len_mu: float = 1.2        # lognormal params (of the length)
    prompt_len_sigma: float = 0.5
    prompt_len_max: int = 12
    out_tokens_mu: float = 1.6
    out_tokens_sigma: float = 0.4
    out_tokens_max: int = 12

    def tenant_ids(self) -> List[str]:
        return [f"t{i}" for i in range(self.tenants)]

    def zipf_weights(self) -> List[float]:
        w = [1.0 / (i + 1) ** self.zipf_s for i in range(self.tenants)]
        total = sum(w)
        return [x / total for x in w]


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled request: submit at round ``step``."""
    step: int
    tenant: str
    prompt_len: int
    max_new_tokens: int


def synthesize(spec: TraceSpec, seed: int) -> List[Arrival]:
    """Spec + seed → the explicit arrival list, sorted by step (arrivals
    within a round keep draw order). Pure function of its arguments:
    identical inputs produce a bit-identical list."""
    rng = seeded_rng(_mix(seed, "trace/" + spec.name))
    weights = spec.zipf_weights()
    cum = []
    acc = 0.0
    for w in weights:
        acc += w
        cum.append(acc)
    cum[-1] = 1.0                      # guard float drift at the top end
    tenants = spec.tenant_ids()
    bursting = False
    out: List[Arrival] = []
    for t in range(spec.horizon):
        # two-state burst wave with geometric sojourn times
        if spec.burst_rate_mult > 1.0:
            flip = (1.0 / spec.burst_on_mean if bursting
                    else 1.0 / spec.burst_off_mean)
            if rng.random() < flip:
                bursting = not bursting
        rate = spec.base_rate
        if spec.diurnal_period:
            rate *= 1.0 + spec.diurnal_amp * math.sin(
                2.0 * math.pi * t / spec.diurnal_period)
        if bursting:
            rate *= spec.burst_rate_mult
        for _ in range(_poisson(rng, max(0.0, rate))):
            tenant = tenants[bisect.bisect_left(cum, rng.random())]
            out.append(Arrival(
                step=t, tenant=tenant,
                prompt_len=_lognormal_len(rng, spec.prompt_len_mu,
                                          spec.prompt_len_sigma, 1,
                                          spec.prompt_len_max),
                max_new_tokens=_lognormal_len(rng, spec.out_tokens_mu,
                                              spec.out_tokens_sigma, 1,
                                              spec.out_tokens_max)))
    return out


def tenant_shares(arrivals: List[Arrival]) -> Dict[str, float]:
    """Observed per-tenant arrival fractions (property tests compare them
    against ``TraceSpec.zipf_weights``)."""
    counts: Dict[str, int] = {}
    for a in arrivals:
        counts[a.tenant] = counts.get(a.tenant, 0) + 1
    total = max(1, len(arrivals))
    return {t: n / total for t, n in counts.items()}


# ---------------------------------------------------------------------------
# Fleet description + replay
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Serializable fleet-under-test description for one soak cell."""
    name: str
    n_nodes: int = 2
    devices_per_node: int = 1
    n_slots: int = 4
    max_len: int = 64
    paged: bool = True
    page_size: int = 4
    cache_pages: Optional[int] = None
    autoscale_every: int = 4
    scale_up_queue_depth: int = 8
    slo_p95_steps: Optional[float] = 24.0
    slo_horizon: int = 16
    migrate_every: int = 0
    device_draws: Tuple[float, ...] = ()   # heterogeneous class draws
    device_speeds: Tuple[float, ...] = ()  # event-loop cadence multipliers

    def n_devices(self) -> int:
        return self.n_nodes * self.devices_per_node


def build_fleet(fleet_spec: FleetSpec, model, params, seed: int,
                reconfig=None) -> Tuple[GatewayFleet, FaultInjector]:
    """One hypervisor + fleet on a fresh FakeClock-driven injector. The
    injector's schedule is empty; ``replay_trace`` adds the soak plan."""
    inj = FaultInjector(seed=_mix(seed, "faults/" + fleet_spec.name))
    hv = Hypervisor(ClusterSpec(n_nodes=fleet_spec.n_nodes,
                                devices_per_node=fleet_spec.devices_per_node,
                                device_draws=fleet_spec.device_draws,
                                device_speeds=fleet_spec.device_speeds),
                    MonitorConfig(heartbeat_interval_s=1.0,
                                  heartbeat_deadline_s=2.5),
                    clock=inj.clock)
    if reconfig is not None:
        hv.reconfig = reconfig         # shared program cache across cells
    fleet = GatewayFleet(
        hv, model, params, n_slots=fleet_spec.n_slots,
        max_len=fleet_spec.max_len, paged=fleet_spec.paged,
        page_size=fleet_spec.page_size, cache_pages=fleet_spec.cache_pages,
        autoscale_every=fleet_spec.autoscale_every,
        scale_up_queue_depth=fleet_spec.scale_up_queue_depth,
        slo_p95_steps=fleet_spec.slo_p95_steps,
        slo_horizon=fleet_spec.slo_horizon,
        migrate_every=fleet_spec.migrate_every, faults=inj)
    return fleet, inj


def replay_trace(trace: TraceSpec, fleet_spec: FleetSpec, seed: int,
                 model, params, reconfig=None, chaos: bool = False,
                 chaos_kills: int = 1, chaos_partitions: int = 1,
                 drain_slack: int = 256, loop: str = "lockstep",
                 prefill_chunk: int = 4) -> dict:
    """Replay one soak cell: build the fleet, open one baas session per
    tenant, feed the trace open-loop round by round, then drain. Returns
    the cell's ``BENCH_scale.json`` record — metrics only, no wall-clock
    values, so the record is a pure function of ``(trace, fleet, seed)``.

    ``loop`` selects the dataplane: ``"lockstep"`` drives the fleet with
    the round-barrier ``GatewayFleet.step``; ``"event"`` schedules each
    arrival as a queue event at its round's tick time and drives an
    ``EventLoop`` one control-tick window per round, so engines advance
    on their own ``device.speed`` cadence and prefill is chunked
    (``prefill_chunk`` tokens per engine event).

    Over-admission is part of the experiment: a submit the admission
    controller (tenant quota) or engine (paged worst-case) refuses counts
    as load shed, not an error. ``drain_slack`` bounds the post-horizon
    drain so a lost request can never hang the harness; whatever is still
    unfinished at the bound is reported as ``incomplete``.
    """
    if loop not in ("lockstep", "event"):
        raise ValueError(f"unknown loop {loop!r}")
    if trace.prompt_len_max + trace.out_tokens_max > fleet_spec.max_len:
        raise ValueError(
            f"trace {trace.name!r} worst case "
            f"{trace.prompt_len_max}+{trace.out_tokens_max} exceeds fleet "
            f"max_len {fleet_spec.max_len}")
    fleet, inj = build_fleet(fleet_spec, model, params, seed,
                             reconfig=reconfig)
    if chaos:
        lo = max(1, trace.horizon // 3)
        hi = max(lo + 1, (2 * trace.horizon) // 3)
        inj.plan_soak(sorted(fleet.hv.db.devices),
                      sorted(fleet.hv.db.nodes), lo, hi,
                      kills=chaos_kills, partitions=chaos_partitions)
    for t in trace.tenant_ids():
        fleet.open_session(t, slots=1, service_model="baas")

    arrivals = synthesize(trace, seed)
    by_step: Dict[int, List[Arrival]] = {}
    for a in arrivals:
        by_step.setdefault(a.step, []).append(a)
    vocab = model.cfg.vocab_size
    prompt_rng = seeded_rng(_mix(seed, "prompts/" + trace.name))

    outstanding: List[Tuple[object, str, int]] = []   # (req, tenant, t0)
    lat_all: List[int] = []
    lat_by_tenant: Dict[str, List[int]] = {}
    done_by_tenant: Dict[str, int] = {}
    rejected = completed = cancelled = tokens_out = 0
    engines_seen: Dict[int, object] = {}
    peak_devices = 0
    rounds = 0

    def _submit(a: Arrival, t0: int) -> None:
        nonlocal rejected
        prompt = [prompt_rng.randrange(vocab)
                  for _ in range(a.prompt_len)]
        try:
            req = fleet.submit(a.tenant, prompt, a.max_new_tokens)
        except (AdmissionError, ValueError, KeyError):
            # quota breach, paged worst-case refusal, or a session the
            # failover path EVICTED (reported via ``evictions``) —
            # open-loop arrivals for it are shed, not an error
            rejected += 1
            return
        outstanding.append((req, a.tenant, t0))

    evloop = None
    if loop == "event":
        evloop = EventLoop(fleet, prefill_chunk=prefill_chunk)
        # arrivals become queue events: scheduled up-front they carry the
        # lowest seqs at their instant, so a round's arrivals fire before
        # that round's control tick — same submit-then-step order as the
        # lockstep replay
        for a in arrivals:
            evloop.queue.at(a.step * evloop.tick_s,
                            lambda a=a: _submit(a, a.step),
                            kind="arrival")
    while rounds < trace.horizon or (outstanding
                                     and rounds < trace.horizon
                                     + drain_slack):
        if evloop is None:
            for a in by_step.get(rounds, ()):
                _submit(a, rounds)
            fleet.step()
        else:
            evloop.run_ticks(1)
        rounds += 1
        peak_devices = max(peak_devices, len(fleet._engines))
        for dev, eng in fleet._engines.items():
            engines_seen[id(eng)] = (dev, eng)
        still = []
        for req, tenant, t0 in outstanding:
            if not req.done.is_set():
                still.append((req, tenant, t0))
            elif req.finish_reason == "cancelled":
                cancelled += 1
            else:
                completed += 1
                tokens_out += len(req.out_tokens)
                done_by_tenant[tenant] = done_by_tenant.get(tenant, 0) + 1
                lat_all.append(rounds - t0)
                lat_by_tenant.setdefault(tenant, []).append(rounds - t0)
        outstanding = still

    if evloop is not None:
        fleet.flush_journal()          # settle lazy dirt before checking
    fleet.verify_invariants()          # pool.verify + quota == journal
    preemptions = sum(e.preemptions for _, e in engines_seen.values())
    steps_by_device: Dict[str, int] = {}
    for dev, eng in engines_seen.values():
        steps_by_device[dev] = steps_by_device.get(dev, 0) + eng.steps
    evictions = len([e for e in fleet.hv.log
                     if e.get("kind") == "failover_evict"])
    by_signal: Dict[str, int] = {}
    scale_ins = 0
    for ev in fleet.autoscale_log:
        if ev["action"] == "scale_in":
            scale_ins += 1
        else:
            by_signal[ev["signal"]] = by_signal.get(ev["signal"], 0) + 1
    slo = fleet_spec.slo_p95_steps
    metrics = {
        "arrivals": len(arrivals),
        "rejected": rejected,
        "completed": completed,
        "cancelled": cancelled,
        "incomplete": len(outstanding),
        "tokens_out": tokens_out,
        "rounds": rounds,
        "goodput_tokens_per_round": round(tokens_out / max(1, rounds), 6),
        "latency_rounds": {
            "p50": percentile(lat_all, 50), "p95": percentile(lat_all, 95),
            "p99": percentile(lat_all, 99),
            "mean": (round(sum(lat_all) / len(lat_all), 6)
                     if lat_all else None),
            "max": max(lat_all) if lat_all else None,
        },
        "per_tenant": {
            t: {"completed": done_by_tenant.get(t, 0),
                "p50": percentile(lat_by_tenant.get(t, []), 50),
                "p95": percentile(lat_by_tenant.get(t, []), 95),
                "p99": percentile(lat_by_tenant.get(t, []), 99)}
            for t in trace.tenant_ids()},
        "slo_violations": (len([x for x in lat_all if x > slo])
                           if slo is not None else None),
        "preemptions": preemptions,
        "evictions": evictions,
        "energy_device_steps": round(fleet.energy, 6),
        "peak_active_devices": peak_devices,
        "per_device_steps": {d: steps_by_device[d]
                             for d in sorted(steps_by_device)},
        "autoscale": {"scale_out_by_signal": by_signal,
                      "scale_in": scale_ins},
    }
    cell = {"trace": trace.name, "fleet": fleet_spec.name,
            "seed": int(seed), "chaos": bool(chaos)}
    if loop != "lockstep":
        # lockstep cells keep their committed-baseline shape; event cells
        # are tagged so records from the two loops never alias
        cell["loop"] = loop
    record = {
        "cell": cell,
        "trace_spec": dataclasses.asdict(trace),
        "fleet_spec": dataclasses.asdict(fleet_spec),
        "faults": [{"step": e["step"], "kind": e["kind"],
                    "target": e.get("target")} for e in inj.log],
        "metrics": metrics,
    }
    fleet.close()
    return record


# ---------------------------------------------------------------------------
# The standing soak matrix
# ---------------------------------------------------------------------------
class SoakMatrix:
    """Chaos seeds × trace specs × fleet sizes, one ``replay_trace`` per
    cell. Each cell gets its own seeded mixed-fault schedule; every cell
    is invariant-checked (``GatewayFleet.verify_invariants``, which
    includes ``PagePoolManager.verify``) before its record is returned.
    """

    def __init__(self, traces: List[TraceSpec], fleets: List[FleetSpec],
                 seeds: List[int], chaos: bool = True,
                 loop: str = "lockstep", prefill_chunk: int = 4):
        self.traces = list(traces)
        self.fleets = list(fleets)
        self.seeds = list(seeds)
        self.chaos = chaos
        self.loop = loop
        self.prefill_chunk = prefill_chunk

    def cells(self) -> List[Tuple[TraceSpec, FleetSpec, int]]:
        return [(t, f, s) for t in self.traces for f in self.fleets
                for s in self.seeds]

    def run(self, model, params, reconfig=None,
            progress=None) -> List[dict]:
        records = []
        for trace, fspec, seed in self.cells():
            rec = replay_trace(trace, fspec, seed, model, params,
                               reconfig=reconfig, chaos=self.chaos,
                               loop=self.loop,
                               prefill_chunk=self.prefill_chunk)
            records.append(rec)
            if progress is not None:
                progress(rec)
        return records


# ---------------------------------------------------------------------------
# Pinned presets (shared by benchmarks/scale_soak.py, tests and CI — the
# committed BENCH_scale.json baseline is generated from these)
# ---------------------------------------------------------------------------
def preset_traces() -> List[TraceSpec]:
    return [
        TraceSpec(name="steady", horizon=48, base_rate=0.6,
                  burst_rate_mult=1.0, diurnal_period=0, diurnal_amp=0.0,
                  tenants=4, zipf_s=1.1),
        TraceSpec(name="burst-diurnal", horizon=64, base_rate=0.5,
                  burst_rate_mult=4.0, burst_on_mean=6.0,
                  burst_off_mean=12.0, diurnal_period=32, diurnal_amp=0.8,
                  tenants=6, zipf_s=1.2),
    ]


def preset_fleets() -> List[FleetSpec]:
    return [
        FleetSpec(name="fleet2", n_nodes=2, devices_per_node=1,
                  slo_p95_steps=24.0, device_draws=(1.0, 2.0)),
        FleetSpec(name="fleet4", n_nodes=4, devices_per_node=1,
                  slo_p95_steps=24.0,
                  device_draws=(1.0, 2.0, 1.5, 1.0)),
    ]


def smoke_cell() -> Tuple[TraceSpec, FleetSpec, int]:
    """The pinned small cell CI replays (scale-smoke job): the steady
    trace on the 2-device fleet, seed 0, no chaos."""
    return preset_traces()[0], preset_fleets()[0], 0
