"""Adversarial-tenant scenario harness: seeded hostile behaviors against a
shared serving device, with isolation invariants machine-checked after
EVERY step.

Threat model (see ARCHITECTURE.md, "Tenant isolation & threat model"): a
hostile co-tenant on a shared paged engine may try to

  * **flood** the admission queue with long prompts (prefill monopoly),
  * **squat** on the page pool with long-lived max-length decodes
    (memory exhaustion),
  * **churn** cancel/resubmit cycles (quota-settle and scrub-queue abuse),
  * **probe** the prefix cache with a co-tenant's prompts (residual-state
    and timing side channel).

``run_scenario`` replays a fixed, seeded victim workload next to one such
behavior on a single shared device (one ``ServingGateway`` — co-residency
by construction) and reports per-tenant latency/goodput so tests can
assert the victim's p95 stays within a configured fairness bound of a
solo (attacker-free) baseline run of the *bit-identical* victim workload.

Everything is deterministic: prompts come from ``seeded_rng`` sub-seeds,
time is an injected ``FakeClock`` (one tick per round — the admission
rate limiter refills on it, never on wall-clock), and two runs with the
same (model, seed, behavior) are identical.

After every step the harness checks, on the live engine:

  * ``PagePoolManager.verify`` — conservation, refcounts, prefix-cache and
    pending-scrub consistency;
  * **cross-tenant page disjointness** — no physical page is referenced by
    two tenants' slots (the salted prefix chain makes cross-tenant COW
    sharing impossible; this is the device-level restatement);

and at teardown ``assert_free_pages_zeroed`` reads the *device* pool
through the real caches: every free-list page must hold zeros (pos -1,
scales 1) — the zero-on-free contract, end to end.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import ClusterSpec, Hypervisor, MonitorConfig
from repro.rc2f.admission import AdmissionError
from repro.runtime.faults import FakeClock, seeded_rng
from repro.runtime.gateway import ServingGateway

VICTIM = "victim"
HOSTILE = "mallory"


def _mix(seed: int, tag: str) -> int:
    """Stable sub-seed derivation (crc32, not Python's salted hash)."""
    return (int(seed) * 0x9E3779B1 + zlib.crc32(tag.encode())) % (2 ** 31)


# ---------------------------------------------------------------------------
# Hostile behaviors (all seeded; each acts once per round through the
# scenario's submit/cancel facade, which counts refusals as shed load)
# ---------------------------------------------------------------------------

class PromptFlood:
    """Long-prompt admission flood: every round submits ``burst`` prompts
    sized near the engine's max. The DRR admission debit is proportional
    to prefill length, so each flood admission costs Mallory several
    rounds of credit — the attack self-penalizes."""
    name = "prompt_flood"

    def __init__(self, burst: int = 4):
        self.burst = burst

    def act(self, rng, ctl) -> None:
        for _ in range(self.burst):
            n = ctl.max_len - 8 - rng.randrange(4)
            ctl.submit(HOSTILE, ctl.prompt(rng, n), new_tokens=2)


class PageSquat:
    """Page-pool squatting: keep ``keep`` long-decode requests outstanding
    so Mallory's pages stay resident as long as possible. The per-tenant
    page cap (vSlice grant) bounds what the squat can ever hold; the
    victim's grant is untouchable."""
    name = "page_squat"

    def __init__(self, keep: int = 6):
        self.keep = keep

    def act(self, rng, ctl) -> None:
        while ctl.outstanding(HOSTILE) < self.keep:
            if not ctl.submit(HOSTILE, ctl.prompt(rng, 16),
                              new_tokens=ctl.max_len - 24):
                break                     # quota/rate refusals: stop early


class CancelChurn:
    """Cancel/resubmit churn: every round cancels everything Mallory has
    outstanding and submits a fresh burst. Exercises quota settle-once,
    scrub-queue turnover, and (with a rate limit set) the token bucket."""
    name = "cancel_churn"

    def __init__(self, burst: int = 3):
        self.burst = burst

    def act(self, rng, ctl) -> None:
        ctl.cancel_all(HOSTILE)
        for _ in range(self.burst):
            ctl.submit(HOSTILE, ctl.prompt(rng, 12), new_tokens=12)


class PrefixProbe:
    """Prefix-cache probing: replay the victim's own prompts verbatim (an
    attacker who guesses or learns them). With the per-tenant salted hash
    chain the probe must never match the prefix cache or share a page —
    the per-step disjointness check is the teeth of this scenario."""
    name = "prefix_probe"

    def act(self, rng, ctl) -> None:
        if ctl.victim_prompts:
            probe = ctl.victim_prompts[rng.randrange(
                len(ctl.victim_prompts))]
            ctl.submit(HOSTILE, list(probe), new_tokens=2)


BEHAVIORS = (PromptFlood, PageSquat, CancelChurn, PrefixProbe)


# ---------------------------------------------------------------------------
# Per-step isolation checks
# ---------------------------------------------------------------------------

def check_isolation(engine) -> None:
    """Pool conservation + cross-tenant page disjointness on a live paged
    engine. Called after every scenario step."""
    pool = engine.pool
    pool.verify()
    held: Dict[str, set] = {}
    for slot, req in enumerate(engine._slots):
        if req is None:
            continue
        held.setdefault(req.tenant, set()).update(pool.slot_blocks(slot))
    tenants = sorted(held)
    for i, a in enumerate(tenants):
        for b in tenants[i + 1:]:
            shared = held[a] & held[b]
            assert not shared, \
                f"tenants {a!r} and {b!r} share physical pages " \
                f"{sorted(shared)} — cross-tenant KV exposure"


def assert_free_pages_zeroed(engine) -> int:
    """Zero-on-free, checked at the DEVICE: flush the pending scrub queue,
    then read every free-list page through the real caches — K/V must be
    all zeros, pos all -1, quant scales all 1. Returns the number of pages
    checked (callers assert it is nonzero so the check cannot pass
    vacuously)."""
    engine._flush_scrub()
    assert engine.pool.scrub_pending == 0
    free = sorted(engine.pool._free)
    if not free:
        return 0
    sel = np.asarray(free, np.int32)

    def chk(path, leaf):
        key = getattr(path[-1], "key", None)
        got = np.asarray(leaf[:, sel])   # rc3e: allow-host-sync — test oracle
        if key == "pos":
            expect, what = -1, "pos != -1"
        elif key in ("k_scale", "v_scale"):
            expect, what = 1, "quant scale != 1"
        else:
            expect, what = 0, "nonzero K/V residue"
        ok = (got.reshape(got.shape[0], got.shape[1], -1) == expect) \
            .all(axis=(0, 2))
        bad = [free[i] for i in np.flatnonzero(~ok)]
        assert not bad, \
            f"free pages {bad} leak freed-tenant state ({what})"
        return leaf

    jax.tree_util.tree_map_with_path(chk, engine.caches)
    return len(free)


# ---------------------------------------------------------------------------
# Scenario runner
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ScenarioReport:
    """Deterministic outcome of one scenario run (no wall-clock values:
    latencies are in engine steps, time is the FakeClock)."""
    behavior: str
    rounds: int
    steps: int
    latency: Dict[str, List[int]]        # completed requests, in steps
    submitted: Dict[str, int]
    completed: Dict[str, int]
    cancelled: Dict[str, int]
    shed: Dict[str, int]                 # admission/rate/validate refusals
    rate_limited: int                    # token-bucket refusals (subset)
    pages_scrubbed: int
    free_pages_checked: int

    def p95(self, tenant: str) -> float:
        lat = sorted(self.latency.get(tenant, []))
        assert lat, f"no completed requests for {tenant!r}"
        return float(lat[int(round(0.95 * (len(lat) - 1)))])

    def max_latency(self, tenant: str) -> int:
        return max(self.latency.get(tenant, [0]))

    def goodput(self, tenant: str) -> float:
        """Completions per round over the submission horizon."""
        return self.completed.get(tenant, 0) / max(1, self.rounds)


class _ScenarioControl:
    """The facade behaviors act through: submits count refusals as shed
    (never an exception — over-admission is part of the experiment)."""

    def __init__(self, gw: ServingGateway, vocab: int):
        self.gw = gw
        self.vocab = vocab
        self.max_len = gw.engine.max_len
        self.victim_prompts: List[List[int]] = []
        self.outstanding_reqs: List[Tuple[object, str, int]] = []
        self.submitted: Dict[str, int] = {}
        self.shed: Dict[str, int] = {}
        self._step = 0

    def prompt(self, rng, n: int) -> List[int]:
        return [rng.randrange(self.vocab) for _ in range(max(1, n))]

    def submit(self, tenant: str, prompt: List[int],
               new_tokens: int) -> bool:
        try:
            req = self.gw.submit(tenant, prompt, max_new_tokens=new_tokens)
        except (AdmissionError, ValueError):
            self.shed[tenant] = self.shed.get(tenant, 0) + 1
            return False
        self.submitted[tenant] = self.submitted.get(tenant, 0) + 1
        self.outstanding_reqs.append((req, tenant, self._step))
        return True

    def outstanding(self, tenant: str) -> int:
        return sum(1 for _, t, _ in self.outstanding_reqs if t == tenant)

    def cancel_all(self, tenant: str) -> int:
        n = 0
        for req, t, _ in list(self.outstanding_reqs):
            if t == tenant and self.gw.cancel(req):
                n += 1
        return n


def run_scenario(model, params, behavior=None, seed: int = 0,
                 rounds: int = 48, victim_every: int = 4,
                 victim_prompt_len: int = 6, victim_new_tokens: int = 6,
                 n_slots: int = 4, max_len: int = 64, page_size: int = 8,
                 cache_pages: Optional[int] = None, quota=None,
                 drain_slack: int = 400) -> ScenarioReport:
    """Run one seeded hostile behavior (or, with ``behavior=None``, the
    solo baseline) against the fixed victim workload on one shared paged
    device. The victim's submissions are a pure function of ``seed`` —
    identical across the baseline and every attacked run — so latency
    deltas are attributable to the attacker alone."""
    clock = FakeClock()
    hv = Hypervisor(ClusterSpec(n_nodes=1, devices_per_node=1),
                    MonitorConfig(heartbeat_interval_s=1.0,
                                  heartbeat_deadline_s=2.5),
                    clock=clock)
    if quota is not None:
        hv.admission.quotas["baas"] = quota
    gw = ServingGateway(hv, model, params, n_slots=n_slots, max_len=max_len,
                        paged=True, page_size=page_size,
                        cache_pages=cache_pages)
    gw.open_session(VICTIM, slots=2, service_model="baas")
    if behavior is not None:
        gw.open_session(HOSTILE, slots=2, service_model="baas")

    vocab = model.cfg.vocab_size
    victim_rng = seeded_rng(_mix(seed, "adversary/victim"))
    hostile_rng = seeded_rng(_mix(seed, "adversary/hostile"))
    ctl = _ScenarioControl(gw, vocab)

    latency: Dict[str, List[int]] = {}
    completed: Dict[str, int] = {}
    cancelled: Dict[str, int] = {}
    steps = 0

    def _poll() -> None:
        for item in list(ctl.outstanding_reqs):
            req, tenant, t0 = item
            if not req.done.is_set():
                continue
            ctl.outstanding_reqs.remove(item)
            if req.finish_reason == "cancelled":
                cancelled[tenant] = cancelled.get(tenant, 0) + 1
            else:
                completed[tenant] = completed.get(tenant, 0) + 1
                latency.setdefault(tenant, []).append(steps - t0)

    def _tick() -> int:
        nonlocal steps
        n = gw.step()
        steps += 1
        ctl._step = steps
        clock.advance(1.0)
        check_isolation(gw.engine)
        _poll()
        return n

    for r in range(rounds):
        if behavior is not None:
            behavior.act(hostile_rng, ctl)
        if r % victim_every == 0:
            p = ctl.prompt(victim_rng, victim_prompt_len)
            ctl.victim_prompts.append(p)
            ctl.submit(VICTIM, p, new_tokens=victim_new_tokens)
        _tick()

    # drain: no new submissions; a stalled drain (step made no progress
    # with work outstanding) is a scheduler bug, fail loudly
    for _ in range(drain_slack):
        if not ctl.outstanding_reqs:
            break
        n = _tick()
        assert n > 0 or not ctl.outstanding_reqs, \
            "drain stalled with requests outstanding (starvation)"
    assert not ctl.outstanding_reqs, \
        f"{len(ctl.outstanding_reqs)} requests never finished"

    free_checked = assert_free_pages_zeroed(gw.engine)
    usage = hv.admission.usage(HOSTILE) if behavior is not None \
        else hv.admission.usage(VICTIM)
    report = ScenarioReport(
        behavior=behavior.name if behavior is not None else "solo",
        rounds=rounds, steps=steps, latency=latency,
        submitted=dict(ctl.submitted), completed=completed,
        cancelled=cancelled, shed=dict(ctl.shed),
        rate_limited=int(usage["rate_limited"]),
        pages_scrubbed=gw.engine.pool.pages_scrubbed,
        free_pages_checked=free_checked)
    gw.close()
    return report
