"""RC2F shell: hosts up to four isolated user cores on one physical device
(paper §IV-D1, Fig. 4).

Two co-residency modes, both real on TPU:

  * ``FusedShell`` — the honest analogue of N partial-reconfiguration regions
    inside one bitstream: one SPMD program executes all resident cores each
    "shell cycle" (their HLO is independent → XLA schedules them in
    parallel); they share the device's HBM bandwidth exactly as the paper's
    cores share the PCIe link. Swapping one core = recompiling this fused
    program (fast via the PR cache) while state of other cores persists.

  * ``SpatialShell`` — vSlices as disjoint sub-meshes of the physical mesh
    (stronger isolation; each slice has its own executable). Used by the
    launcher at pod scale; on this host it degrades to slot bookkeeping over
    the single CPU device.

The shell also owns the gcs and one ucs per slot.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_db import MAX_SLOTS
from repro.rc2f.control import ConfigSpace, device_registers, make_gcs, make_ucs
from repro.rc2f.core_api import CoreSpec, compile_core


@dataclasses.dataclass
class _Slot:
    core_fn: Optional[Callable] = None     # uncompiled shell-convention core
    spec: Optional[CoreSpec] = None
    ucs: Optional[ConfigSpace] = None
    user: Optional[str] = None


class FusedShell:
    """N co-resident cores fused into one program sharing the device."""

    def __init__(self, n_slots: int = MAX_SLOTS):
        assert 1 <= n_slots <= MAX_SLOTS
        self.n_slots = n_slots
        self.gcs = make_gcs()
        self.slots: List[_Slot] = [_Slot() for _ in range(n_slots)]
        self._fused = None           # compiled fused program
        self._dirty = True

    # ---------------- slot management (PR regions) ----------------
    def load(self, slot: int, user_fn: Callable, spec: CoreSpec,
             user: str = "anon"):
        """Partial reconfiguration of one region: only the fused program is
        re-jitted; other slots' cores are untouched."""
        s = self.slots[slot]
        s.core_fn, s.spec, s.user = user_fn, spec, user
        s.ucs = make_ucs()
        self._dirty = True
        self.gcs.write("active_mask",
                       self.gcs.read("active_mask") | (1 << slot))
        self.gcs.write("clock_enable", 1)

    def unload(self, slot: int):
        self.slots[slot] = _Slot()
        self._dirty = True
        mask = self.gcs.read("active_mask") & ~(1 << slot)
        self.gcs.write("active_mask", mask)
        if mask == 0:
            self.gcs.write("clock_enable", 0)   # park: gate clocks

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.core_fn is not None]

    # ---------------- fused execution ----------------
    def _build(self):
        active = self.active_slots()
        fns = [compile_core(self.slots[i].core_fn, self.slots[i].spec)
               for i in active]

        def fused(reg_trees, all_blocks):
            outs = []
            for fn, regs, blocks in zip(fns, reg_trees, all_blocks):
                outs.append(fn(regs, *blocks))
            return tuple(outs)

        self._fused = fused
        self._dirty = False

    def run_cycle(self, inputs: Dict[int, Tuple]) -> Dict[int, Tuple]:
        """One shell cycle: every active core consumes one block from its
        input FIFOs. ``inputs`` maps slot -> tuple of stream blocks."""
        active = self.active_slots()
        if set(inputs) != set(active):
            raise ValueError(f"inputs for slots {sorted(inputs)} but active "
                             f"slots are {active}")
        if self._dirty:
            self._build()
        regs = []
        blocks = []
        for i in active:
            ucs_snap = self.slots[i].ucs.snapshot()
            regs.append({k: jnp.asarray(v, jnp.int32)
                         for k, v in ucs_snap.items()})
            blocks.append(inputs[i])
        outs = self._fused(regs, blocks)
        self.gcs.write("step_counter", self.gcs.read("step_counter") + 1)
        return {slot: out for slot, out in zip(active, outs)}

    # ---------------- accounting ----------------
    def shell_overhead_bytes(self) -> int:
        """Device-side footprint of the shell itself (gcs + ucs replicas +
        FIFO staging) — Table II's 'framework resources' analogue."""
        gcs_bytes = len(self.gcs.snapshot()) * 4
        ucs_bytes = sum(len(s.ucs.snapshot()) * 4 for s in self.slots
                        if s.ucs is not None)
        return gcs_bytes + ucs_bytes


class SpatialShell:
    """vSlices as disjoint sub-meshes of a physical device's chip grid."""

    def __init__(self, devices: Optional[Sequence] = None,
                 n_slots: int = MAX_SLOTS):
        self.devices = list(devices if devices is not None else jax.devices())
        self.n_slots = n_slots
        self.gcs = make_gcs()
        per = max(1, len(self.devices) // n_slots)
        self._groups = [self.devices[i * per:(i + 1) * per] or
                        [self.devices[i % len(self.devices)]]
                        for i in range(n_slots)]
        self.slots: List[_Slot] = [_Slot() for _ in range(n_slots)]
        self._compiled: Dict[int, Callable] = {}

    def slot_mesh(self, slot: int, axis: str = "slice"):
        devs = np.array(self._groups[slot])
        return jax.sharding.Mesh(devs, (axis,))

    def load(self, slot: int, user_fn: Callable, spec: CoreSpec,
             user: str = "anon"):
        s = self.slots[slot]
        s.core_fn, s.spec, s.user = user_fn, spec, user
        s.ucs = make_ucs()
        core = compile_core(user_fn, spec)
        self._compiled[slot] = core
        self.gcs.write("active_mask",
                       self.gcs.read("active_mask") | (1 << slot))

    def run(self, slot: int, *blocks):
        s = self.slots[slot]
        regs = {k: jnp.asarray(v, jnp.int32)
                for k, v in s.ucs.snapshot().items()}
        return self._compiled[slot](regs, *blocks)
