"""Admission control for the RC2F shell — the paper's planned "sanity
checking for (partial) bitfiles" (§VI) plus per-service-model quotas.

Two layers:

* ``admit_core`` — structural checks on a user core, realized as abstract
  evaluation: the core must trace successfully against its declared stream
  shapes, touch no out-of-contract state, and produce finite-sized outputs.
* ``AdmissionController`` — capacity/quota policy per service model
  (RSaaS / RAaaS / BAaaS): how many slots one tenant may hold, how many
  requests it may keep in flight, and how large a request may be. The
  hypervisor owns one controller; the serving gateway consults it before
  any tenant traffic reaches a device.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import jax


class AdmissionError(RuntimeError):
    pass


MAX_OUTPUT_BYTES = 16 << 30      # per block, per slice
MAX_INTERMEDIATE_RATIO = 1024    # outputs can't explode vs inputs


def admit_core(core_fn: Callable, example_inputs) -> None:
    """Abstract-eval the core against declared shapes (no FLOPs spent).

    Raises AdmissionError on contract violations — the analogue of rejecting
    a tampered bitstream before it touches the device.
    """
    try:
        out = jax.eval_shape(core_fn, *example_inputs) \
            if isinstance(example_inputs, tuple) \
            else jax.eval_shape(core_fn, example_inputs)
    except Exception as e:  # noqa: BLE001
        raise AdmissionError(f"core failed abstract evaluation: {e}") from e

    in_bytes = sum(_nbytes(x) for x in jax.tree.leaves(example_inputs))
    out_bytes = sum(_nbytes(x) for x in jax.tree.leaves(out))
    if out_bytes > MAX_OUTPUT_BYTES:
        raise AdmissionError(
            f"core output {out_bytes} bytes exceeds per-slice limit")
    if in_bytes and out_bytes > MAX_INTERMEDIATE_RATIO * in_bytes:
        raise AdmissionError(
            f"core amplifies {in_bytes}B -> {out_bytes}B (> x{MAX_INTERMEDIATE_RATIO})")


def _nbytes(aval) -> int:
    import numpy as np
    return int(np.prod(aval.shape)) * aval.dtype.itemsize if aval.shape else \
        aval.dtype.itemsize


# ---------------------------------------------------------------------------
# Per-service-model quotas (paper §III: the three models expose different
# amounts of the device, so they get different ceilings)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ServiceQuota:
    max_slots_per_tenant: int = 4        # vSlice slots one tenant may hold
    max_inflight_requests: int = 32      # concurrent serving requests
    max_prompt_tokens: int = 4096
    max_new_tokens: int = 1024
    # KV-cache pool pages one tenant may hold on a paged engine (0 = no
    # cap). Enforced at the engine's admission gate with
    # queue-on-exhaustion semantics: a tenant at its ceiling has further
    # requests wait in its queue instead of OOMing the shared pool — the
    # memory-fabric analogue of the slot quota (per-tenant accounting of
    # every shared resource, not just compute).
    max_cache_pages_per_tenant: int = 0
    # Token-bucket rate limit on request submission (0 = unlimited).
    # ``rate_limit_rps`` refills the bucket per clock second;
    # ``rate_limit_burst`` caps it (0 derives max(1, rps)). Refusals shed
    # a cancel/resubmit churn or request-flood attack at the cheapest
    # possible point — before any prefill, page, or slot is touched.
    rate_limit_rps: float = 0.0
    rate_limit_burst: int = 0


DEFAULT_QUOTAS: Dict[str, ServiceQuota] = {
    # RSaaS tenants own whole devices; request limits are irrelevant there
    "rsaas": ServiceQuota(max_slots_per_tenant=4, max_inflight_requests=256),
    "raas": ServiceQuota(max_slots_per_tenant=2, max_inflight_requests=64),
    # BAaaS is the shared serving pool: tight per-tenant ceilings so one
    # tenant cannot monopolize the provider's device
    "baas": ServiceQuota(max_slots_per_tenant=2, max_inflight_requests=16,
                         max_prompt_tokens=2048, max_new_tokens=512,
                         max_cache_pages_per_tenant=256),
}


@dataclass
class _TenantUsage:
    slots: int = 0
    inflight: int = 0
    admitted: int = 0
    rejected: int = 0
    rate_limited: int = 0
    bucket: float = -1.0        # token-bucket level (-1: not yet filled)
    refilled_at: float = 0.0


class AdmissionController:
    """Quota bookkeeping per (tenant, service model): what a tenant holds
    under RAaaS does not count against its BAaaS ceiling and vice versa.

    Raises ``AdmissionError`` when a tenant would exceed its ceiling; the
    caller (hypervisor / gateway) never allocates on a rejected request.

    ``clock`` drives the rate-limit token buckets. The hypervisor passes
    its own (fake, in tests and the soak harness) clock so refill is
    deterministic event time, never wall time — the same discipline as
    every other time source in the stack.
    """

    def __init__(self, quotas: Optional[Dict[str, ServiceQuota]] = None,
                 clock: Optional[Callable[[], float]] = None):
        self.quotas = dict(DEFAULT_QUOTAS)
        if quotas:
            self.quotas.update(quotas)
        self.clock = clock if clock is not None else time.monotonic
        self._usage: Dict[tuple, _TenantUsage] = {}

    def quota_for(self, service_model: str) -> ServiceQuota:
        try:
            return self.quotas[service_model]
        except KeyError:
            raise AdmissionError(f"unknown service model {service_model!r}") \
                from None

    def _u(self, tenant: str, service_model: str) -> _TenantUsage:
        return self._usage.setdefault((tenant, service_model),
                                      _TenantUsage())

    # ---------------- tenant (slot) admission ----------------
    def admit_tenant(self, tenant: str, service_model: str, slots: int):
        q = self.quota_for(service_model)
        u = self._u(tenant, service_model)
        if u.slots + slots > q.max_slots_per_tenant:
            u.rejected += 1
            raise AdmissionError(
                f"tenant {tenant!r} would hold {u.slots + slots} slots, "
                f"{service_model} quota is {q.max_slots_per_tenant}")
        u.slots += slots

    def release_tenant(self, tenant: str, service_model: str, slots: int):
        u = self._u(tenant, service_model)
        u.slots = max(0, u.slots - slots)

    # ---------------- request admission ----------------
    def _take_rate_token(self, tenant: str, service_model: str,
                         q: ServiceQuota, u: _TenantUsage) -> None:
        """Per-tenant token bucket: refill at ``rate_limit_rps`` per clock
        second up to the burst cap, spend one token per submission.
        Raises (and counts the refusal) when the bucket is dry — the
        caller sheds the request before it costs anything downstream."""
        if q.rate_limit_rps <= 0:
            return
        burst = float(q.rate_limit_burst) if q.rate_limit_burst > 0 \
            else max(1.0, q.rate_limit_rps)
        now = self.clock()
        if u.bucket < 0:
            u.bucket = burst               # a new tenant starts with a
            u.refilled_at = now            # full burst allowance
        else:
            u.bucket = min(burst, u.bucket +
                           max(0.0, now - u.refilled_at) * q.rate_limit_rps)
            u.refilled_at = now
        if u.bucket < 1.0:
            u.rejected += 1
            u.rate_limited += 1
            raise AdmissionError(
                f"tenant {tenant!r} rate-limited: {service_model} allows "
                f"{q.rate_limit_rps} req/s (burst {burst:g})")
        u.bucket -= 1.0

    def admit_request(self, tenant: str, service_model: str,
                      prompt_tokens: int, new_tokens: int):
        q = self.quota_for(service_model)
        u = self._u(tenant, service_model)
        self._take_rate_token(tenant, service_model, q, u)
        if u.inflight >= q.max_inflight_requests:
            u.rejected += 1
            raise AdmissionError(
                f"tenant {tenant!r} has {u.inflight} requests in flight "
                f"(quota {q.max_inflight_requests})")
        if prompt_tokens > q.max_prompt_tokens:
            u.rejected += 1
            raise AdmissionError(
                f"prompt of {prompt_tokens} tokens exceeds "
                f"{service_model} limit {q.max_prompt_tokens}")
        if new_tokens > q.max_new_tokens:
            u.rejected += 1
            raise AdmissionError(
                f"{new_tokens} new tokens exceeds {service_model} "
                f"limit {q.max_new_tokens}")
        u.inflight += 1
        u.admitted += 1

    def finish_request(self, tenant: str, service_model: str):
        u = self._u(tenant, service_model)
        u.inflight = max(0, u.inflight - 1)

    # ---------------- introspection ----------------
    def usage(self, tenant: str,
              service_model: Optional[str] = None) -> dict:
        """Usage counters for one service model, or summed across all of a
        tenant's models when ``service_model`` is None. Read-only: never
        creates usage records for unknown tenants."""
        if service_model is not None:
            us = [self._usage.get((tenant, service_model),
                                  _TenantUsage())]
        else:
            us = [u for (t, _), u in self._usage.items() if t == tenant]
        return {"slots": sum(u.slots for u in us),
                "inflight": sum(u.inflight for u in us),
                "admitted": sum(u.admitted for u in us),
                "rejected": sum(u.rejected for u in us),
                "rate_limited": sum(u.rate_limited for u in us)}
