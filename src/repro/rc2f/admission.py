"""Admission checks for RAaaS user cores — the paper's planned "sanity
checking for (partial) bitfiles" (§VI), realized as abstract evaluation:
the core must trace successfully against its declared stream shapes, touch
no out-of-contract state, and produce finite-sized outputs."""
from __future__ import annotations

from typing import Callable

import jax


class AdmissionError(RuntimeError):
    pass


MAX_OUTPUT_BYTES = 16 << 30      # per block, per slice
MAX_INTERMEDIATE_RATIO = 1024    # outputs can't explode vs inputs


def admit_core(core_fn: Callable, example_inputs) -> None:
    """Abstract-eval the core against declared shapes (no FLOPs spent).

    Raises AdmissionError on contract violations — the analogue of rejecting
    a tampered bitstream before it touches the device.
    """
    try:
        out = jax.eval_shape(core_fn, *example_inputs) \
            if isinstance(example_inputs, tuple) \
            else jax.eval_shape(core_fn, example_inputs)
    except Exception as e:  # noqa: BLE001
        raise AdmissionError(f"core failed abstract evaluation: {e}") from e

    in_bytes = sum(_nbytes(x) for x in jax.tree.leaves(example_inputs))
    out_bytes = sum(_nbytes(x) for x in jax.tree.leaves(out))
    if out_bytes > MAX_OUTPUT_BYTES:
        raise AdmissionError(
            f"core output {out_bytes} bytes exceeds per-slice limit")
    if in_bytes and out_bytes > MAX_INTERMEDIATE_RATIO * in_bytes:
        raise AdmissionError(
            f"core amplifies {in_bytes}B -> {out_bytes}B (> x{MAX_INTERMEDIATE_RATIO})")


def _nbytes(aval) -> int:
    import numpy as np
    return int(np.prod(aval.shape)) * aval.dtype.itemsize if aval.shape else \
        aval.dtype.itemsize
