"""RC2F: the Reconfigurable Cloud Computing Framework dataplane."""
from repro.rc2f.admission import (DEFAULT_QUOTAS, AdmissionController,
                                  AdmissionError, ServiceQuota, admit_core)
from repro.rc2f.control import ConfigSpace, make_gcs, make_ucs
from repro.rc2f.core_api import CoreSpec, StreamSpec, compile_core
from repro.rc2f.fifo import (PCIE_LINK_BYTES_S, TPU_HOST_LINK_BYTES_S,
                             TPU_ICI_BYTES_S, OutputFIFO, SharedLink,
                             StreamFIFO, core_throughput)
from repro.rc2f.shell import FusedShell, SpatialShell
