"""RC2F streaming FIFOs (paper §IV-D2) + shared-link contention model.

The paper's Xillybus PCIe core gives each vFPGA an in/out FIFO pair, all
sharing one 800 MB/s host link; Table II/III measure how per-core throughput
collapses as 1→2→4 cores share it. Here:

  * ``StreamFIFO`` is the host-side double-buffered queue feeding a device
    program (``device_put`` prefetch thread = the asynchronous FIFO that
    "divides the system clock from the user clock").
  * ``SharedLink`` is an accounting model of the scarce interconnect: every
    transfer reserves bandwidth over a time interval; concurrent reservations
    split it fairly. It reproduces the paper's contention numbers exactly and
    is what benchmarks/table2_shell.py and table3_matmul.py sweep.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Iterable, List, Optional

import jax
import numpy as np

PCIE_LINK_BYTES_S = 800e6          # paper's Xillybus limit
TPU_HOST_LINK_BYTES_S = 32e9       # realistic host->HBM ingestion per host
TPU_ICI_BYTES_S = 50e9             # per ICI link (roofline constant)


# ---------------------------------------------------------------------------
# Analytic shared-link model (used by benchmarks; deterministic)
# ---------------------------------------------------------------------------

@dataclass
class SharedLink:
    """Fair-share bandwidth accounting for N concurrent streams."""
    bandwidth_bytes_s: float = PCIE_LINK_BYTES_S

    def stream_time_s(self, bytes_per_stream: float, n_streams: int) -> float:
        """Wall time for n identical concurrent streams to move their bytes
        over the fair-shared link."""
        if n_streams <= 0:
            return 0.0
        return bytes_per_stream / (self.bandwidth_bytes_s / n_streams)

    def per_stream_throughput(self, n_streams: int) -> float:
        return self.bandwidth_bytes_s / max(n_streams, 1)


def core_throughput(compute_bytes_s: float, link: SharedLink,
                    n_streams: int) -> float:
    """Effective per-core streaming throughput when a compute-bound core
    (processing ``compute_bytes_s``) shares the link with n-1 peers.

    This is the paper's Table III model: min(compute rate, fair link share).
    """
    return min(compute_bytes_s, link.per_stream_throughput(n_streams))


# ---------------------------------------------------------------------------
# Host-side streaming FIFO (double-buffered prefetch)
# ---------------------------------------------------------------------------

class StreamFIFO:
    """Bounded FIFO moving host arrays to device ahead of consumption.

    ``depth`` plays the role of the BRAM FIFO depth; a background thread
    performs ``jax.device_put`` so compute and transfer overlap (the
    asynchronous clock-domain crossing of the paper's design).
    """

    def __init__(self, depth: int = 2, device=None,
                 sharding: Optional[Any] = None):
        self.depth = depth
        self.device = device
        self.sharding = sharding
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._closed = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.bytes_in = 0
        self.items_in = 0

    def _put_target(self, item):
        if self.sharding is not None:
            return jax.device_put(item, self.sharding)
        if self.device is not None:
            return jax.device_put(item, self.device)
        return jax.device_put(item)

    def feed(self, iterable: Iterable):
        """Start the producer thread over ``iterable``."""
        def run():
            for item in iterable:
                if self._closed.is_set():
                    return
                dev_item = self._put_target(item)
                self.bytes_in += sum(
                    np.asarray(x).nbytes for x in jax.tree.leaves(item))
                self.items_in += 1
                self._q.put(dev_item)
            self._q.put(_EOS)
        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()
        return self

    def get(self, timeout: float = 60.0):
        item = self._q.get(timeout=timeout)
        if item is _EOS:
            raise StopIteration
        return item

    def __iter__(self):
        while True:
            try:
                yield self.get()
            except StopIteration:
                return

    def close(self):
        self._closed.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


class _EOSType:
    pass


_EOS = _EOSType()


class OutputFIFO:
    """Device->host result queue with async host fetch."""

    def __init__(self, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.bytes_out = 0

    def put(self, item):
        item = jax.tree.map(np.asarray, item)   # blocks until ready
        self.bytes_out += sum(x.nbytes for x in jax.tree.leaves(item))
        self._q.put(item)

    def get(self, timeout: float = 60.0):
        return self._q.get(timeout=timeout)

    def empty(self) -> bool:
        return self._q.empty()
