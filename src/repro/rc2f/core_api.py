"""RC2F user-core API (paper §IV-D/E).

A *user core* is the RAaaS tenant's compute kernel: a pure function over
input streams, declared with its stream shapes. ``compile_core`` is the HLS
analogue — it takes the user's plain Python/JAX function ("C function") and
produces a shell-compatible jitted core ("RTL") with the standard FIFO
interface: f(ucs_registers, *stream_blocks) -> stream_blocks.

The CUDA/OpenCL-inspired host API (paper §IV-D2) groups calls into
  (a) device control / status        -> Hypervisor.status / ConfigSpace
  (b) kernel control / reconfigure   -> deploy / swap on RAaaSSession
  (c) data transfers                 -> StreamFIFO / OutputFIFO
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Declared shape/dtype of one FIFO block."""
    shape: Tuple[int, ...]
    dtype: str = "float32"

    def aval(self):
        return jax.ShapeDtypeStruct(self.shape, jnp.dtype(self.dtype))


@dataclasses.dataclass(frozen=True)
class CoreSpec:
    """The user core's declared interface (the HLS pragma block)."""
    name: str
    in_streams: Tuple[StreamSpec, ...]
    out_streams: Tuple[StreamSpec, ...]
    flops_per_block: float = 0.0      # for placement/roofline accounting

    def example_inputs(self):
        return tuple(s.aval() for s in self.in_streams)


def compile_core(user_fn: Callable, spec: CoreSpec,
                 donate_inputs: bool = False) -> Callable:
    """'HLS synthesis': wrap the user function into the shell calling
    convention and jit it. The wrapped core takes (ucs, *blocks)."""

    def core(ucs: Dict[str, jnp.ndarray], *blocks):
        out = user_fn(*blocks, **({"ucs": ucs} if _wants_ucs(user_fn) else {}))
        if not isinstance(out, tuple):
            out = (out,)
        return out

    core.__name__ = f"rc2f_core_{spec.name}"
    jit_kwargs = {}
    if donate_inputs:
        jit_kwargs["donate_argnums"] = tuple(range(1, 1 + len(spec.in_streams)))
    return jax.jit(core, **jit_kwargs)


def _wants_ucs(fn: Callable) -> bool:
    import inspect
    try:
        return "ucs" in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False
