"""RC2F configuration spaces (paper §IV-D1).

gcs — global configuration space: hypervisor-owned status/control registers
      of the shell (one per physical device).
ucs — user configuration space: per-vSlice user-defined command registers
      (the dual-port memory between host API and user core).

Registers live host-side as plain dicts (control plane) and are *threaded
through the step function* as a small pytree when a core wants on-device
access (e.g. step counters, soft-reset flags) — mirroring the paper's
"accessible from the host through the API and on the FPGA via dedicated
control signals".
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict

import jax.numpy as jnp

GCS_FIELDS = ("magic", "version", "n_slots", "active_mask", "soft_reset",
              "clock_enable", "step_counter", "error_flags")
UCS_SIZE = 16   # user-definable command registers per slice


class ConfigSpace:
    """Thread-safe register file with read/write latency accounting."""

    def __init__(self, fields, name: str):
        self._regs: Dict[str, int] = {f: 0 for f in fields}
        self._lock = threading.Lock()
        self.name = name
        self.reads = 0
        self.writes = 0

    def read(self, reg: str) -> int:
        with self._lock:
            self.reads += 1
            return self._regs[reg]

    def write(self, reg: str, value: int):
        with self._lock:
            if reg not in self._regs:
                raise KeyError(f"{self.name}: no register {reg!r}")
            self.writes += 1
            self._regs[reg] = int(value)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._regs)


def make_gcs() -> ConfigSpace:
    gcs = ConfigSpace(GCS_FIELDS, "gcs")
    gcs.write("magic", 0x5C3E)
    gcs.write("version", 2)
    gcs.write("n_slots", 4)
    gcs.write("clock_enable", 0)   # parked: clocks gated (energy policy)
    return gcs


def make_ucs() -> ConfigSpace:
    return ConfigSpace([f"r{i}" for i in range(UCS_SIZE)], "ucs")


def device_registers(gcs: ConfigSpace):
    """Lower the gcs into a device-side pytree (threaded through step fns)."""
    snap = gcs.snapshot()
    return {k: jnp.asarray(v, jnp.int32) for k, v in snap.items()}
