"""Checkpointing: atomic sharded-aware save/restore with keep-k retention,
optional async save, and cross-mesh resharding for elastic restarts.

Layout:  <dir>/step_<N>/
           manifest.json   (treedef, shapes, dtypes, step, extra metadata)
           leaf_<i>.npy    (one file per leaf, host-gathered)
         <dir>/step_<N>.tmp/ -> atomic rename on completion.

On a multi-host cluster each host would write its address-space shards;
here (single-host) leaves are gathered full. ``restore`` optionally takes a
(mesh, spec_tree) to place leaves directly onto a (possibly different) mesh
— that is the elastic-rescale path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MANIFEST = "manifest.json"


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def save(state, directory: str, step: int, keep: int = 3,
         extra: Optional[dict] = None) -> str:
    """Atomic synchronous save. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = _step_dir(directory, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = jax.tree.flatten(state)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(np.shape(l)) for l in leaves],
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "extra": extra or {},
    }
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), np.asarray(leaf))
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(directory, keep)
    return final


def save_async(state, directory: str, step: int, keep: int = 3,
               extra: Optional[dict] = None) -> threading.Thread:
    """Snapshot to host memory synchronously (cheap), write in background."""
    host_state = jax.tree.map(np.asarray, state)
    t = threading.Thread(target=save,
                         args=(host_state, directory, step, keep, extra),
                         daemon=True)
    t.start()
    return t


def _retain(directory: str, keep: int):
    steps = available_steps(directory)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_step_dir(directory, s), ignore_errors=True)


def available_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, MANIFEST)):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = available_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, like, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (a state pytree or eval_shape).

    ``shardings``: optional matching pytree of NamedSharding — leaves are
    device_put directly with that placement (elastic remesh path).
    Returns (state, step).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = _step_dir(directory, step)
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree.flatten(like)
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected "
            f"{len(leaves_like)} — architecture mismatch")
    shard_leaves = (jax.tree.flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_like))
    out = []
    for i, (ref, shd) in enumerate(zip(leaves_like, shard_leaves)):
        arr = np.load(os.path.join(path, f"leaf_{i}.npy"))
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(f"leaf {i}: shape {arr.shape} != "
                             f"{np.shape(ref)}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jnp.asarray(arr))
    return treedef.unflatten(out), step


def reshard(state, mesh, spec_tree):
    """Move a (host or device) state onto ``mesh`` with ``spec_tree``
    PartitionSpecs — the elastic grow/shrink primitive."""
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), NamedSharding(mesh, s)),
        state, spec_tree)
