from repro.ckpt.checkpoint import (available_steps, latest_step, reshard,
                                   restore, save, save_async)
