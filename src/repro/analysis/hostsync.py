"""Hot-path host-sync pass.

``BatchingEngine.step()`` is the per-token loop: everything it reaches
runs once per decoded token for every active slot. A device->host sync
there (``np.asarray`` on a device array, ``.item()``, ``float()`` of a
traced value, ``block_until_ready``) stalls the accelerator pipeline per
token; a host->device re-wrap (``jnp.asarray`` of host state) uploads per
token. The paper's monitoring loop (§V) is explicitly off the data path
for the same reason.

The pass computes the set of functions reachable from
``BatchingEngine.step`` (conservative name-based call graph) and flags
every sync marker inside them. Justified sites carry
``# rc3e: allow-host-sync`` with a reason; merely grandfathered ones live
in the committed baseline.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.common import (Finding, Workspace, call_name,
                                   dotted_call)

PASS = "hostsync"
RULE = "host-sync"
HOT_ROOT = "BatchingEngine.step"

# device -> host (each one is a pipeline stall in the per-token loop)
D2H_CALLS = {"asarray", "array", "item", "block_until_ready", "tolist"}
# numpy module aliases whose .asarray/.array force a device download
NUMPY_NAMES = {"np", "numpy"}
# host -> device: re-uploading host state every step
JNP_NAMES = {"jnp"}


def _marker(node: ast.Call) -> str:
    """Classify a call as a sync marker; '' if benign."""
    name = call_name(node)
    f = node.func
    if isinstance(f, ast.Attribute):
        base = f.value
        if name in {"asarray", "array"} and isinstance(base, ast.Name):
            if base.id in NUMPY_NAMES:
                return f"np.{name}() forces a device->host download"
            if base.id in JNP_NAMES:
                return (f"jnp.{name}() re-uploads host state to the "
                        "device every step")
        if name == "item":
            return ".item() blocks on the device and downloads a scalar"
        if name == "tolist" and not isinstance(base, ast.Constant):
            return ".tolist() downloads the whole array"
        if name == "block_until_ready":
            return ".block_until_ready() stalls until the device drains"
    if isinstance(f, ast.Name):
        if name == "float" and node.args \
                and not isinstance(node.args[0], ast.Constant):
            return "float() of a device value blocks and downloads it"
        if name == "block_until_ready":
            return ".block_until_ready() stalls until the device drains"
    return ""


def run(ws: Workspace) -> List[Finding]:
    hot = ws.reachable_from(HOT_ROOT)
    out: List[Finding] = []
    for mod in ws.modules:
        for fi in mod.functions:
            if f"{mod.rel}::{fi.qualname}" not in hot:
                continue
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                why = _marker(node)
                if not why:
                    continue
                if mod.allows(node.lineno, RULE, fi.node):
                    continue
                out.append(Finding(
                    PASS, RULE, mod.rel, node.lineno, fi.qualname,
                    f"{dotted_call(node) or call_name(node)}() in the "
                    f"per-token hot path (reachable from {HOT_ROOT}): "
                    f"{why} — hoist it out of the loop, keep the value "
                    "on-device, or justify with `# rc3e: allow-host-sync`"))
    return out
