"""Ownership pass: linear-types discipline for RC3E's resource grants,
checked at rest.

The serving stack hand-maintains ~10 call-site conventions pairing every
resource *acquire* with exactly one *release*:

  * ``PagePoolManager`` — ``_alloc_one``/``admit``/``grow``/``cow`` vs
    ``_decref``/``release_slot`` (pool pages);
  * ``AdmissionController`` — ``admit_tenant``/``admit_request``/
    ``admit_serving_request`` vs ``release_tenant``/``finish_request``
    (quota charge vs settle);
  * the fleet recovery journal — append vs retire (``journal.pop`` /
    ``del journal[...]`` / the ``_on_finish`` settle path).

PR 5's chaos suite checks these dynamically (conservation after every
step); this pass checks the same discipline statically, so a refactor
that drops a rollback is caught before any seed ever has to find it.

Rules:

  * **unguarded-acquire** — an acquire call followed, in the same
    function, by a statement that can raise, with no matching release
    anywhere after it and no try/except/finally handler releasing it:
    the charge escapes on the error path.
  * **discarded-handle** — the result of a handle-returning acquire used
    as a bare expression statement: the handle is dropped on the floor
    and can never be released.
  * **unretired-cancel** — a function marking fleet requests cancelled
    (``_mark_cancelled``) without retiring their journal entries in the
    same function: a settled request could later be replayed.
  * **unscrubbed-free** — a function allocating pool pages
    (``pool.admit``/``pool.grow``/``pool.cow`` — every path that can hand
    a RECYCLED page to a new tenant) without the zero-on-free flush
    (``_flush_scrub``/``take_scrub``) anywhere in the same function: a
    freed tenant's KV could be re-exposed through a recycled page. The
    dynamic backstop is ``PagePoolManager._alloc_one``'s pending-scrub
    assert; this catches the bypass at rest.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import List, Optional, Set

from repro.analysis.common import (Finding, ModuleInfo, Workspace, call_name,
                                   dotted_call)

PASS = "ownership"


@dataclasses.dataclass(frozen=True)
class ResourceRule:
    name: str                 # resource family, used in messages
    acquires: frozenset      # call names that charge/allocate
    releases: frozenset      # call names that settle/free
    returns_handle: frozenset = frozenset()   # subset whose result is a handle


RULES = [
    ResourceRule(
        "pool-page",
        acquires=frozenset({"_alloc_one"}),
        releases=frozenset({"_decref", "release_slot"}),
        returns_handle=frozenset({"_alloc_one"})),
    ResourceRule(
        "admission-quota",
        acquires=frozenset({"admit_tenant", "admit_request",
                            "admit_serving_request"}),
        releases=frozenset({"release_tenant", "finish_request"})),
    ResourceRule(
        "vslice",
        acquires=frozenset({"allocate_slice", "allocate_vslice",
                            "allocate_exclusive", "open_serving_session"}),
        releases=frozenset({"release", "close_serving_session",
                            "mark_device_dead", "mark_node_dead"}),
        returns_handle=frozenset({"allocate_slice", "allocate_vslice",
                                  "open_serving_session"})),
    # note: PagePoolManager.grow/cow are NOT acquire rules — they register
    # the new page into the pool's slot block table before returning, so
    # the pool owns the handle from birth (release_slot frees it).
]

# Calls that cannot meaningfully raise mid-protocol: bookkeeping,
# logging, container ops, cheap builtins. Anything else after an acquire
# counts as fallible.
SAFE_CALLS = {
    "_log", "log", "append", "appendleft", "extend", "remove", "discard",
    "add", "pop", "popleft", "get", "set", "setdefault", "update", "clear",
    "items", "keys", "values", "copy", "join", "split", "format",
    "len", "int", "str", "float", "bool", "max", "min", "abs", "round",
    "sum", "any", "all", "sorted", "list", "dict", "tuple", "frozenset",
    "range", "enumerate", "zip", "next", "iter", "id", "hash", "repr",
    "isinstance", "issubclass", "getattr", "hasattr", "setattr",
    "monotonic", "time", "is_set", "deque", "count", "field", "replace",
    "print", "debug", "info", "warning",
    "heappush", "heappop", "heapify",
    # registered-state bookkeeping on already-validated handles, and the
    # injectable clock (a FakeClock/monotonic read)
    "set_slice_state", "clock",
    # sanitizer event points: emit() raises only on a lifecycle violation,
    # at which point the process is dying — not an escape path
    "emit", "scope",
}

JOURNAL_MARK = "_mark_cancelled"
JOURNAL_RETIRE_CALLS = {"_on_finish", "cancel_queued", "_retire_entry"}

# Every PagePoolManager entry point that can hand a RECYCLED page to a new
# tenant, and the scrub hooks that must run first (the engine's batched
# device-side zeroing, or a direct drain of the pending-scrub queue).
POOL_RECYCLE_CALLS = frozenset({"admit", "grow", "cow"})
SCRUB_HOOKS = frozenset({"_flush_scrub", "take_scrub"})


def _is_fallible(stmt: ast.stmt) -> Optional[ast.AST]:
    """First node in ``stmt`` that can raise: a non-safe call, or an
    explicit raise/assert."""
    for node in ast.walk(stmt):
        if isinstance(node, (ast.Raise, ast.Assert)):
            return node
        if isinstance(node, ast.Call):
            name = call_name(node)
            if name is not None and name not in SAFE_CALLS:
                return node
    return None


def _calls_in(nodes) -> Set[str]:
    out: Set[str] = set()
    for n in nodes:
        for c in ast.walk(n):
            if isinstance(c, ast.Call):
                name = call_name(c)
                if name:
                    out.add(name)
    return out


def _protecting_trys(func: ast.AST, node: ast.AST,
                     releases: frozenset) -> bool:
    """Is ``node`` inside a try whose except handlers or finally body
    release the resource? (The codebase's rollback idiom.)"""
    for t in ast.walk(func):
        if not isinstance(t, ast.Try):
            continue
        start = t.body[0].lineno
        end = max(getattr(s, "end_lineno", s.lineno) for s in t.body)
        if not (start <= node.lineno <= end):
            continue
        guarded = _calls_in(t.handlers) | _calls_in(t.finalbody)
        if guarded & releases:
            return True
    return False


def _handler_ranges(func: ast.AST, line: int) -> List[tuple]:
    """Line ranges of except handlers belonging to trys whose body holds
    ``line``: those statements only run if the acquire (or something
    before it) ALREADY failed, so they are not escape paths for it."""
    out = []
    for t in ast.walk(func):
        if not isinstance(t, ast.Try):
            continue
        start = t.body[0].lineno
        end = max(getattr(s, "end_lineno", s.lineno) for s in t.body)
        if not (start <= line <= end):
            continue
        for h in t.handlers:
            out.append((h.lineno, getattr(h, "end_lineno", h.lineno)))
    return out


def _statements_after(func: ast.AST, line: int,
                      include_handlers: bool = True) -> List[ast.stmt]:
    """Top-to-bottom statements of ``func`` strictly after ``line``
    (flattened: a statement inside try/if bodies appears itself).
    ``include_handlers=False`` drops the acquire's own except handlers —
    they only run when the protocol already failed, so they are release
    paths, not escape paths."""
    skip = [] if include_handlers else _handler_ranges(func, line)
    out = []
    for node in ast.walk(func):
        if isinstance(node, ast.stmt) and node.lineno > line \
                and not isinstance(node, (ast.FunctionDef,
                                          ast.AsyncFunctionDef,
                                          ast.ClassDef)) \
                and not any(a <= node.lineno <= b for a, b in skip):
            out.append(node)
    return sorted(out, key=lambda s: s.lineno)


def _release_after(func: ast.AST, line: int, releases: frozenset) -> bool:
    for stmt in _statements_after(func, line):
        for c in ast.walk(stmt):
            if isinstance(c, ast.Call) and call_name(c) in releases:
                return True
    return False


def _check_unguarded(fi, rule: ResourceRule, out: List[Finding]):
    mod = fi.module
    func = fi.node
    for node in ast.walk(func):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if name not in rule.acquires or name == fi.name:
            continue       # skip the definition's own recursion
        if _release_after(func, node.lineno, rule.releases):
            continue       # a settle path exists downstream
        # find the first fallible statement after the acquire that is not
        # itself protected by a rollback try
        for stmt in _statements_after(func, node.lineno,
                                      include_handlers=False):
            bad = _is_fallible(stmt)
            if bad is None:
                continue
            if _protecting_trys(func, stmt, rule.releases):
                break      # rollback handler covers the remainder
            if mod.allows(node.lineno, "unguarded-acquire", func):
                break
            out.append(Finding(
                PASS, "unguarded-acquire", mod.rel, node.lineno,
                fi.qualname,
                f"{rule.name} acquired via {dotted_call(node)}() can "
                f"escape: line {stmt.lineno} may raise before any "
                f"matching release ({'/'.join(sorted(rule.releases))}) "
                "— wrap in try/except with a rollback, or release on "
                "the error path"))
            break
        # note: an acquire as the last fallible action needs no guard


def _check_discarded(mod: ModuleInfo, out: List[Finding]):
    handle_names = {n for r in RULES for n in r.returns_handle}
    rule_of = {n: r for r in RULES for n in r.returns_handle}
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Expr) \
                or not isinstance(node.value, ast.Call):
            continue
        name = call_name(node.value)
        if name not in handle_names:
            continue
        fi = mod.enclosing_function(node)
        if fi is not None and fi.name == name:
            continue
        func = fi.node if fi is not None else None
        if mod.allows(node.lineno, "discarded-handle", func):
            continue
        out.append(Finding(
            PASS, "discarded-handle", mod.rel, node.lineno,
            fi.qualname if fi else "",
            f"result of {dotted_call(node.value)}() discarded: the "
            f"{rule_of[name].name} handle escapes without an owner and "
            "can never be released"))


def _check_unscrubbed(fi, out: List[Finding]):
    """Pool allocation sites must sit behind the zero-on-free flush: a
    function calling ``pool.admit``/``pool.grow``/``pool.cow`` without a
    scrub hook in the same function can re-expose a freed tenant's KV
    through a recycled page. (``_alloc_one``'s pending-scrub assert is the
    dynamic backstop; this flags the bypass at rest.)"""
    if fi.callees & SCRUB_HOOKS:
        return
    mod = fi.module
    for node in ast.walk(fi.node):
        if not isinstance(node, ast.Call) \
                or call_name(node) not in POOL_RECYCLE_CALLS:
            continue
        recv = node.func.value if isinstance(node.func, ast.Attribute) \
            else None
        recv_name = recv.attr if isinstance(recv, ast.Attribute) else \
            recv.id if isinstance(recv, ast.Name) else None
        if recv_name != "pool":
            continue
        if mod.allows(node.lineno, "unscrubbed-free", fi.node):
            continue
        out.append(Finding(
            PASS, "unscrubbed-free", mod.rel, node.lineno, fi.qualname,
            f"pool pages allocated via {dotted_call(node)}() with no "
            "zero-on-free flush in this function: a recycled page may "
            "still hold a freed tenant's KV — call _flush_scrub() (or "
            "drain take_scrub()) before any pool allocation"))


def _check_journal(mod: ModuleInfo, out: List[Finding]):
    """Functions cancelling journaled requests must retire the journal
    entry in the same function (pop/del/_on_finish) — a settled request
    must never be replayable."""
    for fi in mod.functions:
        if fi.name == JOURNAL_MARK:
            continue
        marks = [n for n in ast.walk(fi.node) if isinstance(n, ast.Call)
                 and call_name(n) == JOURNAL_MARK]
        if not marks:
            continue
        retired = bool(fi.callees & JOURNAL_RETIRE_CALLS)
        if not retired:
            for n in ast.walk(fi.node):
                # journal.pop(...) / del self.journal[...]
                if isinstance(n, ast.Call) and call_name(n) == "pop" \
                        and isinstance(n.func, ast.Attribute) \
                        and isinstance(n.func.value, ast.Attribute) \
                        and n.func.value.attr == "journal":
                    retired = True
                if isinstance(n, ast.Delete):
                    for t in n.targets:
                        if isinstance(t, ast.Subscript) \
                                and isinstance(t.value, ast.Attribute) \
                                and t.value.attr == "journal":
                            retired = True
        if retired:
            continue
        node = marks[0]
        if mod.allows(node.lineno, "unretired-cancel", fi.node):
            continue
        out.append(Finding(
            PASS, "unretired-cancel", mod.rel, node.lineno, fi.qualname,
            f"{JOURNAL_MARK}() without retiring the journal entry in the "
            "same function: a cancelled (settled) request would stay "
            "journaled and could be replayed by a later recovery"))


def run(ws: Workspace) -> List[Finding]:
    out: List[Finding] = []
    for mod in ws.modules:
        for fi in mod.functions:
            for rule in RULES:
                _check_unguarded(fi, rule, out)
            _check_unscrubbed(fi, out)
        _check_discarded(mod, out)
        _check_journal(mod, out)
    return out
