"""Determinism pass.

PR 5's chaos harness replays seeded kill schedules bit-exactly; that only
holds if the runtime is a pure function of (seed, workload). Three things
silently break it:

  * **time-time** — ``time.time()`` (wall clock) in ``runtime/`` or
    ``core/``: chaos runs use the injectable ``FakeClock``; wall-clock
    reads make replays diverge. ``time.monotonic()`` stays legal — the
    codebase uses it for latency *measurement*, never control flow.
  * **unseeded-random** — ``random.random()``, ``random.choice``, bare
    ``random.Random()``: any randomness must flow through
    ``repro.runtime.faults.seeded_rng(seed)`` so a seed pins the run.
    Enforced repo-wide.
  * **set-iteration** — ``for x in <set-literal/set()/set-typed attr>``:
    Python set iteration order is salted per process; iterating one in
    ``runtime/``/``core/`` makes event order differ between runs. Wrap
    in ``sorted(...)`` to fix the order.
  * **round-counter** — reading the fleet-wide round counter
    (``.steps``) inside the event loop (``runtime/events.py``): event
    code paced by the lockstep round counter silently re-introduces the
    barrier the event queue exists to remove. The loop keeps its own
    ``ticks`` count; engine-local pacing belongs in the engine.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.common import Finding, ModuleInfo, Workspace

PASS = "determinism"

SCOPED_DIRS = ("runtime", "core")      # time-time / set-iteration scope
RNG_HELPER = "seeded_rng"              # the one sanctioned constructor

UNSEEDED_RANDOM_FUNCS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "betavariate",
    "expovariate",
}


def _attr_chain(node: ast.AST) -> Optional[str]:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _check_time(mod: ModuleInfo, out: List[Finding]):
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        if _attr_chain(node.func) != "time.time":
            continue
        fi = mod.enclosing_function(node)
        func = fi.node if fi else None
        if mod.allows(node.lineno, "time-time", func):
            continue
        out.append(Finding(
            PASS, "time-time", mod.rel, node.lineno,
            fi.qualname if fi else "",
            "time.time() reads the wall clock — chaos replays use the "
            "injectable FakeClock; use time.monotonic() for durations or "
            "take a clock parameter"))


def _check_random(mod: ModuleInfo, out: List[Finding]):
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if chain is None:
            continue
        bad = ""
        if chain.startswith("random.") \
                and chain.split(".", 1)[1] in UNSEEDED_RANDOM_FUNCS:
            bad = f"{chain}() draws from the process-global unseeded RNG"
        elif chain in ("random.Random", "Random"):
            # even a seeded construction bypasses the choke point: the
            # helper is where seed derivation / reproducibility lives
            bad = f"{chain}() constructed outside {RNG_HELPER}()"
        if not bad:
            continue
        fi = mod.enclosing_function(node)
        if fi is not None and fi.name == RNG_HELPER:
            continue    # the sanctioned choke point itself
        func = fi.node if fi else None
        if mod.allows(node.lineno, "unseeded-random", func):
            continue
        out.append(Finding(
            PASS, "unseeded-random", mod.rel, node.lineno,
            fi.qualname if fi else "",
            f"{bad} — route it through "
            f"repro.runtime.faults.{RNG_HELPER}(seed) so a seed pins "
            "the whole run"))


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, ast.Set):
        return True
    if isinstance(node, ast.SetComp):
        return True
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Name) and f.id in ("set", "frozenset"):
            return True
        # .keys() of a dict is insertion-ordered: fine. set ops are not.
        if isinstance(f, ast.Attribute) and f.attr in (
                "union", "intersection", "difference",
                "symmetric_difference"):
            return True
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
        # a | b etc. over sets — only flag when one side is clearly a set
        return _is_set_expr(node.left) or _is_set_expr(node.right)
    return False


def _set_typed_names(scope: ast.AST) -> set:
    """Local names bound to a set expression (``s = set(xs)``; ``s = {..}``)
    anywhere in ``scope`` — iterating them later is just as unordered."""
    names = set()
    for node in ast.walk(scope):
        if isinstance(node, ast.Assign) and _is_set_expr(node.value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
    return names


def _check_set_iter(mod: ModuleInfo, out: List[Finding]):
    set_names = {}   # function node -> names bound to sets
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.For):
            it = node.iter
        elif isinstance(node, ast.comprehension):
            it = node.iter
        else:
            continue
        direct = _is_set_expr(it)
        via_name = False
        if not direct and isinstance(it, ast.Name):
            fi0 = mod.enclosing_function(it)
            scope = fi0.node if fi0 else mod.tree
            if scope not in set_names:
                set_names[scope] = _set_typed_names(scope)
            via_name = it.id in set_names[scope]
        if not (direct or via_name):
            continue
        fi = mod.enclosing_function(it)
        func = fi.node if fi else None
        if mod.allows(it.lineno, "set-iteration", func):
            continue
        out.append(Finding(
            PASS, "set-iteration", mod.rel, it.lineno,
            fi.qualname if fi else "",
            "iterating a set: order is salted per process, so event "
            "order differs between runs — wrap in sorted(...) or iterate "
            "the ordered source collection"))


EVENT_LOOP_SUFFIXES = ("runtime/events.py",)
ROUND_COUNTER_ATTR = "steps"


def _check_round_counter(mod: ModuleInfo, out: List[Finding]):
    """Flag READS of ``.steps`` in event-loop modules. Stores/AugAssigns
    are fine (an engine counts its own steps); it is basing event-loop
    control flow on the fleet round counter that re-couples the loops."""
    if not any(mod.rel.endswith(s) for s in EVENT_LOOP_SUFFIXES):
        return
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Attribute) \
                or node.attr != ROUND_COUNTER_ATTR \
                or not isinstance(node.ctx, ast.Load):
            continue
        fi = mod.enclosing_function(node)
        func = fi.node if fi else None
        if mod.allows(node.lineno, "round-counter", func):
            continue
        out.append(Finding(
            PASS, "round-counter", mod.rel, node.lineno,
            fi.qualname if fi else "",
            "event-loop code reading the fleet round counter (.steps) — "
            "pacing events off the lockstep round counter re-introduces "
            "the barrier; use the loop's own ticks / the event clock"))


def run(ws: Workspace) -> List[Finding]:
    out: List[Finding] = []
    scoped = ws.select(*SCOPED_DIRS)
    for mod in scoped:
        _check_time(mod, out)
        _check_set_iter(mod, out)
        _check_round_counter(mod, out)
    for mod in ws.modules:          # unseeded randomness: repo-wide
        _check_random(mod, out)
    return out
