"""Pallas kernel pass.

Two static rules over ``kernels/`` plus one executed registry check:

  * **traced-branch** — Python ``if``/``while`` on a traced value
    (``pl.program_id``, anything loaded from a ``*_ref``): inside a
    kernel these must be ``pl.when`` / ``jnp.where`` — a Python branch
    either fails tracing or silently bakes in one side. ``is None``
    checks on optional ref parameters and branches on static (kwonly,
    partial-bound) params stay legal.
  * **grid-divisibility** — a ``grid = (..., X // b, ...)`` whose
    numerator is neither guarded by an ``assert X % b == 0`` nor
    produced by a round-up/padding helper (``_pad_to``/``cdiv``/...):
    a non-divisible shape would silently drop the ragged tail.
  * **registry-shapes** — executed (not AST) check that every config in
    the architecture registry tiles cleanly: ``max_seq_len`` divisible
    by the decode sweep block and the KV page size, ``head_dim`` lane-
    aligned. Run against both full and ``reduced()`` shapes.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.common import (Finding, ModuleInfo, Workspace,
                                   call_name)

PASS = "kernels"

PAD_HELPERS = ("pad", "cdiv", "ceil", "round")   # substring match on callee
DECODE_BLOCK = 512      # default bk in decode_attention
PAGE_SIZE = 16          # engine default page size
LANE_ALIGN = 8


# ---------------------------------------------------------------------------
# traced-branch
# ---------------------------------------------------------------------------

def _ref_params(func: ast.AST) -> Set[str]:
    args = func.args
    names = [a.arg for a in args.posonlyargs + args.args]
    return {n for n in names if n.endswith("_ref") or n == "ref"}


def _tainted_names(func: ast.AST) -> Set[str]:
    """Names carrying traced values: assigned from pl.program_id or from
    a ``*_ref`` load, transitively through plain assignments."""
    refs = _ref_params(func)
    tainted: Set[str] = set()

    def expr_tainted(e: ast.AST) -> bool:
        for n in ast.walk(e):
            if isinstance(n, ast.Call) and call_name(n) == "program_id":
                return True
            if isinstance(n, ast.Subscript) and isinstance(n.value, ast.Name) \
                    and n.value.id in refs:
                return True
            if isinstance(n, ast.Name) and n.id in tainted:
                return True
        return False

    changed = True
    while changed:
        changed = False
        for node in ast.walk(func):
            if not isinstance(node, ast.Assign):
                continue
            if not expr_tainted(node.value):
                continue
            for t in node.targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name) and n.id not in tainted:
                        tainted.add(n.id)
                        changed = True
    return tainted


def _is_none_check(test: ast.AST) -> bool:
    if isinstance(test, ast.Compare) and any(
            isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
        return True
    if isinstance(test, ast.BoolOp):
        return all(_is_none_check(v) for v in test.values)
    return False


def _check_traced_branch(mod: ModuleInfo, out: List[Finding]):
    for fi in mod.functions:
        refs = _ref_params(fi.node)
        if not refs and "program_id" not in fi.callees:
            continue       # not a kernel body
        tainted = _tainted_names(fi.node)

        def test_tainted(test: ast.AST) -> bool:
            for n in ast.walk(test):
                if isinstance(n, ast.Call) and call_name(n) == "program_id":
                    return True
                if isinstance(n, ast.Subscript) \
                        and isinstance(n.value, ast.Name) \
                        and n.value.id in refs:
                    return True
                if isinstance(n, ast.Name) and n.id in tainted:
                    return True
            return False

        for node in ast.walk(fi.node):
            if not isinstance(node, (ast.If, ast.While)):
                continue
            if _is_none_check(node.test):
                continue   # optional-ref presence check: static
            if not test_tainted(node.test):
                continue
            if mod.allows(node.lineno, "traced-branch", fi.node):
                continue
            out.append(Finding(
                PASS, "traced-branch", mod.rel, node.lineno, fi.qualname,
                "Python branch on a traced value inside a kernel body — "
                "tracing either fails or bakes in one side; use pl.when "
                "(side effects) or jnp.where (values)"))


# ---------------------------------------------------------------------------
# grid-divisibility
# ---------------------------------------------------------------------------

def _name_of(e: ast.AST) -> str:
    return e.id if isinstance(e, ast.Name) else ast.dump(e)


def _mod_asserts(func: ast.AST) -> Set[tuple]:
    """(numerator, denominator) name pairs proven by an assert X % b == 0."""
    out: Set[tuple] = set()
    for node in ast.walk(func):
        if not isinstance(node, ast.Assert):
            continue
        for n in ast.walk(node.test):
            if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mod):
                out.add((_name_of(n.left), _name_of(n.right)))
    return out


def _padded_names(func: ast.AST) -> Set[str]:
    """Names produced by a round-up helper (``Mp = _pad_to(M, bm)``) or by
    the inline ceil idiom ``-(-n // b) * b``."""
    out: Set[str] = set()
    for node in ast.walk(func):
        if not isinstance(node, ast.Assign):
            continue
        padded = False
        for n in ast.walk(node.value):
            if isinstance(n, ast.Call):
                name = call_name(n) or ""
                if any(h in name for h in PAD_HELPERS):
                    padded = True
            if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mult) \
                    and isinstance(n.left, ast.UnaryOp) \
                    and isinstance(n.left.op, ast.USub):
                padded = True
        if padded:
            for t in node.targets:
                if isinstance(t, ast.Tuple):
                    out.update(e.id for e in t.elts
                               if isinstance(e, ast.Name))
                elif isinstance(t, ast.Name):
                    out.add(t.id)
    return out


def _check_grid(mod: ModuleInfo, out: List[Finding]):
    for fi in mod.functions:
        grids = [n for n in ast.walk(fi.node) if isinstance(n, ast.Assign)
                 and any(isinstance(t, ast.Name) and t.id == "grid"
                         for t in n.targets)]
        if not grids:
            continue
        proven = _mod_asserts(fi.node)
        padded = _padded_names(fi.node)
        for g in grids:
            for n in ast.walk(g.value):
                if not (isinstance(n, ast.BinOp)
                        and isinstance(n.op, ast.FloorDiv)):
                    continue
                num, den = _name_of(n.left), _name_of(n.right)
                if (num, den) in proven or num in padded:
                    continue
                if mod.allows(n.lineno, "grid-divisibility", fi.node):
                    continue
                out.append(Finding(
                    PASS, "grid-divisibility", mod.rel, n.lineno,
                    fi.qualname,
                    f"grid dimension {num} // {den} without an "
                    f"`assert {num} % {den} == 0` or a round-up pad of "
                    f"{num}: a non-divisible shape silently drops the "
                    "ragged tail"))


# ---------------------------------------------------------------------------
# registry-shapes (executed)
# ---------------------------------------------------------------------------

def check_registry_shapes() -> List[Finding]:
    """Divisibility of every registered architecture against the kernel
    tiling constants. Executed, not AST: the registry is data."""
    out: List[Finding] = []
    try:
        from repro.configs import registry
    except Exception as e:   # missing heavy deps in a bare lint env
        out.append(Finding(
            PASS, "registry-shapes", "configs/registry.py", 1, "",
            f"could not import the config registry: {e}"))
        return out
    for name in registry.ARCH_IDS:
        for variant, cfg in (("full", registry.get_config(name)),
                             ("reduced", registry.reduced(
                                 registry.get_config(name)))):
            L = cfg.max_seq_len
            bk = min(DECODE_BLOCK, L)
            checks = [
                (L % bk == 0,
                 f"max_seq_len={L} not divisible by decode block {bk}"),
                (L % PAGE_SIZE == 0,
                 f"max_seq_len={L} not divisible by page size "
                 f"{PAGE_SIZE}"),
                (cfg.head_dim % LANE_ALIGN == 0,
                 f"head_dim={cfg.head_dim} not {LANE_ALIGN}-aligned"),
            ]
            for ok, msg in checks:
                if not ok:
                    out.append(Finding(
                        PASS, "registry-shapes", "configs/registry.py", 1,
                        f"{name}:{variant}",
                        f"{msg} — the Pallas sweep would drop the ragged "
                        "tail of the cache"))
    return out


# ---------------------------------------------------------------------------
# tuner-shapes (executed)
# ---------------------------------------------------------------------------

TUNER_ARCHS = ("smollm-135m", "gemma3-1b")   # pinned: one small, one local/
TUNER_SPEEDS = (1.0, 0.25)                   # global-pattern arch; 2 classes
TUNER_MAX_LEN = 2048


def check_tuner_shapes() -> List[Finding]:
    """Tuner-emitted geometry tiles cleanly: run the design-space sweep
    for the pinned archs on each device class and re-verify every
    winner against the kernel registry's divisibility rules. Executed,
    not AST — the winners are data the model produces, and a cost-model
    change that starts emitting a ragged geometry must fail here, not
    in a TPU run."""
    out: List[Finding] = []
    try:
        from repro.configs import registry
        from repro.kernels import registry as kreg
        from repro.tuning import profile_for_speed, tune
    except Exception as e:   # missing heavy deps in a bare lint env
        out.append(Finding(
            PASS, "tuner-shapes", "tuning/explorer.py", 1, "",
            f"could not import the tuner: {e}"))
        return out
    for name in TUNER_ARCHS:
        cfg = registry.get_config(name)
        for speed in TUNER_SPEEDS:
            for paged in (False, True):
                best = tune(cfg, profile_for_speed(speed),
                            max_len=TUNER_MAX_LEN, paged=paged).best
                checks = [
                    kreg.check_decode_block(TUNER_MAX_LEN,
                                            best.decode_block_k),
                    kreg.check_flash_blocks(TUNER_MAX_LEN,
                                            best.flash_block_q,
                                            best.flash_block_k),
                    kreg.check_head_alignment(cfg.resolved_head_dim),
                ]
                if paged:
                    checks.append(kreg.check_page_size(TUNER_MAX_LEN,
                                                       best.page_size))
                where = f"{name}:c{speed:.2f}x:" \
                    + ("paged" if paged else "dense")
                for reason in checks:
                    if reason is not None:
                        out.append(Finding(
                            PASS, "tuner-shapes", "tuning/explorer.py", 1,
                            where,
                            f"tuned geometry {best.geometry_key()} "
                            f"violates: {reason} — the Pallas grid would "
                            "drop the ragged tail"))
    return out


def run(ws: Workspace) -> List[Finding]:
    out: List[Finding] = []
    for mod in ws.select("kernels"):
        _check_traced_branch(mod, out)
        _check_grid(mod, out)
    out.extend(check_registry_shapes())
    out.extend(check_tuner_shapes())
    return out
