"""Runtime lifecycle sanitizer (``RC3E_SANITIZE=1``).

The static passes check discipline at rest; this module checks it in
motion. Each RC3E object class has one declarative state machine — a
transition table mapping ``(state, event) -> state`` — and the runtime
emits events at its lifecycle points (engine admit/preempt/finish, fleet
drain/adopt/recover, pool alloc/free, device activate/kill, journal
append/retire). An emit that has no legal transition raises
``LifecycleViolation`` at the exact call site, so a chaos seed that
races e.g. a double-release dies loudly instead of corrupting counters.

Intentionally stdlib-only and branch-free when disabled: ``emit`` is a
single attribute load + early return unless ``RC3E_SANITIZE=1`` (or a
test called ``enable()``), so the production hot path pays one predictable
branch per event point.

Keys are caller-chosen; for per-instance machines (engines, pools) the
owner takes a ``scope()`` token at construction and namespaces its keys
with it — monotonic tokens, never ``id()``, so a GC'd engine's slot 3
can never collide with a new engine's slot 3.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Dict, FrozenSet, Mapping, Tuple


class LifecycleViolation(AssertionError):
    """An object was driven through an illegal lifecycle transition."""


@dataclasses.dataclass(frozen=True)
class Machine:
    """One lifecycle as data: states are strings, events are strings.
    ``pop_terminal`` drops the key at a terminal state so caller-chosen
    keys (request tokens, journal ids) stay bounded; sticky terminals
    (devices) keep the entry so post-mortem events still violate."""
    initial: str
    transitions: Mapping[Tuple[str, str], str]
    terminal: FrozenSet[str] = frozenset()
    pop_terminal: bool = True

    def legal_events(self, state: str):
        return sorted(e for (s, e) in self.transitions if s == state)


MACHINES: Dict[str, Machine] = {
    # A request as the engine+fleet see it. PREFILLING = admitted to a
    # slot but its prompt prefill is not yet spliced (the event loop
    # chunks it — ``chunk`` self-loops once per chunk event; the lockstep
    # loop passes through it in one admit→ready breath). TRANSIT =
    # drained for a live hand-off; ORPHANED = its device died while it
    # was queued/decoding. ``requeue`` (engine.resume) is legal from
    # QUEUED too: preemption emits preempt first, so resume's requeue
    # self-loops — but a resume of a RUNNING or DONE request is the bug
    # class this machine exists to catch (double-queue /
    # decode-after-settle).
    "request": Machine(
        initial="NEW",
        transitions={
            ("NEW", "submit"): "QUEUED",
            ("QUEUED", "admit"): "PREFILLING",
            ("QUEUED", "requeue"): "QUEUED",
            ("QUEUED", "orphan"): "ORPHANED",
            ("QUEUED", "cancel"): "DONE",
            ("PREFILLING", "chunk"): "PREFILLING",
            ("PREFILLING", "ready"): "RUNNING",
            ("PREFILLING", "drain"): "TRANSIT",
            ("PREFILLING", "orphan"): "ORPHANED",
            ("PREFILLING", "cancel"): "DONE",
            ("RUNNING", "preempt"): "QUEUED",
            ("RUNNING", "drain"): "TRANSIT",
            ("RUNNING", "orphan"): "ORPHANED",
            ("RUNNING", "finish"): "DONE",
            ("RUNNING", "cancel"): "DONE",
            ("TRANSIT", "requeue"): "QUEUED",
            ("TRANSIT", "adopt"): "RUNNING",
            ("TRANSIT", "cancel"): "DONE",
            ("ORPHANED", "requeue"): "QUEUED",
            ("ORPHANED", "cancel"): "DONE",
        },
        terminal=frozenset({"DONE"})),
    # One engine decode slot. occupy/release must alternate exactly.
    "slot": Machine(
        initial="FREE",
        transitions={
            ("FREE", "occupy"): "BUSY",
            ("BUSY", "release"): "FREE",
        }),
    # One KV-cache page in the pool. alloc/free must alternate; shares
    # (prefix-adoption increfs) and unshares (COW detach) only while
    # allocated — a decref of a free page is a double-free. ``scrub``
    # (zero-on-free) is only legal while FREE: a scrub racing a
    # reallocation would zero a live tenant's KV and is the exact bug
    # class the isolation hardening must never ship.
    "page": Machine(
        initial="FREE",
        transitions={
            ("FREE", "alloc"): "USED",
            ("FREE", "scrub"): "FREE",
            ("USED", "share"): "USED",
            ("USED", "unshare"): "USED",
            ("USED", "free"): "FREE",
        }),
    # A physical device in the DeviceDB. DEAD is terminal AND sticky:
    # failed hardware never silently returns to the pool, and any event
    # against a dead device is a violation. ``park`` self-loops from
    # PARKED (idempotent energy gating, incl. DBs restored from JSON).
    "device": Machine(
        initial="PARKED",
        transitions={
            ("PARKED", "activate"): "ACTIVE",
            ("PARKED", "exclusive"): "EXCLUSIVE",
            ("PARKED", "park"): "PARKED",
            ("ACTIVE", "activate"): "ACTIVE",      # more slices
            ("ACTIVE", "park"): "PARKED",
            ("EXCLUSIVE", "park"): "PARKED",
            ("PARKED", "kill"): "DEAD",
            ("ACTIVE", "kill"): "DEAD",
            ("EXCLUSIVE", "kill"): "DEAD",
        },
        terminal=frozenset({"DEAD"}),
        pop_terminal=False),
    # A fleet journal entry: append exactly once, replay while open only,
    # retire exactly once. The event loop batches token syncs off the
    # critical path: ``dirty`` marks the entry stale vs the live request,
    # ``flush`` copies the token log back (DIRTY→OPEN), and ``rollback``
    # abandons unflushed tokens when their device died (crash recovery
    # replays from the last flush). Retire is ONLY legal from OPEN — that
    # is the machine-enforced flush barrier: quota can never settle, and
    # a hand-off can never export, against a dirty entry. RETIRED pops
    # the key, so a replay after retire resolves against NEW — still
    # illegal, which is exactly the "settled request replayed by
    # recovery" bug.
    "journal": Machine(
        initial="NEW",
        transitions={
            ("NEW", "append"): "OPEN",
            ("OPEN", "replay"): "OPEN",
            ("OPEN", "dirty"): "DIRTY",
            ("DIRTY", "dirty"): "DIRTY",
            ("DIRTY", "flush"): "OPEN",
            ("DIRTY", "rollback"): "OPEN",
            ("OPEN", "retire"): "RETIRED",
        },
        terminal=frozenset({"RETIRED"})),
}


class Sanitizer:
    """Process-wide transition checker. Disabled it costs one branch."""

    def __init__(self) -> None:
        self.enabled = os.environ.get("RC3E_SANITIZE", "") == "1"
        self._lock = threading.Lock()
        self._state: Dict[Tuple[str, object], str] = {}
        self._counts: Dict[str, int] = {}
        self._scope = 0

    # -- control -------------------------------------------------------
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        with self._lock:
            self._state.clear()
            self._counts.clear()

    def scope(self) -> int:
        """Fresh namespace token for a per-instance machine owner. Unlike
        ``id()``, never reused after the owner is collected."""
        with self._lock:
            self._scope += 1
            return self._scope

    # -- the event point -----------------------------------------------
    def emit(self, machine: str, key, event: str) -> None:
        if not self.enabled:
            return
        m = MACHINES[machine]
        k = (machine, key)
        with self._lock:
            state = self._state.get(k, m.initial)
            nxt = m.transitions.get((state, event))
            if nxt is None:
                raise LifecycleViolation(
                    f"[{machine}] {key!r}: illegal event {event!r} in "
                    f"state {state!r} (legal: "
                    f"{m.legal_events(state) or 'none — terminal'})")
            self._counts[machine] = self._counts.get(machine, 0) + 1
            if nxt in m.terminal and m.pop_terminal:
                self._state.pop(k, None)   # key retired; id can recycle
            else:
                self._state[k] = nxt

    # -- introspection (chaos harness asserts on this) ------------------
    def stats(self) -> Dict[str, int]:
        """Transitions checked per machine since the last reset."""
        with self._lock:
            return dict(self._counts)

    def live(self, machine: str) -> int:
        """Objects currently in a non-initial, non-terminal state."""
        with self._lock:
            return sum(1 for (m, _) in self._state if m == machine)

    def state(self, machine: str, key) -> str:
        """Current state of one tracked object (tests peek at this)."""
        with self._lock:
            return self._state.get((machine, key), MACHINES[machine].initial)


sanitizer = Sanitizer()
