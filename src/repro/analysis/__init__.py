"""rc3e-check: static + dynamic enforcement of RC3E's resource discipline.

Static half (``python -m repro.analysis src/``): four AST/dataflow passes
— ownership (acquire/release pairing), hostsync (device syncs reachable
from the per-token loop), determinism (wall clocks, unseeded RNG, set
iteration), kernels (Pallas traced branches + grid divisibility +
registry shape check). Dynamic half: the ``RC3E_SANITIZE=1`` lifecycle
sanitizer in :mod:`repro.analysis.lifecycle`.

This ``__init__`` stays import-light (lifecycle only — stdlib) because
the runtime imports the sanitizer on every start; the analyzer passes
load only under ``python -m repro.analysis``.
"""
from repro.analysis.lifecycle import (LifecycleViolation, Sanitizer,
                                      sanitizer)

__all__ = ["LifecycleViolation", "Sanitizer", "sanitizer"]
