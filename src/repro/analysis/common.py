"""Shared infrastructure for the ``rc3e-check`` static analyzer.

Every pass works from the same picture of the tree: a ``Workspace`` of
parsed modules, a per-function index (qualnames, call sites, pragma
lines), and the suppression machinery — inline ``# rc3e: allow-<rule>``
pragmas for sites that are *justified*, and a committed JSON baseline for
sites that are merely *grandfathered* (the debt ledger new code must not
grow). Findings carry exact locations so tests can pin them; baseline
matching deliberately ignores line numbers so moving code does not churn
the ledger.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

PRAGMA_RE = re.compile(r"#\s*rc3e:\s*allow-([a-z0-9-]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer hit. ``key()`` (pass, rule, file, symbol) is what the
    baseline stores — line numbers are reported but not matched on."""
    pass_name: str          # ownership | hostsync | determinism | kernels
    rule: str               # e.g. unguarded-acquire, host-sync, set-iteration
    file: str               # path relative to the scanned root
    line: int
    symbol: str             # enclosing function qualname ("" = module level)
    message: str

    def key(self) -> Tuple[str, str, str, str]:
        return (self.pass_name, self.rule, self.file, self.symbol)

    def format(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return (f"{self.file}:{self.line}: "
                f"[{self.pass_name}/{self.rule}]{sym} {self.message}")


@dataclasses.dataclass
class FunctionInfo:
    """One function/method definition and everything passes ask about it."""
    qualname: str                   # "Class.method" or "func"
    name: str
    node: ast.AST                   # FunctionDef | AsyncFunctionDef
    module: "ModuleInfo"
    callees: Set[str]               # simple names of every call target

    @property
    def lineno(self) -> int:
        return self.node.lineno


class ModuleInfo:
    """A parsed source file plus its pragma map and function index."""

    def __init__(self, path: Path, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        # line -> set of allowed rule names from "# rc3e: allow-<rule>"
        self.pragmas: Dict[int, Set[str]] = {}
        for i, text in enumerate(self.lines, start=1):
            hits = PRAGMA_RE.findall(text)
            if hits:
                self.pragmas[i] = set(hits)
        self.functions: List[FunctionInfo] = []
        self._index_functions()

    def _index_functions(self):
        def visit(node, prefix):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qual = f"{prefix}{child.name}"
                    self.functions.append(FunctionInfo(
                        qual, child.name, child, self,
                        callees=call_names(child)))
                    visit(child, f"{qual}.")
                elif isinstance(child, ast.ClassDef):
                    visit(child, f"{prefix}{child.name}.")
        visit(self.tree, "")

    def allows(self, line: int, rule: str, func: Optional[ast.AST] = None
               ) -> bool:
        """Pragma on the finding's line, or on/above the enclosing def
        (a def-line pragma waives the rule for the whole function)."""
        if rule in self.pragmas.get(line, ()):
            return True
        if func is not None:
            for ln in range(func.lineno,
                            getattr(func, "body", [func])[0].lineno):
                if rule in self.pragmas.get(ln, ()):
                    return True
        return False

    def enclosing_function(self, node: ast.AST) -> Optional[FunctionInfo]:
        best = None
        for fi in self.functions:
            f = fi.node
            end = getattr(f, "end_lineno", f.lineno)
            if f.lineno <= node.lineno <= end:
                if best is None or f.lineno > best.node.lineno:
                    best = fi
        return best


def call_name(node: ast.Call) -> Optional[str]:
    """Simple name of a call target: ``foo(..)`` -> foo, ``a.b.c(..)`` -> c."""
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def call_names(node: ast.AST) -> Set[str]:
    return {n for c in ast.walk(node) if isinstance(c, ast.Call)
            for n in [call_name(c)] if n is not None}


def dotted_call(node: ast.Call) -> str:
    """Render ``a.b.c(...)``'s target as "a.b.c" (best effort)."""
    parts: List[str] = []
    f = node.func
    while isinstance(f, ast.Attribute):
        parts.append(f.attr)
        f = f.value
    if isinstance(f, ast.Name):
        parts.append(f.id)
    return ".".join(reversed(parts))


class Workspace:
    """All parsed modules under the scanned roots, plus a name-indexed
    function table for the (conservative, name-based) call graph."""

    def __init__(self, roots: Iterable[Path]):
        self.modules: List[ModuleInfo] = []
        seen: Set[Path] = set()
        for root in roots:
            root = root.resolve()
            files = [root] if root.is_file() else sorted(root.rglob("*.py"))
            for path in files:
                if path in seen:
                    continue
                seen.add(path)
                try:
                    src = path.read_text()
                    # canonical rel path: from the `repro` package root when
                    # present, so baseline keys are identical whether the
                    # scan root is src/, src/repro/ or a single file
                    parts = path.parts
                    if "repro" in parts:
                        i = len(parts) - 1 - parts[::-1].index("repro")
                        rel = "/".join(parts[i + 1:])
                    else:
                        base = root if root.is_dir() else root.parent
                        rel = path.relative_to(base).as_posix()
                    self.modules.append(ModuleInfo(path, rel, src))
                except (SyntaxError, UnicodeDecodeError) as e:
                    raise SystemExit(f"rc3e-check: cannot parse {path}: {e}")
        self.by_name: Dict[str, List[FunctionInfo]] = {}
        for mod in self.modules:
            for fi in mod.functions:
                self.by_name.setdefault(fi.name, []).append(fi)

    def select(self, *subdirs: str) -> List[ModuleInfo]:
        """Modules whose relative path contains any of ``subdirs`` (empty
        selection = every module)."""
        if not subdirs:
            return list(self.modules)
        return [m for m in self.modules
                if any(f"/{d}/" in f"/{m.rel}" for d in subdirs)]

    def reachable_from(self, qualname: str) -> Set[str]:
        """Name-based reachability: start at the function whose qualname
        matches, follow callee *simple names* to any same-named definition
        in the workspace. Over-approximates (any same-named method is
        considered a callee) — exactly right for a lint that must not miss
        the hot path through duck-typed hooks."""
        start = [fi for m in self.modules for fi in m.functions
                 if fi.qualname == qualname]
        seen: Set[int] = set()
        out: Set[str] = set()
        work = list(start)
        while work:
            fi = work.pop()
            if id(fi) in seen:
                continue
            seen.add(id(fi))
            out.add(f"{fi.module.rel}::{fi.qualname}")
            for name in fi.callees:
                work.extend(self.by_name.get(name, ()))
        return out


# ---------------------------------------------------------------------------
# Baseline (grandfathered findings)
# ---------------------------------------------------------------------------

def load_baseline(path: Optional[Path]) -> Set[Tuple[str, str, str, str]]:
    if path is None or not path.exists():
        return set()
    raw = json.loads(path.read_text())
    return {(e["pass"], e["rule"], e["file"], e.get("symbol", ""))
            for e in raw.get("findings", [])}


def write_baseline(path: Path, findings: List[Finding]) -> None:
    entries = sorted({f.key() for f in findings})
    path.write_text(json.dumps({
        "comment": "rc3e-check grandfathered findings; regenerate with "
                   "`python -m repro.analysis src/ --write-baseline`. "
                   "New code must ship clean or carry an inline "
                   "`# rc3e: allow-<rule>` pragma with a justification.",
        "findings": [{"pass": p, "rule": r, "file": f, "symbol": s}
                     for (p, r, f, s) in entries],
    }, indent=1) + "\n")


def apply_suppressions(findings: List[Finding],
                       baseline: Set[Tuple[str, str, str, str]]
                       ) -> Tuple[List[Finding], List[Finding]]:
    """Split into (unbaselined, baselined). Pragma suppression happens in
    the passes themselves (they know the enclosing function)."""
    fresh = [f for f in findings if f.key() not in baseline]
    old = [f for f in findings if f.key() in baseline]
    return fresh, old
