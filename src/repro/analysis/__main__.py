"""CLI driver: ``python -m repro.analysis [roots...]``.

Exit status is the contract CI relies on: 0 when every finding is either
pragma-suppressed or in the committed baseline, 1 when anything new
slipped in, 2 on usage errors. ``--write-baseline`` regenerates the
grandfather ledger (review the diff — shrinking is progress, growth is a
regression someone must justify).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List

from repro.analysis import determinism, hostsync, kernelpass, ownership
from repro.analysis.common import (Finding, Workspace, apply_suppressions,
                                   load_baseline, write_baseline)

PASSES = (ownership, hostsync, determinism, kernelpass)


def _default_baseline(roots: List[Path]) -> Path:
    """analysis_baseline.json next to the scanned tree's repo root (the
    directory holding src/), falling back to the CWD."""
    for root in roots:
        for parent in [root.resolve()] + list(root.resolve().parents):
            if (parent / "analysis_baseline.json").exists() \
                    or (parent / ".git").exists():
                return parent / "analysis_baseline.json"
    return Path("analysis_baseline.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="rc3e-check: ownership / hostsync / determinism / "
                    "kernel passes over the serving dataplane")
    ap.add_argument("roots", nargs="*", default=["src/"],
                    help="directories or files to scan (default: src/)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="grandfather ledger (default: "
                         "analysis_baseline.json at the repo root)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from the current findings "
                         "and exit 0")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON (all of them, with a "
                         "baselined flag)")
    args = ap.parse_args(argv)

    roots = [Path(r) for r in args.roots]
    for r in roots:
        if not r.exists():
            ap.error(f"no such path: {r}")

    ws = Workspace(roots)
    findings: List[Finding] = []
    for p in PASSES:
        findings.extend(p.run(ws))
    findings.sort(key=lambda f: (f.file, f.line, f.rule))

    baseline_path = args.baseline or _default_baseline(roots)
    if args.write_baseline:
        write_baseline(baseline_path, findings)
        print(f"rc3e-check: wrote {len({f.key() for f in findings})} "
              f"grandfathered finding keys to {baseline_path}")
        return 0

    fresh, old = apply_suppressions(findings, load_baseline(baseline_path))

    if args.as_json:
        keys = {f.key() for f in old}
        print(json.dumps([{
            "pass": f.pass_name, "rule": f.rule, "file": f.file,
            "line": f.line, "symbol": f.symbol, "message": f.message,
            "baselined": f.key() in keys,
        } for f in findings], indent=1))
        return 1 if fresh else 0

    for f in fresh:
        print(f.format())
    n_mod = len(ws.modules)
    if fresh:
        print(f"\nrc3e-check: {len(fresh)} unbaselined finding(s) across "
              f"{n_mod} modules ({len(old)} baselined). Fix them, justify "
              "with `# rc3e: allow-<rule>`, or (last resort) regenerate "
              "the baseline with --write-baseline.")
        return 1
    print(f"rc3e-check: clean — {n_mod} modules, {len(old)} baselined "
          f"finding(s), 0 new.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
