"""Synthetic token pipeline: deterministic, host-sharded, learnable.

The stream mixes (a) a Zipf unigram backbone with (b) induction patterns
(repeated bigram episodes) so a real model's loss demonstrably falls below
the unigram entropy — giving the end-to-end training example a meaningful
learning signal without external data.

``DataPipeline`` yields {tokens, labels} numpy batches; feed through
``repro.rc2f.StreamFIFO`` for host->device overlap.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 512
    seq_len: int = 128
    batch_size: int = 8
    seed: int = 0
    zipf_a: float = 1.2
    induction_period: int = 16    # every k-th position repeats an episode
    n_hosts: int = 1              # host sharding of the global batch
    host_index: int = 0


class DataPipeline:
    def __init__(self, cfg: DataConfig):
        if cfg.batch_size % cfg.n_hosts:
            raise ValueError("global batch not divisible by n_hosts")
        self.cfg = cfg
        self.local_batch = cfg.batch_size // cfg.n_hosts

    def _rng_for(self, step: int) -> np.random.Generator:
        # independent of host count: seed by (seed, step); host slices rows
        return np.random.default_rng((self.cfg.seed, step))

    def batch_at(self, step: int) -> dict:
        """Deterministic global batch for ``step``, sliced to this host."""
        c = self.cfg
        rng = self._rng_for(step)
        # Zipf backbone, clipped to vocab
        toks = rng.zipf(c.zipf_a, size=(c.batch_size, c.seq_len + 1))
        toks = np.minimum(toks, c.vocab_size - 1).astype(np.int32)
        # induction episodes: copy a window so earlier context predicts later
        ep = c.induction_period
        if c.seq_len + 1 >= 2 * ep:
            starts = rng.integers(0, c.seq_len + 1 - 2 * ep,
                                  size=c.batch_size)
            for b in range(c.batch_size):
                s = starts[b]
                toks[b, s + ep: s + 2 * ep] = toks[b, s: s + ep]
        lo = self.cfg.host_index * self.local_batch
        hi = lo + self.local_batch
        return {"tokens": toks[lo:hi, :-1], "labels": toks[lo:hi, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def unigram_entropy_nats(self, n_samples: int = 200_000) -> float:
        """Empirical entropy of the marginal token distribution (the loss
        floor for a context-free predictor)."""
        c = self.cfg
        rng = np.random.default_rng(c.seed + 1)
        toks = np.minimum(rng.zipf(c.zipf_a, size=n_samples),
                          c.vocab_size - 1)
        counts = np.bincount(toks, minlength=c.vocab_size).astype(np.float64)
        p = counts / counts.sum()
        nz = p > 0
        return float(-(p[nz] * np.log(p[nz])).sum())
