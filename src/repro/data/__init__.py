from repro.data.synthetic import DataConfig, DataPipeline
