"""Flash attention (prefill) Pallas kernel: blocked online-softmax causal
attention with GQA and optional sliding window.

Layout: q (B, Hq, S, D), k/v (B, Hkv, S, D), Hq = G·Hkv.
Grid (B·Hq, S/bq, S/bk) — the kv block index is minor, so the fp32
accumulators (acc, m, l) live in VMEM scratch across the kv sweep and each
output tile is written once. Causal + window masking is computed from block
offsets with iota; fully-masked kv blocks are skipped via ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import registry as kreg

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, n_k: int, scale: float, window: int,
                  softcap: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq
    k_start = ik * bk
    # any overlap with the causal (and window) band?
    first_allowed_k = q_start - (window - 1) if window else 0
    relevant = (k_start <= q_start + bq - 1) & \
        (k_start + bk - 1 >= first_allowed_k)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos <= qpos
        if window:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float = 0.0, softcap: float = 0.0,
                    block_q: int = kreg.FLASH_BLOCK_DEFAULT,
                    block_k: int = kreg.FLASH_BLOCK_DEFAULT,
                    interpret: bool = False):
    """q (B, Hq, S, D); k, v (B, Hkv, S, D). Returns (B, Hq, S, D).

    ``block_q``/``block_k`` are tunable geometry knobs — legal ranges and
    divisibility rules live in ``kernels.registry``."""
    assert causal, "kernel implements the causal (decoder) case"
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    scale = scale or D ** -0.5
    bq = min(block_q, S)
    bk = min(block_k, S)
    reason = kreg.check_flash_blocks(S, block_q, block_k)
    assert S % bq == 0 and S % bk == 0 and reason is None, (S, bq, bk, reason)
    qf = q.reshape(B * Hq, S, D)
    grid = (B * Hq, S // bq, S // bk)

    def kv_map(h, iq, ik):
        # h = b * Hq + head; the matching kv row is b * Hkv + head // g
        return ((h // Hq) * Hkv + (h % Hq) // g, ik, 0)

    kf = k.reshape(B * Hkv, S, D)
    vf = v.reshape(B * Hkv, S, D)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, n_k=grid[2],
                          scale=scale, window=window, softcap=softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bk, D), kv_map),
            pl.BlockSpec((1, bk, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, S, D)
