"""KV-cache decode attention Pallas kernel: one new query token per sequence
against a (possibly ring-buffered) cache, GQA, online softmax over cache
blocks. This is the serve_step hot loop (decode_32k / long_500k cells).

Layout: q (B, Hq, D); k/v (B, Hkv, L, D); kpos (B, L) absolute positions
(-1 = empty); cur (B,) current positions. Grid (B·Hq, L/bk), accumulators in
VMEM scratch across the cache sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, kpos_ref, cur_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, n_k: int, scale: float,
                   window: int):
    _decode_body(q_ref, k_ref, v_ref, None, None, kpos_ref, cur_ref, o_ref,
                 acc_ref, m_ref, l_ref, n_k=n_k, scale=scale, window=window)


def _decode_kernel_q8(q_ref, k_ref, v_ref, ks_ref, vs_ref, kpos_ref, cur_ref,
                      o_ref, acc_ref, m_ref, l_ref, *, n_k: int, scale: float,
                      window: int):
    """int8-quantized cache variant: k/v arrive as int8 blocks + per-row
    fp32 scales and are dequantized in VMEM — HBM traffic for the cache
    sweep is halved vs bf16 (the decode roofline's dominant term)."""
    _decode_body(q_ref, k_ref, v_ref, ks_ref, vs_ref, kpos_ref, cur_ref,
                 o_ref, acc_ref, m_ref, l_ref, n_k=n_k, scale=scale,
                 window=window)


def _decode_body(q_ref, k_ref, v_ref, ks_ref, vs_ref, kpos_ref, cur_ref,
                 o_ref, acc_ref, m_ref, l_ref, *, n_k: int, scale: float,
                 window: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale        # (D,)
    k = k_ref[0].astype(jnp.float32)                # (bk, D)
    v = v_ref[0].astype(jnp.float32)
    if ks_ref is not None:                          # dequantize in VMEM
        k = k * ks_ref[0][:, None]
        v = v * vs_ref[0][:, None]
    kpos = kpos_ref[0]                              # (bk,)
    cur = cur_ref[0]                                # scalar

    s = jnp.dot(k, q, preferred_element_type=jnp.float32)   # (bk,)
    mask = (kpos >= 0) & (kpos <= cur)
    if window:
        mask &= (cur - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p[None, :], v, preferred_element_type=jnp.float32)[0]
    m_ref[0] = m_new

    @pl.when(ik == n_k - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[0], 1e-30)).astype(
            o_ref.dtype)


def decode_attention(q, k, v, kpos, cur, *, window: int = 0,
                     scale: float = 0.0, block_k: int = 512,
                     k_scale=None, v_scale=None, interpret: bool = False):
    """q (B, Hq, D); k/v (B, Hkv, L, D); kpos (B, L); cur (B,).

    ``k_scale``/``v_scale`` (B, Hkv, L) enable the int8-cache path: k/v are
    int8 and dequantized blockwise in VMEM. Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    Hkv, L = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = scale or D ** -0.5
    bk = min(block_k, L)
    assert L % bk == 0, (L, bk)
    grid = (B * Hq, L // bk)
    quant = k_scale is not None

    def kv_map(h, ik):
        return ((h // Hq) * Hkv + (h % Hq) // g, ik, 0)

    def kvs_map(h, ik):
        return ((h // Hq) * Hkv + (h % Hq) // g, ik)

    in_specs = [
        pl.BlockSpec((1, D), lambda h, ik: (h, 0)),
        pl.BlockSpec((1, bk, D), kv_map),
        pl.BlockSpec((1, bk, D), kv_map),
    ]
    operands = [q.reshape(B * Hq, D), k.reshape(B * Hkv, L, D),
                v.reshape(B * Hkv, L, D)]
    if quant:
        in_specs += [pl.BlockSpec((1, bk), kvs_map),
                     pl.BlockSpec((1, bk), kvs_map)]
        operands += [k_scale.reshape(B * Hkv, L),
                     v_scale.reshape(B * Hkv, L)]
        kernel = functools.partial(_decode_kernel_q8, n_k=grid[1],
                                   scale=scale, window=window)
    else:
        kernel = functools.partial(_decode_kernel, n_k=grid[1], scale=scale,
                                   window=window)
    in_specs += [
        pl.BlockSpec((1, bk), lambda h, ik: (h // Hq, ik)),
        pl.BlockSpec((1,), lambda h, ik: (h // Hq,)),
    ]
    operands += [kpos, cur]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, D), lambda h, ik: (h, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, D),
                                       q.dtype if not quant else jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((D,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    return out.reshape(B, Hq, D).astype(q.dtype)
