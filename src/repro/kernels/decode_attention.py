"""KV-cache decode attention Pallas kernel: one new query token per sequence
against a (possibly ring-buffered) cache, GQA, online softmax over cache
blocks. This is the serve_step hot loop (decode_32k / long_500k cells).

Layout: q (B, Hq, D); k/v (B, Hkv, L, D); kpos (B, L) absolute positions
(-1 = empty); cur (B,) current positions. Grid (B·Hq, L/bk), accumulators in
VMEM scratch across the cache sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import registry as kreg

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, kpos_ref, cur_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, n_k: int, scale: float,
                   window: int):
    _decode_body(q_ref, k_ref, v_ref, None, None, kpos_ref, cur_ref, o_ref,
                 acc_ref, m_ref, l_ref, n_k=n_k, scale=scale, window=window)


def _decode_kernel_q8(q_ref, k_ref, v_ref, ks_ref, vs_ref, kpos_ref, cur_ref,
                      o_ref, acc_ref, m_ref, l_ref, *, n_k: int, scale: float,
                      window: int):
    """int8-quantized cache variant: k/v arrive as int8 blocks + per-row
    fp32 scales and are dequantized in VMEM — HBM traffic for the cache
    sweep is halved vs bf16 (the decode roofline's dominant term)."""
    _decode_body(q_ref, k_ref, v_ref, ks_ref, vs_ref, kpos_ref, cur_ref,
                 o_ref, acc_ref, m_ref, l_ref, n_k=n_k, scale=scale,
                 window=window)


def _sweep_update(q, k, v, kpos, cur, o_ref, acc_ref, m_ref, l_ref, *,
                  ik, n_k: int, window: int):
    """One cache-block step of the online softmax: q (D,), k/v (bk, D) in
    fp32, kpos (bk,). Shared by the dense and block-table-paged sweeps."""
    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    s = jnp.dot(k, q, preferred_element_type=jnp.float32)   # (bk,)
    mask = (kpos >= 0) & (kpos <= cur)
    if window:
        mask &= (cur - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p[None, :], v, preferred_element_type=jnp.float32)[0]
    m_ref[0] = m_new

    @pl.when(ik == n_k - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[0], 1e-30)).astype(
            o_ref.dtype)


def _decode_body(q_ref, k_ref, v_ref, ks_ref, vs_ref, kpos_ref, cur_ref,
                 o_ref, acc_ref, m_ref, l_ref, *, n_k: int, scale: float,
                 window: int):
    q = q_ref[0].astype(jnp.float32) * scale        # (D,)
    k = k_ref[0].astype(jnp.float32)                # (bk, D)
    v = v_ref[0].astype(jnp.float32)
    if ks_ref is not None:                          # dequantize in VMEM
        k = k * ks_ref[0][:, None]
        v = v * vs_ref[0][:, None]
    _sweep_update(q, k, v, kpos_ref[0], cur_ref[0], o_ref, acc_ref, m_ref,
                  l_ref, ik=pl.program_id(1), n_k=n_k, window=window)


def decode_attention(q, k, v, kpos, cur, *, window: int = 0,
                     scale: float = 0.0,
                     block_k: int = kreg.DECODE_BLOCK_DEFAULT,
                     k_scale=None, v_scale=None, interpret: bool = False):
    """q (B, Hq, D); k/v (B, Hkv, L, D); kpos (B, L); cur (B,).

    ``block_k`` is a tunable geometry knob — legal range and divisibility
    rule live in ``kernels.registry``. ``k_scale``/``v_scale`` (B, Hkv, L)
    enable the int8-cache path: k/v are int8 and dequantized blockwise in
    VMEM. Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    Hkv, L = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = scale or D ** -0.5
    bk = min(block_k, L)
    reason = kreg.check_decode_block(L, block_k)
    assert L % bk == 0 and reason is None, (L, bk, reason)
    grid = (B * Hq, L // bk)
    quant = k_scale is not None

    def kv_map(h, ik):
        return ((h // Hq) * Hkv + (h % Hq) // g, ik, 0)

    def kvs_map(h, ik):
        return ((h // Hq) * Hkv + (h % Hq) // g, ik)

    in_specs = [
        pl.BlockSpec((1, D), lambda h, ik: (h, 0)),
        pl.BlockSpec((1, bk, D), kv_map),
        pl.BlockSpec((1, bk, D), kv_map),
    ]
    operands = [q.reshape(B * Hq, D), k.reshape(B * Hkv, L, D),
                v.reshape(B * Hkv, L, D)]
    if quant:
        in_specs += [pl.BlockSpec((1, bk), kvs_map),
                     pl.BlockSpec((1, bk), kvs_map)]
        operands += [k_scale.reshape(B * Hkv, L),
                     v_scale.reshape(B * Hkv, L)]
        kernel = functools.partial(_decode_kernel_q8, n_k=grid[1],
                                   scale=scale, window=window)
    else:
        kernel = functools.partial(_decode_kernel, n_k=grid[1], scale=scale,
                                   window=window)
    in_specs += [
        pl.BlockSpec((1, bk), lambda h, ik: (h // Hq, ik)),
        pl.BlockSpec((1,), lambda h, ik: (h // Hq,)),
    ]
    operands += [kpos, cur]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, D), lambda h, ik: (h, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, D),
                                       q.dtype if not quant else jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((D,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    return out.reshape(B, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged variant: the cache lives in a shared page pool, each sequence's
# pages located through a block table (scalar-prefetched so the BlockSpec
# index maps can read page ids before the DMA is issued).
# ---------------------------------------------------------------------------

def _paged_kernel(bt_ref, q_ref, k_ref, v_ref, kpos_ref, cur_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, n_k: int, scale: float,
                  window: int):
    q = q_ref[0].astype(jnp.float32) * scale        # (D,)
    k = k_ref[0, 0].astype(jnp.float32)             # (ps, D)
    v = v_ref[0, 0].astype(jnp.float32)
    _sweep_update(q, k, v, kpos_ref[0], cur_ref[0], o_ref, acc_ref, m_ref,
                  l_ref, ik=pl.program_id(1), n_k=n_k, window=window)


def _paged_kernel_q8(bt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, kpos_ref,
                     cur_ref, o_ref, acc_ref, m_ref, l_ref, *, n_k: int,
                     scale: float, window: int):
    q = q_ref[0].astype(jnp.float32) * scale
    k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]
    v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]
    _sweep_update(q, k, v, kpos_ref[0], cur_ref[0], o_ref, acc_ref, m_ref,
                  l_ref, ik=pl.program_id(1), n_k=n_k, window=window)


def paged_decode_attention(q, k_pool, v_pool, kpos_pool, block_tables, cur, *,
                           window: int = 0, scale: float = 0.0,
                           k_scale=None, v_scale=None,
                           interpret: bool = False):
    """Block-table-indirect decode attention over a shared page pool.

    q (B, Hq, D); k/v pools (P, Hkv, ps, D); kpos_pool (P, ps) absolute
    positions (-1 = empty); block_tables (B, nb) int32 page ids; cur (B,).
    The cache sweep walks each sequence's block table: grid step (h, j)
    DMAs page ``block_tables[b, j]`` straight from the pool — no dense
    (B, L) cache ever materializes, so HBM holds one copy of every shared
    (prefix) page. Unused table entries must point at pages whose kpos is
    -1 (the engine reserves page 0 for this). ``k_scale``/``v_scale``
    (P, Hkv, ps) enable the int8-pool path. Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    Hkv, ps = k_pool.shape[1], k_pool.shape[2]
    nb = block_tables.shape[1]
    g = Hq // Hkv
    scale = scale or D ** -0.5
    grid = (B * Hq, nb)
    quant = k_scale is not None

    def kv_map(h, j, bt):
        return (bt[h // Hq, j], (h % Hq) // g, 0, 0)

    def kvs_map(h, j, bt):
        return (bt[h // Hq, j], (h % Hq) // g, 0)

    in_specs = [
        pl.BlockSpec((1, D), lambda h, j, bt: (h, 0)),
        pl.BlockSpec((1, 1, ps, D), kv_map),
        pl.BlockSpec((1, 1, ps, D), kv_map),
    ]
    operands = [q.reshape(B * Hq, D), k_pool, v_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, 1, ps), kvs_map),
                     pl.BlockSpec((1, 1, ps), kvs_map)]
        operands += [k_scale, v_scale]
        kernel = functools.partial(_paged_kernel_q8, n_k=nb, scale=scale,
                                   window=window)
    else:
        kernel = functools.partial(_paged_kernel, n_k=nb, scale=scale,
                                   window=window)
    in_specs += [
        pl.BlockSpec((1, ps), lambda h, j, bt: (bt[h // Hq, j], 0)),
        pl.BlockSpec((1,), lambda h, j, bt: (h // Hq,)),
    ]
    operands += [kpos_pool, cur]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, D), lambda h, j, bt: (h, 0)),
        scratch_shapes=[
            pltpu.VMEM((D,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hq, D),
                                       q.dtype if not quant else jnp.float32),
        interpret=interpret,
    )(block_tables, *operands)
    return out.reshape(B, Hq, D).astype(q.dtype)
