"""Pure-jnp oracles for every kernel (the ground truth tests compare to)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a, b):
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)


def matmul_batched_ref(a, b):
    return jax.vmap(matmul_ref)(a, b)


def flash_attention_ref(q, k, v, *, window: int = 0, scale: float = 0.0,
                        softcap: float = 0.0):
    """q (B,Hq,S,D); k/v (B,Hkv,S,D) causal (+optional window)."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    scale = scale or D ** -0.5
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   kk.astype(jnp.float32))
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)


def ssd_chunk_scan_ref(x, dt, Bm, Cm, a, d):
    """Sequential (non-chunked) SSD recurrence. x (BH,S,P); dt (BH,S);
    Bm/Cm (BH,S,N); a/d (BH,). The exact reference for the chunked kernel."""
    BH, S, P = x.shape
    N = Bm.shape[-1]

    def per_row(xr, dtr, br, cr, ar, dr):
        def step(state, inp):
            xt, dtt, bt, ct = inp
            dA = jnp.exp(dtt * ar)
            state = state * dA + jnp.outer(xt * dtt, bt)     # (P, N)
            y = state @ ct + dr * xt
            return state, y
        _, ys = jax.lax.scan(
            step, jnp.zeros((P, N), jnp.float32),
            (xr.astype(jnp.float32), dtr.astype(jnp.float32),
             br.astype(jnp.float32), cr.astype(jnp.float32)))
        return ys

    return jax.vmap(per_row)(x, dt, Bm, Cm, a, d).astype(x.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, kpos_pool, block_tables,
                               cur, *, window: int = 0, scale: float = 0.0,
                               k_scale=None, v_scale=None):
    """Paged-cache oracle: gather pages through the block table into the
    dense layout, then defer to ``decode_attention_ref``.

    q (B, Hq, D); k/v pools (P, Hkv, ps, D); kpos_pool (P, ps);
    block_tables (B, nb) int32 page ids; cur (B,). Unused block-table
    entries must reference pages whose kpos entries are -1 (the engine
    reserves page 0 for this). ``k_scale``/``v_scale`` (P, Hkv, ps) enable
    the int8-pool path."""
    B, nb = block_tables.shape
    Hkv, ps = k_pool.shape[1], k_pool.shape[2]
    L = nb * ps

    def gather(pool):                       # (P, Hkv, ps, ...) -> (B, Hkv, L, ...)
        g = pool[block_tables]              # (B, nb, Hkv, ps, ...)
        return jnp.moveaxis(g, 2, 1).reshape((B, Hkv, L) + pool.shape[3:])

    kpos = kpos_pool[block_tables].reshape(B, L)
    return decode_attention_ref(
        q, gather(k_pool), gather(v_pool), kpos, cur, window=window,
        scale=scale,
        k_scale=None if k_scale is None else gather(k_scale),
        v_scale=None if v_scale is None else gather(v_scale))


def decode_attention_ref(q, k, v, kpos, cur, *, window: int = 0,
                         scale: float = 0.0, k_scale=None, v_scale=None):
    B, Hq, D = q.shape
    Hkv, L = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = scale or D ** -0.5
    if k_scale is not None:
        k = k.astype(jnp.float32) * k_scale[..., None]
        v = v.astype(jnp.float32) * v_scale[..., None]
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhd,bhld->bhl", q.astype(jnp.float32) * scale,
                   kk.astype(jnp.float32))
    mask = (kpos >= 0) & (kpos <= cur[:, None])
    if window:
        mask &= (cur[:, None] - kpos) < window
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhl,bhld->bhd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)
