"""Pallas TPU kernels (validated on CPU via interpret mode; ``ops`` picks
kernel vs jnp reference by backend)."""
from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2_chunk import ssd_chunk_scan
from repro.kernels.stream_matmul import stream_matmul, stream_matmul_batched
