"""Jit'd dispatch wrappers: Pallas kernels on TPU, jnp references elsewhere.

``force`` overrides: "kernel" (compiled pallas), "interpret" (pallas in
interpret mode — the CPU validation path), "ref" (pure jnp).

Block sizes are tunable geometry knobs (legal ranges in
``kernels.registry``; swept by ``repro.tuning``). They are static args —
each geometry is its own executable — and no-ops on the ref path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import ref as _ref
from repro.kernels import registry as kreg
from repro.kernels.decode_attention import (
    decode_attention as _decode_k,
    paged_decode_attention as _paged_decode_k)
from repro.kernels.flash_attention import flash_attention as _flash_k
from repro.kernels.mamba2_chunk import ssd_chunk_scan as _ssd_k
from repro.kernels.stream_matmul import (stream_matmul as _mm_k,
                                         stream_matmul_batched as _mmb_k)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(force: Optional[str]) -> str:
    if force is not None:
        return force
    return "kernel" if _on_tpu() else "ref"


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "force"))
def matmul(a, b, block_m: int = kreg.MM_BLOCK_DEFAULT,
           block_n: int = kreg.MM_BLOCK_DEFAULT,
           block_k: int = kreg.MM_BLOCK_DEFAULT,
           force: Optional[str] = None):
    m = _mode(force)
    if m == "ref":
        return _ref.matmul_ref(a, b)
    return _mm_k(a, b, block_m=block_m, block_n=block_n, block_k=block_k,
                 interpret=(m == "interpret"))


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "force"))
def matmul_batched(a, b, block_m: int = kreg.MM_BLOCK_DEFAULT,
                   block_n: int = kreg.MM_BLOCK_DEFAULT,
                   block_k: int = kreg.MM_BLOCK_DEFAULT,
                   force: Optional[str] = None):
    m = _mode(force)
    if m == "ref":
        return _ref.matmul_batched_ref(a, b)
    return _mmb_k(a, b, block_m=block_m, block_n=block_n, block_k=block_k,
                  interpret=(m == "interpret"))


@functools.partial(jax.jit,
                   static_argnames=("window", "scale", "softcap", "block_q",
                                    "block_k", "force"))
def flash_attention(q, k, v, window: int = 0, scale: float = 0.0,
                    softcap: float = 0.0,
                    block_q: int = kreg.FLASH_BLOCK_DEFAULT,
                    block_k: int = kreg.FLASH_BLOCK_DEFAULT,
                    force: Optional[str] = None):
    m = _mode(force)
    if m == "ref":
        return _ref.flash_attention_ref(q, k, v, window=window, scale=scale,
                                        softcap=softcap)
    return _flash_k(q, k, v, window=window, scale=scale, softcap=softcap,
                    block_q=block_q, block_k=block_k,
                    interpret=(m == "interpret"))


@functools.partial(jax.jit, static_argnames=("chunk", "force"))
def ssd_chunk_scan(x, dt, Bm, Cm, a, d, chunk: int = 256,
                   force: Optional[str] = None):
    m = _mode(force)
    if m == "ref":
        return _ref.ssd_chunk_scan_ref(x, dt, Bm, Cm, a, d)
    return _ssd_k(x, dt, Bm, Cm, a, d, chunk=chunk,
                  interpret=(m == "interpret"))


@functools.partial(jax.jit, static_argnames=("window", "scale", "block_k",
                                             "force"))
def decode_attention(q, k, v, kpos, cur, window: int = 0, scale: float = 0.0,
                     block_k: int = kreg.DECODE_BLOCK_DEFAULT,
                     k_scale=None, v_scale=None, force: Optional[str] = None):
    m = _mode(force)
    if m == "ref":
        return _ref.decode_attention_ref(q, k, v, kpos, cur, window=window,
                                         scale=scale, k_scale=k_scale,
                                         v_scale=v_scale)
    return _decode_k(q, k, v, kpos, cur, window=window, scale=scale,
                     block_k=block_k, k_scale=k_scale, v_scale=v_scale,
                     interpret=(m == "interpret"))


@functools.partial(jax.jit, static_argnames=("window", "scale", "force"))
def paged_decode_attention(q, k_pool, v_pool, kpos_pool, block_tables, cur,
                           window: int = 0, scale: float = 0.0,
                           k_scale=None, v_scale=None,
                           force: Optional[str] = None):
    m = _mode(force)
    if m == "ref":
        return _ref.paged_decode_attention_ref(
            q, k_pool, v_pool, kpos_pool, block_tables, cur, window=window,
            scale=scale, k_scale=k_scale, v_scale=v_scale)
    return _paged_decode_k(q, k_pool, v_pool, kpos_pool, block_tables, cur,
                           window=window, scale=scale, k_scale=k_scale,
                           v_scale=v_scale, interpret=(m == "interpret"))
