"""Streaming matrix-multiply kernel — the paper's §V example application,
re-tiled for the TPU MXU instead of an HLS systolic core.

The paper streams 100k small (16×16 / 32×32) matrix multiplications through
a vFPGA core. On TPU the same workload is a batched matmul whose profitable
tiling is MXU-aligned (128×128×128 fp32/bf16 blocks): the kernel walks the
K dimension in VMEM-resident blocks, accumulating in an fp32 VMEM scratch,
and writes each (bm, bn) output tile once — HBM traffic is exactly
A + B + O, the streaming ideal.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import registry as kreg


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def stream_matmul(a, b, *, block_m: int = kreg.MM_BLOCK_DEFAULT,
                  block_n: int = kreg.MM_BLOCK_DEFAULT,
                  block_k: int = kreg.MM_BLOCK_DEFAULT,
                  interpret: bool = False):
    """a (M, K) @ b (K, N) with MXU-aligned VMEM tiling.

    Block sizes are tunable geometry knobs (``kernels.registry``). Shapes
    are padded up to block multiples (zeros contribute nothing).
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = (min(block_m, _ceil_mult(M, 8)),
                  min(block_n, _ceil_mult(N, 128)),
                  min(block_k, _ceil_mult(K, 128)))
    Mp, Np, Kp = _pad_to(M, bm), _pad_to(N, bn), _pad_to(K, bk)
    a_p = jnp.pad(a, ((0, Mp - M), (0, Kp - K)))
    b_p = jnp.pad(b, ((0, Kp - K), (0, Np - N)))
    grid = (Mp // bm, Np // bn, Kp // bk)
    out = pl.pallas_call(
        functools.partial(_mm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a_p, b_p)
    return out[:M, :N]


def stream_matmul_batched(a, b, **kw):
    """(G, M, K) @ (G, K, N): the paper's '100,000 multiplications' stream.
    vmap over the stream; each element reuses the MXU tiling."""
    return jax.vmap(lambda x, y: stream_matmul(x, y, **kw))(a, b)


def _pad_to(n: int, b: int) -> int:
    return -(-n // b) * b


def _ceil_mult(n: int, m: int) -> int:
    return max(m, _pad_to(n, m))
