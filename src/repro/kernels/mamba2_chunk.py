"""Mamba2 SSD chunk-scan Pallas kernel.

One grid row per (batch·head); the chunk index is the minor grid dim so the
(P, N) SSD state lives in VMEM scratch across the sequential chunk sweep —
the TPU analogue of the paper-adapted streaming core: HBM traffic per chunk
is x/B/C/dt in, y out, state never leaves VMEM.

Inputs are pre-expanded per head by the wrapper:
  x  (BH, S, P)   dt (BH, S)   Bm/Cm (BH, S, N)   a (BH,) negative decay
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, state_ref,
                *, Q: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)          # (Q,)
    Bm = b_ref[0].astype(jnp.float32)           # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)           # (Q, N)
    a = a_ref[0]                                # scalar (negative)

    dA = dt * a                                 # (Q,)
    cums = jnp.cumsum(dA)                       # (Q,)
    seg = cums[:, None] - cums[None, :]         # (Qi, Qj)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.exp(jnp.where(ii >= jj, seg, -jnp.inf))   # mask pre-exp
    CB = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)   # (Q, Q)
    dtx = x * dt[:, None]                       # (Q, P)
    y_intra = jnp.dot(CB * L, dtx, preferred_element_type=jnp.float32)

    state = state_ref[...]                      # (P, N)
    y_inter = jnp.exp(cums)[:, None] * jnp.dot(
        Cm, state.T, preferred_element_type=jnp.float32)          # (Q, P)

    total = cums[-1]
    decay_out = jnp.exp(total - cums)           # (Q,)
    contrib = jnp.dot(dtx.T, Bm * decay_out[:, None],
                      preferred_element_type=jnp.float32)         # (P, N)
    state_ref[...] = state * jnp.exp(total) + contrib

    y = y_intra + y_inter + d_ref[0] * x
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_chunk_scan(x, dt, Bm, Cm, a, d, *, chunk: int = 256,
                   interpret: bool = False):
    """x (BH, S, P); dt (BH, S); Bm/Cm (BH, S, N); a/d (BH,).
    Returns y (BH, S, P)."""
    BH, S, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    grid = (BH, S // Q)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, Q), lambda h, c: (h, c)),
            pl.BlockSpec((1, Q, N), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, Q, N), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1,), lambda h, c: (h,)),
            pl.BlockSpec((1,), lambda h, c: (h,)),
        ],
        out_specs=pl.BlockSpec((1, Q, P), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, Bm, Cm, a, d)
