"""Legal-geometry registry for the Pallas dataplane kernels.

Single source of truth for the block-size / page-size / pool-geometry
design space that the auto-tuner (``repro.tuning``) explores and the
rc3e-check kernel pass (``repro.analysis.kernelpass``) verifies. Every
knob the kernels accept is declared here with its legal range plus the
hard TPU constraints (min tile shapes, lane width, VMEM budget) that
candidates must satisfy.

Deliberately jax-free: the bare-lint analysis environment imports this
module without a jax install.
"""
from __future__ import annotations

from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Hard TPU tiling constraints (see the Pallas guide: MXU 128x128, VPU 8x128;
# min tile (sublane, lane) is dtype-dependent, lane dim always 128).
# ---------------------------------------------------------------------------
LANE = 128
SUBLANE_F32 = 8
SUBLANE_BF16 = 16
SUBLANE_INT8 = 32
VMEM_BYTES = 16 * 1024 * 1024       # per-core VMEM budget (v5e-class)

# hand-picked defaults that shipped before the tuner existed
DECODE_BLOCK_DEFAULT = 512
FLASH_BLOCK_DEFAULT = 256
MM_BLOCK_DEFAULT = 128
PAGE_SIZE_DEFAULT = 16
SLOTS_DEFAULT = 4
PREFILL_CHUNK_DEFAULT = 4

# legal ranges (the CDSE sweep axes)
DECODE_BLOCK_CHOICES: Tuple[int, ...] = (128, 256, 512, 1024, 2048)
FLASH_BLOCK_CHOICES: Tuple[int, ...] = (128, 256, 512)
MM_BLOCK_CHOICES: Tuple[int, ...] = (128, 256, 512)
PAGE_SIZE_CHOICES: Tuple[int, ...] = (8, 16, 32, 64)
SLOTS_CHOICES: Tuple[int, ...] = (2, 4, 8)
PREFILL_CHUNK_CHOICES: Tuple[int, ...] = (2, 4, 8, 16)


def sublane(dtype: str) -> int:
    if "int8" in dtype:
        return SUBLANE_INT8
    if "bfloat16" in dtype or "float16" in dtype:
        return SUBLANE_BF16
    return SUBLANE_F32


def dtype_bytes(dtype: str) -> int:
    if "int8" in dtype:
        return 1
    if "bfloat16" in dtype or "float16" in dtype:
        return 2
    if "float64" in dtype or "int64" in dtype:
        return 8
    return 4


# ---------------------------------------------------------------------------
# Divisibility rules — mirror the asserts inside the kernels themselves.
# Each returns None when legal, else a human-readable reason (the tuner
# prunes on it; the analysis pass fails on it).
# ---------------------------------------------------------------------------

def check_decode_block(cache_len: int, block_k: int) -> Optional[str]:
    """decode_attention sweeps the cache in blocks of ``min(block_k, L)``
    and requires L to divide evenly (kernels/decode_attention.py)."""
    if block_k < 1:
        return f"decode block_k={block_k} < 1"
    bk = min(block_k, cache_len)
    if cache_len % bk != 0:
        return f"cache_len={cache_len} not divisible by block_k={bk}"
    return None


def check_flash_blocks(seq_len: int, block_q: int,
                       block_k: int) -> Optional[str]:
    """flash_attention tiles (S // bq, S // bk); both must divide S."""
    bq, bk = min(block_q, seq_len), min(block_k, seq_len)
    if seq_len % bq != 0:
        return f"seq_len={seq_len} not divisible by block_q={bq}"
    if seq_len % bk != 0:
        return f"seq_len={seq_len} not divisible by block_k={bk}"
    return None


def check_page_size(max_len: int, page_size: int) -> Optional[str]:
    """The paged pool carves max_len into whole pages; the engine asserts
    ``max_len % page_size == 0`` (runtime/serve.py)."""
    if page_size < 1:
        return f"page_size={page_size} < 1"
    if max_len % page_size != 0:
        return f"max_len={max_len} not divisible by page_size={page_size}"
    return None


def check_head_alignment(head_dim: int) -> Optional[str]:
    """Kernel layouts put head_dim on the sublane axis — keep it a multiple
    of the fp32 min sublane so blocks tile."""
    if head_dim % SUBLANE_F32 != 0:
        return f"head_dim={head_dim} not a multiple of {SUBLANE_F32}"
    return None


# ---------------------------------------------------------------------------
# VMEM footprints (bytes) — per-grid-step working sets, mirroring the
# BlockSpec + scratch shapes inside each kernel. Used for hard pruning.
# ---------------------------------------------------------------------------

def decode_vmem_bytes(block_k: int, head_dim: int, kv_dtype: str) -> int:
    """decode_attention grid step: q (D,) fp32 + k/v blocks (bk, D) + kpos
    (bk,) + fp32 scratch acc (D,) + m/l (1,)."""
    kvb = dtype_bytes(kv_dtype)
    q = head_dim * 4
    kv = 2 * block_k * head_dim * kvb
    kpos = block_k * 4
    scratch = head_dim * 4 + 2 * 4
    return q + kv + kpos + scratch


def flash_vmem_bytes(block_q: int, block_k: int, head_dim: int,
                     dtype: str) -> int:
    """flash_attention grid step: q (bq, D) + k/v (bk, D) + acc scratch
    (bq, D) fp32 + m/l (bq,) fp32."""
    db = dtype_bytes(dtype)
    q = block_q * head_dim * db
    kv = 2 * block_k * head_dim * db
    scratch = block_q * head_dim * 4 + 2 * block_q * 4
    return q + kv + scratch


def matmul_vmem_bytes(block_m: int, block_n: int, block_k: int,
                      dtype: str) -> int:
    """stream_matmul grid step: a (bm, bk) + b (bk, bn) + fp32 acc (bm, bn)
    + out (bm, bn)."""
    db = dtype_bytes(dtype)
    return (block_m * block_k * db + block_k * block_n * db
            + block_m * block_n * (4 + db))
